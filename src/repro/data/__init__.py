"""Deterministic, shardable, resumable synthetic data pipeline."""

from .pipeline import TokenPipeline  # noqa: F401
