"""Token data pipeline.

Determinism contract (what survives restarts and elastic resize):
  * the batch for global step ``t`` is a pure function of (seed, t) —
    NOT of any iterator state — so restart-from-checkpoint resumes exactly;
  * host-sharding: each host materializes only its slice
    ``[host_id::n_hosts]`` of the global batch, so the same stream works at
    any host count (elastic rescale just changes the slicing);
  * a tiny background prefetch thread keeps ``depth`` batches ready.

The generator synthesizes a mixture of repeated n-grams (so models have
something learnable) over a configurable vocab.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    ngram: int = 8

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The (host slice of the) batch for global step ``step``."""
        rng = np.random.default_rng((self.seed, step))
        b = self.global_batch
        # learnable structure: each row repeats a small set of n-grams
        base = rng.integers(0, self.vocab, (b, self.ngram), dtype=np.int32)
        reps = -(-(self.seq_len + 1) // self.ngram)
        toks = np.tile(base, (1, reps))[:, : self.seq_len + 1]
        noise = rng.random((b, self.seq_len + 1)) < 0.05
        toks = np.where(noise, rng.integers(0, self.vocab, toks.shape), toks)
        sl = slice(self.host_id, None, self.n_hosts)
        return {
            "tokens": toks[sl, :-1].astype(np.int32),
            "labels": toks[sl, 1:].astype(np.int32),
            "mask": np.ones((toks[sl].shape[0], self.seq_len), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def prefetching(self, start_step: int = 0, depth: int = 2):
        """Iterator with a background prefetch thread, resumable at a step."""
        q: "queue.Queue" = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                q.put((s, self.batch_at(s)))
                s += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()

        class _Iter:
            def __iter__(self):
                return self

            def __next__(self):
                return q.get()

            def close(self):
                stop.set()
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass

        return _Iter()
