"""Optimizers as pure pytree transforms (no external deps).

State layout mirrors the parameter tree so the ZeRO-1 sharding rules in
``models.sharding`` can address moments exactly like weights.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params) → (new_params, new_state)


def AdamW(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree_util.tree_leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def m_next(g, m):
            return b1 * m + (1 - b1) * g.astype(jnp.float32)

        def v_next(g, v):
            g32 = g.astype(jnp.float32)
            return b2 * v + (1 - b2) * g32 * g32

        new_m = jax.tree_util.tree_map(m_next, grads, state["m"])
        new_v = jax.tree_util.tree_map(v_next, grads, state["v"])

        def p_next(p, m, v):
            delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(p_next, params, new_m, new_v)
        return new_params, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


def SGD(lr: float = 1e-2, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {
            "mom": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        new_m = jax.tree_util.tree_map(
            lambda g, m: momentum * m + g.astype(jnp.float32), grads, state["mom"])
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, new_m)
        return new_params, {"mom": new_m, "step": state["step"] + 1}

    return Optimizer(init, update)
