"""Training substrate: optimizers, step builders, gradient compression."""

from .optimizer import AdamW, Optimizer, SGD  # noqa: F401
