"""Sharded, atomic, elastic checkpointing (no external deps).

Layout::

    <dir>/step_<N>/
        manifest.json        # tree structure, shapes, dtypes, shard map, hashes
        shard_<i>.npz        # leaf arrays, chunked along dim 0 per shard

Properties needed at 1000+ nodes:
  * **atomic**: written to ``step_<N>.tmp`` then os.rename'd — a crash
    mid-write never corrupts the latest checkpoint;
  * **sharded**: leaves split into ``n_shards`` files so hosts write/read in
    parallel (here one process writes all shards; the layout is the same);
  * **elastic reshard**: restore() takes the *target* pytree structure and
    re-slices shards onto whatever mesh/shape the new job uses — a 2-pod
    checkpoint restores onto 1 pod (pod loss) and vice versa;
  * **integrity**: content hashes per shard, verified on load;
  * **gc**: keep the most recent ``keep`` checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, n_shards: int = 4, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.n_shards = n_shards
        self.keep = keep

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None) -> Path:
        items, _ = _flatten(tree)
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        manifest: Dict[str, Any] = {"step": step, "leaves": {}, "extra": extra or {},
                                    "n_shards": self.n_shards}
        shards: List[Dict[str, np.ndarray]] = [dict() for _ in range(self.n_shards)]
        for name, leaf in items:
            arr = np.asarray(leaf)
            manifest["leaves"][name] = {"shape": list(arr.shape),
                                        "dtype": str(arr.dtype)}
            if arr.ndim == 0 or arr.shape[0] < self.n_shards:
                shards[0][name] = arr
                manifest["leaves"][name]["shards"] = [0]
            else:
                chunks = np.array_split(arr, self.n_shards, axis=0)
                for i, c in enumerate(chunks):
                    shards[i][name] = c
                manifest["leaves"][name]["shards"] = list(range(self.n_shards))

        hashes = []
        for i, shard in enumerate(shards):
            path = tmp / f"shard_{i}.npz"
            np.savez(path, **{k.replace("/", "|"): v for k, v in shard.items()})
            hashes.append(hashlib.sha256(path.read_bytes()).hexdigest())
        manifest["hashes"] = hashes
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))

        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    # -- restore ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, target_tree: Any, step: Optional[int] = None,
                verify: bool = True) -> Tuple[Any, Dict[str, Any]]:
        """Load into the *structure* (and shardings) of ``target_tree``.

        ``target_tree`` may hold arrays or ShapeDtypeStructs; shapes must
        match the saved shapes (elastic resharding = different device
        placement of the same global array, which jax.device_put handles).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())

        if verify:
            for i, want in enumerate(manifest["hashes"]):
                got = hashlib.sha256((d / f"shard_{i}.npz").read_bytes()).hexdigest()
                if got != want:
                    raise IOError(f"checkpoint shard {i} hash mismatch at step {step}")

        loaded = [np.load(d / f"shard_{i}.npz") for i in range(manifest["n_shards"])]
        items, treedef = _flatten(target_tree)
        leaves = []
        for name, leaf in items:
            info = manifest["leaves"].get(name)
            if info is None:
                raise KeyError(f"checkpoint missing leaf {name}")
            key = name.replace("/", "|")
            parts = [loaded[i][key] for i in info["shards"]]
            arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
            want_shape = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"{name}: checkpoint shape {arr.shape} != target {want_shape}")
            if isinstance(leaf, jax.Array) and hasattr(leaf, "sharding"):
                arr = jax.device_put(arr, leaf.sharding)   # elastic reshard
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]

    def _gc(self) -> None:
        steps = sorted(p for p in self.dir.glob("step_*") if not p.name.endswith(".tmp"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p)
