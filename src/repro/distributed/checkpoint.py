"""Sharded, atomic, elastic checkpointing (no external deps).

Layout::

    <dir>/step_<N>/
        manifest.json        # tree structure, shapes, dtypes, shard map, hashes
        shard_<i>.npz        # leaf arrays, chunked along dim 0 per shard

Properties needed at 1000+ nodes:
  * **atomic**: written to ``step_<N>.tmp`` then os.rename'd — a crash
    mid-write never corrupts the latest checkpoint;
  * **sharded**: leaves split into ``n_shards`` files so hosts write/read in
    parallel (here one process writes all shards; the layout is the same);
  * **elastic reshard**: restore() takes the *target* pytree structure and
    re-slices shards onto whatever mesh/shape the new job uses — a 2-pod
    checkpoint restores onto 1 pod (pod loss) and vice versa;
  * **integrity**: content hashes per shard, verified on load — a failed
    verification (or an unreadable manifest) quarantines the step directory
    (renamed ``step_<N>.corrupt``, matching the PlanStore idiom) and
    restore falls back to the previous step with a ``warn_event`` instead
    of raising; ``restore(..., strict=True)`` keeps the raising behavior;
  * **gc**: keep the most recent ``keep`` checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, n_shards: int = 4, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.n_shards = n_shards
        self.keep = keep

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None) -> Path:
        items, _ = _flatten(tree)
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        manifest: Dict[str, Any] = {"step": step, "leaves": {}, "extra": extra or {},
                                    "n_shards": self.n_shards}
        shards: List[Dict[str, np.ndarray]] = [dict() for _ in range(self.n_shards)]
        for name, leaf in items:
            arr = np.asarray(leaf)
            manifest["leaves"][name] = {"shape": list(arr.shape),
                                        "dtype": str(arr.dtype)}
            if arr.ndim == 0 or arr.shape[0] < self.n_shards:
                shards[0][name] = arr
                manifest["leaves"][name]["shards"] = [0]
            else:
                chunks = np.array_split(arr, self.n_shards, axis=0)
                for i, c in enumerate(chunks):
                    shards[i][name] = c
                manifest["leaves"][name]["shards"] = list(range(self.n_shards))

        hashes = []
        for i, shard in enumerate(shards):
            path = tmp / f"shard_{i}.npz"
            np.savez(path, **{k.replace("/", "|"): v for k, v in shard.items()})
            hashes.append(hashlib.sha256(path.read_bytes()).hexdigest())
        manifest["hashes"] = hashes
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))

        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    # -- restore ----------------------------------------------------------------
    def steps(self) -> List[int]:
        """Published (non-tmp, non-quarantined) step numbers, ascending."""
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if not p.name.endswith(".tmp")
                      and not p.name.endswith(".corrupt"))

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, target_tree: Any, step: Optional[int] = None,
                verify: bool = True, strict: bool = False,
                ) -> Tuple[Any, Dict[str, Any]]:
        """Load into the *structure* (and shardings) of ``target_tree``.

        ``target_tree`` may hold arrays or ShapeDtypeStructs; shapes must
        match the saved shapes (elastic resharding = different device
        placement of the same global array, which jax.device_put handles).

        A step whose manifest is unreadable or whose shard hashes mismatch
        is **quarantined** (directory renamed ``step_<N>.corrupt``) and the
        restore falls back to the previous published step, emitting a
        ``ckpt.quarantined`` warn_event — one corrupt snapshot must not
        brick recovery.  ``strict=True`` restores the old behavior: the
        first corrupt step raises ``IOError``.
        """
        if step is not None:
            candidates = [s for s in self.steps() if s <= step]
            if step not in candidates:
                raise FileNotFoundError(
                    f"no checkpoint for step {step} under {self.dir}")
            candidates = list(reversed(candidates))
        else:
            candidates = list(reversed(self.steps()))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")

        last_err: Optional[BaseException] = None
        for s in candidates:
            d = self.dir / f"step_{s:08d}"
            try:
                return self._load_step(d, s, target_tree, verify)
            except (IOError, OSError, ValueError, KeyError) as e:
                if strict:
                    raise
                last_err = e
                self._quarantine(d, s, e)
        raise IOError(
            f"every checkpoint under {self.dir} failed to restore; "
            f"last error: {last_err}")

    def _load_step(self, d: Path, step: int, target_tree: Any,
                   verify: bool) -> Tuple[Any, Dict[str, Any]]:
        manifest = json.loads((d / "manifest.json").read_text())

        if verify:
            for i, want in enumerate(manifest["hashes"]):
                got = hashlib.sha256((d / f"shard_{i}.npz").read_bytes()).hexdigest()
                if got != want:
                    raise IOError(f"checkpoint shard {i} hash mismatch at step {step}")

        loaded = [np.load(d / f"shard_{i}.npz") for i in range(manifest["n_shards"])]
        items, treedef = _flatten(target_tree)
        leaves = []
        for name, leaf in items:
            info = manifest["leaves"].get(name)
            if info is None:
                raise KeyError(f"checkpoint missing leaf {name}")
            key = name.replace("/", "|")
            parts = [loaded[i][key] for i in info["shards"]]
            arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
            want_shape = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"{name}: checkpoint shape {arr.shape} != target {want_shape}")
            if isinstance(leaf, jax.Array) and hasattr(leaf, "sharding"):
                arr = jax.device_put(arr, leaf.sharding)   # elastic reshard
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]

    def _quarantine(self, d: Path, step: int, error: BaseException) -> None:
        from ..obs.trace import get_tracer, warn_event

        corrupt = d.with_name(d.name + ".corrupt")
        if corrupt.exists():
            shutil.rmtree(corrupt)
        if d.exists():
            os.rename(d, corrupt)
        get_tracer().counter("ckpt.quarantined")
        warn_event("ckpt.quarantined", step=step, path=str(corrupt),
                   error=f"{type(error).__name__}: {error}")

    def _gc(self) -> None:
        steps = sorted(p for p in self.dir.glob("step_*")
                       if not p.name.endswith(".tmp")
                       and not p.name.endswith(".corrupt"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p)
