"""Fault tolerance: retrying step runner, straggler detection, elastic hooks.

On a real pod the failure signals are XLA runtime errors (device loss,
collective timeout) and heartbeat gaps; here they surface as exceptions
from the step callable.  The runner implements the standard production
policy around them:

  * **checkpoint cadence** + restore-on-failure (bounded retries);
  * **straggler detection**: EWMA of step time via
    :class:`repro.robust.retry.StragglerDetector`; a step slower than
    ``straggler_factor``× the EWMA is logged and counted — the hook where a
    real deployment triggers pre-emptive re-sharding or backup workers;
  * **elastic resize**: on ``ElasticEvent`` the caller re-builds the mesh
    from surviving hosts and the runner restores the last checkpoint onto
    the new topology (checkpointing is placement-agnostic; see
    ``checkpoint.CheckpointManager.restore``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..robust.retry import StragglerDetector
from .checkpoint import CheckpointManager


class ElasticEvent(Exception):
    """Raised (by the platform layer) when the device set changed."""


@dataclass
class StepStats:
    step: int
    seconds: float
    straggler: bool
    loss: Optional[float] = None


@dataclass
class StepRunner:
    step_fn: Callable[..., Tuple[Any, ...]]   # (state..., batch) -> (state..., metrics)
    ckpt: CheckpointManager
    ckpt_every: int = 50
    max_retries: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    history: List[StepStats] = field(default_factory=list)
    stragglers: int = 0

    def run(self, state: Tuple[Any, ...], batches, *, start_step: int = 0,
            num_steps: int = 100,
            on_failure: Optional[Callable[[int, Exception], None]] = None):
        """Drive ``num_steps`` steps with checkpointing + retry-restore."""
        detector = StragglerDetector(factor=self.straggler_factor,
                                     alpha=self.ewma_alpha)
        step = start_step
        retries = 0
        it = iter(batches)
        while step < start_step + num_steps:
            got = next(it)
            batch_step, batch = got if isinstance(got, tuple) else (step, got)
            t0 = time.time()
            try:
                *new_state, metrics = self.step_fn(*state, batch)
            except Exception as e:  # device loss / elastic event / NaN guard
                retries += 1
                if on_failure is not None:
                    on_failure(step, e)
                if retries > self.max_retries:
                    raise
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state, extra = self.ckpt.restore(tuple(state))
                    step = int(extra.get("step", latest))
                continue
            retries = 0
            dt = time.time() - t0
            straggler = detector.observe(dt)
            if straggler:
                self.stragglers += 1
            loss = None
            if isinstance(metrics, dict) and "loss" in metrics:
                loss = float(metrics["loss"])
            self.history.append(StepStats(step, dt, straggler, loss))
            state = tuple(new_state)
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state, extra={"step": step})
        return state
