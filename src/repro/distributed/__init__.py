"""Distributed substrate: checkpointing, fault tolerance, compression."""

from .checkpoint import CheckpointManager  # noqa: F401
from .fault import StepRunner  # noqa: F401
