"""Qwen2-1.5B — dense GQA with QKV bias [arXiv:2407.10671].

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960 (swiglu), vocab 151936.
Full attention → long_500k skipped.
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-1.5b", family="dense", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936, d_head=128,
    mlp_type="swiglu", qkv_bias=True, rope_theta=1e6, dtype="bfloat16",
)

REDUCED = ModelConfig(
    arch="qwen2-1.5b-smoke", family="dense", n_layers=2, d_model=96,
    n_heads=3, n_kv_heads=1, d_ff=256, vocab=512, d_head=32,
    qkv_bias=True, dtype="float32", remat=False,
)
