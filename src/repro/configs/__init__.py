"""Architecture configs (one module per assigned arch) + shape registry."""

from importlib import import_module
from typing import Dict, List

from ..models.api import ModelConfig

_MODULES = {
    "starcoder2-15b": "starcoder2_15b",
    "glm4-9b": "glm4_9b",
    "qwen2-1.5b": "qwen2_1_5b",
    "granite-34b": "granite_34b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "zamba2-7b": "zamba2_7b",
    "whisper-base": "whisper_base",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return import_module(f".{_MODULES[arch]}", __package__).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return import_module(f".{_MODULES[arch]}", __package__).REDUCED


from .shapes import SHAPES, cell_applicable, input_specs  # noqa: E402,F401
