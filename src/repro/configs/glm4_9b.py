"""GLM4-9B — dense GQA [hf:THUDM/glm-4-9b].

40L, d_model 4096, 32 heads (GQA kv=2), d_ff 13696 (swiglu), vocab 151552,
RoPE.  Full attention → long_500k skipped.
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552, d_head=128,
    mlp_type="swiglu", rope_theta=1e4, dtype="bfloat16",
)

REDUCED = ModelConfig(
    arch="glm4-9b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, d_head=32, dtype="float32",
    remat=False,
)
