"""Qwen2-VL-7B — VLM backbone with M-RoPE [arXiv:2409.12191].

28L, d_model 3584, 28 heads (GQA kv=4), d_ff 18944 (swiglu), vocab 152064.
M-RoPE sections (16, 24, 24) over the 64 d_head/2 frequency slots; the
vision frontend is a STUB — ``input_specs`` provides patch embeddings
(B, S, D) and 3-stream positions.  Full attention → long_500k skipped.
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18944, vocab=152064, d_head=128,
    mlp_type="swiglu", mrope_sections=(16, 24, 24), rope_theta=1e6,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    arch="qwen2-vl-smoke", family="vlm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, d_head=32,
    mrope_sections=(4, 6, 6), dtype="float32", remat=False,
)
