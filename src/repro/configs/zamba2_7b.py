"""Zamba2-7B — hybrid Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

81 mamba layers, d_model 3584 (d_inner 7168, ssm_state 64), one SHARED
attention+MLP block (32H MHA, d_ff 14336) applied every 6 layers, vocab
32000.  SSM decode state is O(1) → long_500k RUNS (the shared-attention
cache at 500k is the documented cost of the hybrid).
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000, d_head=112,
    d_inner=7168, ssm_state=64, attn_every=6, ssm_chunk=64,
    rope_theta=1e4, dtype="bfloat16", sub_quadratic=True,
)

REDUCED = ModelConfig(
    arch="zamba2-smoke", family="hybrid", n_layers=5, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, d_head=32,
    d_inner=256, ssm_state=16, attn_every=2, ssm_chunk=16,
    dtype="float32", remat=False, sub_quadratic=True,
)
