"""Whisper-base — encoder-decoder, conv audio frontend STUBBED
[arXiv:2212.04356].

6L encoder + 6L decoder, d_model 512, 8 heads (MHA), d_ff 2048 (gelu),
vocab 51865.  ``input_specs`` feeds precomputed frame embeddings
(B, S, 512) — the conv frontend is a stub per the assignment.  Decode
shapes run the DECODER with cross-attention.  Full attention → long_500k
skipped.
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-base", family="encdec", n_layers=6, n_enc_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865, d_head=64,
    mlp_type="gelu", rope_theta=1e4, dtype="bfloat16",
)

REDUCED = ModelConfig(
    arch="whisper-smoke", family="encdec", n_layers=2, n_enc_layers=2,
    d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, d_head=32,
    mlp_type="gelu", dtype="float32", remat=False,
)
