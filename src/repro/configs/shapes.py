"""Assigned input shapes × per-arch input specs (ShapeDtypeStructs only).

Shapes (LM family, 4 per arch = 40 cells):
  train_4k    : seq 4096,   global_batch 256 — lowers train_step
  prefill_32k : seq 32768,  global_batch 32  — lowers prefill_step
  decode_32k  : seq 32768,  global_batch 128 — lowers serve_step (1 token)
  long_500k   : seq 524288, global_batch 1   — serve_step; sub-quadratic
                archs only (mixtral SWA / zamba2 / rwkv6); skips recorded.

``input_specs`` returns ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, never allocated (the dry-run contract).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.api import Model, ModelConfig, build_model


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch × shape) cell."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "full attention is quadratic at 500k; skipped per assignment"
    if shape_name == "prefill_32k" and cfg.family in ("hybrid", "rwkv", "encdec"):
        # these run, no skip — branch kept for clarity
        return True, ""
    return True, ""


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: Shape) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "labels": _sds((b, s), "int32"),
        "mask": _sds((b, s), "float32"),
    }
    if cfg.family == "vlm":
        specs["embeds"] = _sds((b, s, cfg.d_model), cfg.dtype)    # stub patch embeds
        specs["positions3"] = _sds((3, b, s), "int32")
    elif cfg.family == "encdec":
        specs["frames"] = _sds((b, s, cfg.d_model), cfg.dtype)    # stub conv frontend
        specs["tokens"] = _sds((b, s), "int32")
    else:
        specs["tokens"] = _sds((b, s), "int32")
    return specs


def prefill_batch_specs(cfg: ModelConfig, shape: Shape) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"frames": _sds((b, s, cfg.d_model), cfg.dtype)}
    if cfg.family == "vlm":
        return {"embeds": _sds((b, s, cfg.d_model), cfg.dtype),
                "positions3": _sds((3, b, s), "int32")}
    return {"tokens": _sds((b, s), "int32")}


def decode_state_specs(model: Model, shape: Shape) -> Any:
    """ShapeDtypeStructs of the decode state via eval_shape (no allocation)."""
    b, cap = shape.global_batch, shape.seq_len
    cfg = model.cfg
    if cfg.family == "encdec":
        # decoder self-cache + cross K/V
        l, h, d = cfg.n_layers, cfg.n_heads, cfg.d_head
        return {
            "k": _sds((l, b, cfg.n_kv_heads, cap, d), cfg.dtype),
            "v": _sds((l, b, cfg.n_kv_heads, cap, d), cfg.dtype),
            "cross_k": _sds((l, b, h, 1500, d), cfg.dtype),
            "cross_v": _sds((l, b, h, 1500, d), cfg.dtype),
            "len": _sds((), "int32"),
        }
    if cfg.window is not None:
        cap = min(cap, cfg.window)   # SWA: rotating window-bounded cache
    state = jax.eval_shape(lambda: model.init_state(b, cap))
    return state


def input_specs(cfg: ModelConfig, shape_name: str):
    """(kind, spec-pytree) for a cell — everything the step function takes
    besides params/opt_state."""
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    if shape.kind == "train":
        return "train", train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return "prefill", prefill_batch_specs(cfg, shape)
    state = decode_state_specs(model, shape)
    tokens = _sds((shape.global_batch, 1), "int32")
    return "decode", {"state": state, "tokens": tokens}
