"""RWKV6-1.6B ("Finch") — attention-free linear RNN with data-dependent
decay [arXiv:2404.05892].

24L, d_model 2048, d_ff 7168, vocab 65536.  No KV cache; decode state is
(token-shift, wkv matrix) per layer → long_500k RUNS.
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="rwkv6-1.6b", family="rwkv", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=7168, vocab=65536, d_head=64,
    dtype="bfloat16", sub_quadratic=True,
)

REDUCED = ModelConfig(
    arch="rwkv6-smoke", family="rwkv", n_layers=2, d_model=128,
    n_heads=2, n_kv_heads=2, d_ff=256, vocab=512, d_head=64,
    dtype="float32", remat=False, sub_quadratic=True,
)
