"""StarCoder2-15B — dense GQA code model [arXiv:2402.19173].

40L, d_model 6144, 48 heads (GQA kv=4), d_ff 24576 (4x, gelu), vocab 49152,
RoPE.  Full attention → long_500k skipped (DESIGN.md §Arch-applicability).
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=4, d_ff=24576, vocab=49152, d_head=128,
    mlp_type="gelu", rope_theta=1e5, dtype="bfloat16",
)

REDUCED = ModelConfig(
    arch="starcoder2-15b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=512, vocab=512, d_head=32,
    mlp_type="gelu", dtype="float32", remat=False,
)
