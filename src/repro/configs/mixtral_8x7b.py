"""Mixtral-8x7B — MoE 8 experts top-2 with sliding-window attention
[arXiv:2401.04088].

32L, d_model 4096, 32 heads (GQA kv=8), expert d_ff 14336, vocab 32000,
SWA window 4096.  SWA makes decode cache window-bounded → long_500k RUNS.
8 experts < 16-way model axis → EP impossible; falls back to TP over the
expert d_ff (sharding.py rule).
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000, d_head=128,
    n_experts=8, top_k=2, window=4096, rope_theta=1e6, dtype="bfloat16",
    sub_quadratic=True,
)

REDUCED = ModelConfig(
    arch="mixtral-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, d_head=32,
    n_experts=4, top_k=2, window=32, dtype="float32", remat=False,
    sub_quadratic=True, moe_capacity_factor=8.0,  # drop-free at smoke scale
)
