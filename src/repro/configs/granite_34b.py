"""Granite-34B-Code — dense MQA (kv=1), 88 layers [arXiv:2405.04324].

d_model 6144, 48 heads, d_ff 24576 (4x gelu, GPTBigCode lineage), vocab
49152.  The 88-layer depth is the scan-over-layers stress test.  Full
attention → long_500k skipped.
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="granite-34b", family="dense", n_layers=88, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152, d_head=128,
    mlp_type="gelu", rope_theta=1e4, dtype="bfloat16",
)

REDUCED = ModelConfig(
    arch="granite-34b-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=4, n_kv_heads=1, d_ff=512, vocab=512, d_head=32,
    mlp_type="gelu", dtype="float32", remat=False,
)
