"""Moonlight-16B-A3B (moonshot) — MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].

48L, d_model 2048, 16 heads (kv=16, MHA), expert d_ff 1408, vocab 163840.
Expert parallelism: 64 experts over the 16-way model axis (4/device).
Full attention → long_500k skipped.  (Shared-expert and dense-first-layer
details of the HF checkpoint are simplified to a uniform MoE stack; see
DESIGN.md.)
"""
from ..models.api import ModelConfig

CONFIG = ModelConfig(
    arch="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840, d_head=128,
    n_experts=64, top_k=6, rope_theta=5e4, dtype="bfloat16",
)

REDUCED = ModelConfig(
    arch="moonshot-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=64, vocab=512, d_head=32,
    n_experts=8, top_k=2, dtype="float32", remat=False, moe_capacity_factor=8.0,
)
