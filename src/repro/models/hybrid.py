"""Zamba2-style hybrid: Mamba2 backbone + periodically applied *shared*
attention block (one set of attention+MLP weights reused at every
application point — the Zamba trick that buys attention quality at ~1/k the
parameter cost).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm


def init_hybrid(cfg, key) -> Dict[str, Any]:
    dt = cfg.param_dtype
    kemb, km, ka, kmlp, kfin = L.split_keys(key, 5)
    p: Dict[str, Any] = {
        "emb": L.dense_init(kemb, (cfg.vocab, cfg.d_model), scale=0.02, dtype=dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        # one SHARED attention + MLP block
        "shared_attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.d_head, dtype=dt),
        "shared_attn_norm": jnp.ones((cfg.d_model,), dt),
        "shared_mlp": L.init_mlp(kmlp, cfg.d_model, cfg.d_ff, "swiglu", dtype=dt),
        "shared_mlp_norm": jnp.ones((cfg.d_model,), dt),
    }
    mkeys = jax.random.split(km, cfg.n_layers)

    def one(k):
        return {
            "mamba": ssm.init_mamba2(k, cfg.d_model, cfg.d_inner, cfg.ssm_state, dtype=dt),
            "norm": jnp.ones((cfg.d_model,), dt),
        }

    p["layers"] = jax.vmap(one)(jnp.stack(mkeys))
    return p


def _shared_block(p, cfg, x, positions):
    h = x + L.attention_block(
        p["shared_attn"], L.rmsnorm(x, p["shared_attn_norm"]), positions,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
        causal=True, rope_theta=cfg.rope_theta, attn_mode=cfg.attn_mode,
        attn_unroll=cfg.scan_unroll)
    return h + L.mlp_block(p["shared_mlp"], L.rmsnorm(h, p["shared_mlp_norm"]), "swiglu")


def backbone(params, cfg, x, positions):
    """Mamba scan with a shared attention block every ``attn_every`` layers."""

    def body(carry, inp):
        x, idx = carry
        lp = inp

        def with_attn(x):
            return _shared_block(params, cfg, x, positions)

        x = jax.lax.cond(idx % cfg.attn_every == 0, with_attn, lambda x: x, x)
        y, _ = ssm.mamba2_block(lp["mamba"], L.rmsnorm(x, lp["norm"]),
                                d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                                chunk=cfg.ssm_chunk)
        return (x + y, idx + 1), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.int32)), params["layers"],
                             unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return L.rmsnorm(x, params["final_norm"])


def lm_loss(params, cfg, batch):
    from .lm import chunked_ce_loss

    x = params["emb"][batch["tokens"]]
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    xf = backbone(params, cfg, x, positions)
    return chunked_ce_loss(params, cfg, xf, batch["labels"], batch["mask"],
                           chunk=cfg.loss_chunk)


# ---------------------------------------------------------------------------
# serving: recurrent decode state + shared-attn KV cache
# ---------------------------------------------------------------------------


def _shared_kv(params, cfg, x, positions):
    """K/V of the shared attention block for the prefill cache."""
    xn = L.rmsnorm(x, params["shared_attn_norm"])
    _, k, v = L._qkv(params["shared_attn"], xn, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    if cfg.rope_theta > 0:
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def prefill(params, cfg, tokens, cache_capacity: int):
    """Prompt pass building the full decode state: per-layer mamba states +
    one KV cache per shared-attention application point."""
    x = params["emb"][tokens]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ae = cfg.attn_every
    full = cfg.n_layers // ae
    rem = cfg.n_layers - full * ae

    def regroup(t):
        return jax.tree_util.tree_map(
            lambda a: a[: full * ae].reshape((full, ae) + a.shape[1:]), t)

    def mamba_scan(x, gp):
        def one(xc, lp):
            y, st = ssm.mamba2_block(lp["mamba"], L.rmsnorm(xc, lp["norm"]),
                                     d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                                     chunk=cfg.ssm_chunk)
            return xc + y, st
        return jax.lax.scan(one, x, gp)

    def group(x, gp):
        k, v = _shared_kv(params, cfg, x, positions)
        x = _shared_block(params, cfg, x, positions)
        x, (convs, ssms) = mamba_scan(x, gp)
        return x, (k, v, convs, ssms)

    grouped = regroup(params["layers"])
    x, (ks, vs, convs, ssms) = jax.lax.scan(group, x, grouped)
    convs = convs.reshape((full * ae,) + convs.shape[2:])
    ssms = ssms.reshape((full * ae,) + ssms.shape[2:])

    if rem:
        tail = jax.tree_util.tree_map(lambda a: a[full * ae:], params["layers"])
        tk, tv = _shared_kv(params, cfg, x, positions)
        x = _shared_block(params, cfg, x, positions)
        x, (tc, ts) = mamba_scan(x, tail)
        ks = jnp.concatenate([ks, tk[None]])
        vs = jnp.concatenate([vs, tv[None]])
        convs = jnp.concatenate([convs, tc])
        ssms = jnp.concatenate([ssms, ts])

    pad = cache_capacity - s
    if pad > 0:
        ks = jnp.concatenate(
            [ks, jnp.zeros(ks.shape[:3] + (pad,) + ks.shape[4:], ks.dtype)], axis=3)
        vs = jnp.concatenate(
            [vs, jnp.zeros(vs.shape[:3] + (pad,) + vs.shape[4:], vs.dtype)], axis=3)

    xf = L.rmsnorm(x, params["final_norm"])
    logits = xf[:, -1].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    state = {"conv": convs, "ssm": ssms, "k": ks, "v": vs,
             "len": jnp.asarray(s, jnp.int32)}
    return logits, state


def init_decode_state(params, cfg, batch_size: int, cache_capacity: int):
    h = cfg.d_inner // 64
    return {
        "conv": jnp.zeros((cfg.n_layers, batch_size, 3, cfg.d_inner), cfg.param_dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch_size, h, cfg.ssm_state, 64), jnp.float32),
        # one KV cache per shared-attention application point
        "k": jnp.zeros((cfg.n_attn_points, batch_size, cfg.n_kv_heads,
                        cache_capacity, cfg.d_head), cfg.param_dtype),
        "v": jnp.zeros((cfg.n_attn_points, batch_size, cfg.n_kv_heads,
                        cache_capacity, cfg.d_head), cfg.param_dtype),
        "len": jnp.asarray(0, jnp.int32),
    }


def _decode_attn(params, cfg, x, ck, cv, clen):
    xn = L.rmsnorm(x, params["shared_attn_norm"])
    att, nk, nv = L.decode_attention_block(
        params["shared_attn"], xn, ck, cv, clen,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
        rope_theta=cfg.rope_theta)
    x = x + att
    x = x + L.mlp_block(params["shared_mlp"],
                        L.rmsnorm(x, params["shared_mlp_norm"]), "swiglu")
    return x, nk, nv


def _decode_mamba_scan(cfg, x, layer_params, conv_s, ssm_s):
    def one(xc, inp):
        lp, cs, ss = inp
        y, (nc, ns) = ssm.mamba2_decode(lp["mamba"], L.rmsnorm(xc, lp["norm"]),
                                        (cs, ss), d_inner=cfg.d_inner,
                                        ssm_state=cfg.ssm_state)
        return xc + y, (nc, ns)

    return jax.lax.scan(one, x, (layer_params, conv_s, ssm_s))


def decode_step(params, cfg, state, tokens):
    """One-token decode: scan over (shared-attn + mamba-group) super-blocks
    so the HLO stays O(1) in depth; the trailing partial group is unrolled.
    """
    x = params["emb"][tokens]
    clen = state["len"]
    ae = cfg.attn_every
    full = cfg.n_layers // ae
    rem = cfg.n_layers - full * ae

    def regroup(a):
        head = a[: full * ae].reshape((full, ae) + a.shape[1:])
        return head

    grouped = jax.tree_util.tree_map(regroup, params["layers"])
    conv_g = regroup(state["conv"])
    ssm_g = regroup(state["ssm"])

    def group(x, inp):
        gp, ck, cv, cs, ss = inp
        x, nk, nv = _decode_attn(params, cfg, x, ck, cv, clen)
        x, (ncs, nss) = _decode_mamba_scan(cfg, x, gp, cs, ss)
        return x, (nk, nv, ncs, nss)

    x, (nk, nv, nconv, nssm) = jax.lax.scan(
        group, x, (grouped, state["k"][:full], state["v"][:full], conv_g, ssm_g))
    nconv = nconv.reshape((full * ae,) + nconv.shape[2:])
    nssm = nssm.reshape((full * ae,) + nssm.shape[2:])

    if rem:  # trailing partial group: one more shared-attn point + rem mambas
        tail = jax.tree_util.tree_map(lambda a: a[full * ae:], params["layers"])
        x, tk, tv = _decode_attn(params, cfg, x, state["k"][full], state["v"][full], clen)
        x, (tc, ts) = _decode_mamba_scan(cfg, x, tail,
                                         state["conv"][full * ae:], state["ssm"][full * ae:])
        nk = jnp.concatenate([nk, tk[None]])
        nv = jnp.concatenate([nv, tv[None]])
        nconv = jnp.concatenate([nconv, tc])
        nssm = jnp.concatenate([nssm, ts])

    xf = L.rmsnorm(x, params["final_norm"])
    logits = xf[:, -1].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    new_state = {"conv": nconv, "ssm": nssm, "k": nk, "v": nv, "len": clen + 1}
    return logits, new_state
