"""Sharding rules: parameter/batch/cache PartitionSpecs per mesh.

This is the SPMD backend's decision table — what the CVM parallelization
rewrite decides abstractly (Split over "data", weight-Split over "model",
pre-aggregation = psum) is realized here as GSPMD PartitionSpecs:

  * TP (Megatron): attention qkv column-split / wo row-split; MLP in/out;
    embeddings vocab-split (loss logsumexp becomes a model-axis all-reduce);
  * EP: expert dim over "model" when divisible, else TP over expert d_ff;
  * DP: batch over ("pod", "data");
  * SP (decode): sequence-split KV caches when batch or heads can't fill
    the mesh (long-context decode);
  * ZeRO-1: optimizer moments additionally sharded over "data".

Every rule checks divisibility and falls back to replication — dry-run
proves the final table compiles for all 40 (arch × shape) cells.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh: Mesh) -> int:
    out = 1
    for a in _dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def _shard_dim(shape: Tuple[int, ...], dim: int, size: int) -> bool:
    return len(shape) > 0 and shape[dim] % size == 0 and shape[dim] >= size


# name-keyed rules: (which dim to shard over "model") given the leaf name
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "cm_k", "in_proj", "wr", "wg", "w1"}
_ROW = {"wo", "w_down", "cm_v", "out_proj", "cm_r", "wvv"}


def param_spec(path: str, leaf: jax.Array, mesh: Mesh, zero1_axis: Optional[str] = None) -> P:
    m = _axis_size(mesh, "model")
    shape = leaf.shape
    rank = len(shape)
    name = path.split("/")[-1]
    spec = [None] * rank

    if name == "emb" and _shard_dim(shape, 0, m):
        spec[0] = "model"                      # vocab-sharded embedding
    elif name in ("router", "conv_w", "A_log", "D", "dt_bias", "mu", "u", "w0",
                  "cm_mu", "w2"):
        pass                                    # replicated (small)
    elif "moe" in path and name in ("w_gate", "w_up", "w_down") and rank >= 3:
        e_dim = rank - 3                        # (L, E, D, F) or (E, D, F)
        if _shard_dim(shape, e_dim, m):
            spec[e_dim] = "model"               # expert parallelism
        elif name in ("w_gate", "w_up") and _shard_dim(shape, rank - 1, m):
            spec[rank - 1] = "model"            # fall back to TP over d_ff
        elif name == "w_down" and _shard_dim(shape, rank - 2, m):
            spec[rank - 2] = "model"
    elif name in _COL and rank >= 2 and _shard_dim(shape, rank - 1, m):
        spec[rank - 1] = "model"
    elif name in _ROW and rank >= 2 and _shard_dim(shape, rank - 2, m):
        spec[rank - 2] = "model"
    elif name == "wk" or name == "wv":
        pass                                    # small kv that didn't divide → replicate

    if zero1_axis is not None:
        z = _axis_size(mesh, zero1_axis)
        for d in range(rank - 1, -1, -1):       # prefer trailing (largest) dims
            if spec[d] is None and shape[d] % (z) == 0 and shape[d] >= z:
                spec[d] = zero1_axis
                break
    return P(*spec)


def _tree_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else k, v)
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def tree_param_specs(params, mesh: Mesh, zero1: bool = False):
    """Pytree of PartitionSpecs mirroring ``params``."""

    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else k, v) for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            t = type(node)
            return t(rec(f"{prefix}/{i}", v) for i, v in enumerate(node))
        return param_spec(prefix, node, mesh, zero1_axis=None)

    return rec("", params)


def tree_opt_specs(opt_state, params_specs, mesh: Mesh, zero1: bool = True):
    """Moments follow the weight specs; ZeRO-1 adds a "data" shard when it fits."""

    def add_zero1(spec: P, leaf: jax.Array) -> P:
        if not zero1:
            return spec
        z = _dp_size(mesh)
        if z <= 1:
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for d in range(len(leaf.shape) - 1, -1, -1):
            if parts[d] is None and leaf.shape[d] % z == 0 and leaf.shape[d] >= z:
                parts[d] = _dp_axes(mesh) if len(_dp_axes(mesh)) > 1 else _dp_axes(mesh)[0]
                return P(*parts)
        return spec

    def rec(spec_node, state_node):
        if isinstance(state_node, dict):
            return {k: rec(spec_node.get(k) if isinstance(spec_node, dict) else spec_node,
                           v) for k, v in state_node.items()}
        if isinstance(state_node, (tuple, list)):
            t = type(state_node)
            return t(rec(spec_node[i] if isinstance(spec_node, (tuple, list)) else spec_node, v)
                     for i, v in enumerate(state_node))
        if hasattr(state_node, "shape") and state_node.ndim > 0 and isinstance(spec_node, P):
            return add_zero1(spec_node, state_node)
        return P()

    out = {}
    for key in opt_state:
        if key in ("m", "v", "mom"):
            out[key] = rec(params_specs, opt_state[key])
        else:
            out[key] = P()
    return out


def tree_grad_specs(params_shapes, param_specs, mesh: Mesh):
    """ZeRO-2-style specs for the f32 gradient accumulator: weight specs
    plus a data-axis shard on the largest free dim (same rule as ZeRO-1)."""
    z = _dp_size(mesh)
    dp = _dp_axes(mesh)

    def one(spec: P, leaf) -> P:
        if z <= 1:
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for d in range(len(leaf.shape) - 1, -1, -1):
            if parts[d] is None and leaf.shape[d] % z == 0 and leaf.shape[d] >= z:
                parts[d] = dp if len(dp) > 1 else dp[0]
                return P(*parts)
        return spec

    return jax.tree_util.tree_map(
        one, param_specs, params_shapes,
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_shapes: Dict[str, Tuple[Tuple[int, ...], Any]], mesh: Mesh):
    """Specs for a training/serving batch: shard dim 0 (batch) over DP axes,
    falling back to sequence sharding (dim 1) for batch-1 long-context."""
    dp = _dp_axes(mesh)
    n = _dp_size(mesh)
    out = {}
    for name, (shape, _) in batch_shapes.items():
        spec = [None] * len(shape)
        bdim = 1 if name == "positions3" else 0
        if len(shape) > bdim and shape[bdim] % n == 0 and shape[bdim] >= n:
            spec[bdim] = dp if len(dp) > 1 else dp[0]
        elif len(shape) > bdim + 1 and shape[bdim + 1] % n == 0:
            spec[bdim + 1] = dp if len(dp) > 1 else dp[0]   # sequence sharding
        out[name] = P(*spec)
    return out


def cache_specs(cache_shapes, mesh: Mesh, cfg) -> Any:
    """KV-cache/state sharding for decode.

    Preference order per leaf (L, B, H, S, D)-like: batch over DP;
    heads over "model" when divisible; otherwise sequence over "model"
    (flash-decoding style split — GSPMD inserts the LSE-combine collectives).
    """
    m = _axis_size(mesh, "model")
    dp = _dp_axes(mesh)
    n = _dp_size(mesh)

    def spec_for(path: str, shape, dtype) -> P:
        rank = len(shape)
        spec = [None] * rank
        if rank == 0:
            return P()
        # find batch dim: first dim whose size matches the batch heuristic —
        # caches are stacked (L, B, ...): dim 1 is batch
        bdim = 1 if rank >= 2 else 0
        if shape[bdim] % n == 0 and shape[bdim] >= n:
            spec[bdim] = dp if len(dp) > 1 else dp[0]
        if rank >= 5:
            hdim, sdim = 2, 3                   # (L, B, H, S, D)
            if shape[hdim] % m == 0 and shape[hdim] >= m:
                spec[hdim] = "model"
            elif shape[sdim] % m == 0 and shape[sdim] >= m:
                spec[sdim] = "model"            # sequence-sharded cache
        elif rank == 4:                          # e.g. conv state (L, B, K, Di)
            if shape[3] % m == 0 and shape[3] >= m:
                spec[3] = "model"
        return P(*spec)

    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}" if prefix else k, v) for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            t = type(node)
            return t(rec(f"{prefix}/{i}", v) for i, v in enumerate(node))
        return spec_for(prefix, node.shape, node.dtype)

    return rec("", cache_shapes)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
