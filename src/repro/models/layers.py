"""Shared layers: norms, RoPE/M-RoPE, GQA attention, MLPs, MoE, init.

Everything is a pure function over explicit parameter pytrees (dicts of
arrays) — no module framework, so pjit sees a flat, spec-addressable tree.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, sections: Sequence[int],
                theta: float = 10000.0) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): 3 position streams (t, h, w).

    x: (B, H, S, D); positions3: (3, B, S).  ``sections`` partitions the D/2
    frequency slots among the three streams (sum(sections) == D/2).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (D/2,)
    # choose a position stream for each frequency slot
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=d // 2)  # (D/2,)
    pos = positions3.astype(jnp.float32)                          # (3, B, S)
    pos_per_slot = pos[sec_id]                                    # (D/2, B, S)
    angles = jnp.transpose(pos_per_slot, (1, 2, 0))[:, None, :, :] * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional SWA / M-RoPE / cross)
# ---------------------------------------------------------------------------


def init_attention(key, d_model, n_heads, n_kv, d_head, qkv_bias=False, dtype=jnp.float32):
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * d_head), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, n_kv * d_head), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, n_kv * d_head), dtype=dtype),
        "wo": dense_init(ks[3], (n_heads * d_head, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv * d_head,), dtype)
    return p


def _qkv(p, x, n_heads, n_kv, d_head):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, n_kv, d_head).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, n_kv, d_head).transpose(0, 2, 1, 3)
    return q, k, v


def attention_block(p: Params, x: jax.Array, positions: jax.Array, *,
                    n_heads: int, n_kv: int, d_head: int,
                    causal: bool = True, window: Optional[int] = None,
                    rope_theta: float = 10000.0,
                    mrope_sections: Optional[Sequence[int]] = None,
                    positions3: Optional[jax.Array] = None,
                    attn_mode: str = "chunked",
                    attn_unroll: bool = False) -> jax.Array:
    b, s, d_model = x.shape
    q, k, v = _qkv(p, x, n_heads, n_kv, d_head)
    if mrope_sections is not None:
        q = apply_mrope(q, positions3, mrope_sections, rope_theta)
        k = apply_mrope(k, positions3, mrope_sections, rope_theta)
    elif rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    o = kops.attention(q, k, v, causal=causal, window=window, mode=attn_mode,
                       unroll=attn_unroll)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_head)
    return o @ p["wo"]


def decode_attention_block(p: Params, x: jax.Array, cache_k, cache_v, cache_len, *,
                           n_heads: int, n_kv: int, d_head: int,
                           window: Optional[int] = None,
                           rope_theta: float = 10000.0,
                           mrope_sections: Optional[Sequence[int]] = None,
                           positions3: Optional[jax.Array] = None):
    """One-token decode: returns (out, new_k_cache, new_v_cache)."""
    b, one, _ = x.shape
    cap = cache_k.shape[2]
    q, k, v = _qkv(p, x, n_heads, n_kv, d_head)
    pos = jnp.broadcast_to(jnp.asarray(cache_len)[None, None], (b, 1)).astype(jnp.int32)
    if mrope_sections is not None:
        q = apply_mrope(q, positions3, mrope_sections, rope_theta)
        k = apply_mrope(k, positions3, mrope_sections, rope_theta)
    elif rope_theta > 0:
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    # rotating write for window-bounded (SWA) caches; plain append otherwise.
    # Mask-based write instead of dynamic_update_slice: a dus at a dynamic
    # position on a *sequence-sharded* cache makes GSPMD gather the whole
    # cache per layer; the where() is elementwise → fully shard-local
    # (EXPERIMENTS §Perf iteration 4).
    write_pos = jnp.remainder(cache_len, cap)
    sel = (jax.lax.broadcasted_iota(jnp.int32, (cap,), 0) == write_pos)[None, None, :, None]
    new_k = jnp.where(sel, k.astype(cache_k.dtype), cache_k)
    new_v = jnp.where(sel, v.astype(cache_v.dtype), cache_v)
    valid_len = jnp.minimum(cache_len + 1, cap)
    o = kops.decode_attention(q, new_k, new_v, valid_len)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, n_heads * d_head)
    return o @ p["wo"], new_k, new_v


def cross_attention_block(p: Params, x: jax.Array, enc_k, enc_v, *,
                          n_heads: int, n_kv: int, d_head: int) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    o = kops.attention(q, enc_k, enc_v, causal=False, mode="chunked")
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_head)
    return o @ p["wo"]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, mlp_type="swiglu", dtype=jnp.float32):
    ks = split_keys(key, 3)
    if mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def mlp_block(p: Params, x: jax.Array, mlp_type="swiglu") -> jax.Array:
    if mlp_type == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (capacity-based index dispatch — static shapes, MXU-dense expert GEMMs)
# ---------------------------------------------------------------------------


def init_moe(key, d_model, d_ff, n_experts, dtype=jnp.float32):
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, n_experts), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (n_experts, d_model, d_ff), dtype=dtype),
        "w_up": dense_init(ks[2], (n_experts, d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[3], (n_experts, d_ff, d_model), dtype=dtype),
    }


def moe_block(p: Params, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Top-k capacity MoE.  x: (B, S, D) → (out, aux_loss).

    Sort-free index dispatch: per (token, k) choice compute its position
    within the chosen expert via a stable argsort of expert ids; tokens past
    capacity are dropped (standard Switch/GShard semantics).  Expert compute
    is stacked dense GEMMs (E, C, D)×(E, D, F) — shardable over E (EP) or F
    (TP) by pjit.
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf @ p["router"]).astype(jnp.float32)             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, top_k)                    # (T, K)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    capacity = max(int(t * top_k / n_experts * capacity_factor), 4)
    flat_e = topi.reshape(-1)                                   # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(n_experts))   # (E,)
    pos_sorted = jnp.arange(t * top_k) - start[sorted_e]
    pos = jnp.zeros((t * top_k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < capacity                                       # (T*K,)
    slot = jnp.where(keep, flat_e * capacity + pos, n_experts * capacity)  # overflow slot

    tok_idx = jnp.repeat(jnp.arange(t), top_k)                  # (T*K,)
    gathered = xf[tok_idx] * keep[:, None].astype(xf.dtype)     # (T*K, D)
    expert_in = jnp.zeros((n_experts * capacity + 1, d), xf.dtype).at[slot].add(gathered)
    expert_in = expert_in[:-1].reshape(n_experts, capacity, d)

    # NOTE (EXPERIMENTS §Perf iteration 7, refuted): GSPMD replicates these
    # scatter-produced dispatch buffers (106 GiB/dev on mixtral prefill_32k).
    # Pinning them with sharding constraints made things WORSE (train 13.6 →
    # 30.4 GiB: the partitioner inserts full-remat copies to satisfy the
    # constraint against the F-sharded expert weights).  The correct fix is
    # an all-to-all expert-parallel dispatch (GShard-style), which
    # restructures this block — recorded as the top next-step candidate.
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])          # (E, C, D)

    out_flat = jnp.concatenate(
        [out_e.reshape(n_experts * capacity, d), jnp.zeros((1, d), out_e.dtype)])
    y = out_flat[slot] * (topw.reshape(-1)[:, None] * keep[:, None]).astype(out_e.dtype)
    y = jax.ops.segment_sum(y, tok_idx, num_segments=t)

    # load-balancing aux loss (Switch): E * Σ_e f_e · P_e
    me = jnp.mean(jax.nn.one_hot(topi[:, 0], n_experts), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(me * ce)
    return y.reshape(b, s, d), aux
