"""Whisper-style encoder-decoder backbone (conv audio frontend stubbed).

Per the assignment, the modality frontend is a stub: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, D) straight into the encoder.  The
decoder is a standard causal stack with cross-attention; serving precomputes
cross K/V once at prefill.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L


def init_encdec(cfg, key) -> Dict[str, Any]:
    dt = cfg.param_dtype
    kemb, kenc, kdec = L.split_keys(key, 3)
    p: Dict[str, Any] = {
        "emb": L.dense_init(kemb, (cfg.vocab, cfg.d_model), scale=0.02, dtype=dt),
        "enc_final_norm": jnp.ones((cfg.d_model,), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }

    def enc_layer(k):
        ka, km = jax.random.split(k)
        return {
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.d_head, dtype=dt),
            "attn_norm": jnp.ones((cfg.d_model,), dt),
            "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, "gelu", dtype=dt),
            "mlp_norm": jnp.ones((cfg.d_model,), dt),
        }

    def dec_layer(k):
        ka, kc, km = L.split_keys(k, 3)
        return {
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.d_head, dtype=dt),
            "attn_norm": jnp.ones((cfg.d_model,), dt),
            "cross": L.init_attention(kc, cfg.d_model, cfg.n_heads, cfg.n_heads,
                                      cfg.d_head, dtype=dt),
            "cross_norm": jnp.ones((cfg.d_model,), dt),
            "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, "gelu", dtype=dt),
            "mlp_norm": jnp.ones((cfg.d_model,), dt),
        }

    p["enc_layers"] = jax.vmap(enc_layer)(jnp.stack(jax.random.split(kenc, cfg.n_enc_layers)))
    p["dec_layers"] = jax.vmap(dec_layer)(jnp.stack(jax.random.split(kdec, cfg.n_layers)))
    return p


def encode(params, cfg, frames):
    """frames: (B, S_enc, D) stubbed conv-frontend output → encoder states."""
    x = frames.astype(cfg.param_dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, lp):
        h = x + L.attention_block(
            lp["attn"], L.rmsnorm(x, lp["attn_norm"]), positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
            causal=False, rope_theta=cfg.rope_theta, attn_mode=cfg.attn_mode,
            attn_unroll=cfg.scan_unroll)
        return h + L.mlp_block(lp["mlp"], L.rmsnorm(h, lp["mlp_norm"]), "gelu"), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=cfg.n_enc_layers if cfg.scan_unroll else 1)
    return L.rmsnorm(x, params["enc_final_norm"])


def _cross_kv(lp, enc, n_heads, d_head):
    b, s, _ = enc.shape
    k = (enc @ lp["cross"]["wk"]).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    v = (enc @ lp["cross"]["wv"]).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    return k, v


def decode_train(params, cfg, enc, tokens):
    """Teacher-forced decoder forward → final hidden states (B, S_dec, D)."""
    x = params["emb"][tokens]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, lp):
        h = x + L.attention_block(
            lp["attn"], L.rmsnorm(x, lp["attn_norm"]), positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
            causal=True, rope_theta=cfg.rope_theta, attn_mode=cfg.attn_mode,
            attn_unroll=cfg.scan_unroll)
        ck, cv = _cross_kv(lp, enc, cfg.n_heads, cfg.d_head)
        h = h + L.cross_attention_block(lp["cross"], L.rmsnorm(h, lp["cross_norm"]),
                                        ck, cv, n_heads=cfg.n_heads,
                                        n_kv=cfg.n_heads, d_head=cfg.d_head)
        return h + L.mlp_block(lp["mlp"], L.rmsnorm(h, lp["mlp_norm"]), "gelu"), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"],
                        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return L.rmsnorm(x, params["final_norm"])


def encdec_loss(params, cfg, batch):
    from .lm import chunked_ce_loss

    enc = encode(params, cfg, batch["frames"])
    xf = decode_train(params, cfg, enc, batch["tokens"])
    return chunked_ce_loss(params, cfg, xf, batch["labels"], batch["mask"],
                           chunk=cfg.loss_chunk)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params, cfg, frames, cache_capacity: int):
    """Encode audio; build (empty) decoder self-cache + cross K/V."""
    enc = encode(params, cfg, frames)

    def per_layer(lp):
        return _cross_kv(lp, enc, cfg.n_heads, cfg.d_head)

    cross_k, cross_v = jax.vmap(per_layer)(params["dec_layers"])   # (L,B,H,S,Dh)
    b = frames.shape[0]
    shape = (cfg.n_layers, b, cfg.n_kv_heads, cache_capacity, cfg.d_head)
    cache = {
        "k": jnp.zeros(shape, cfg.param_dtype),
        "v": jnp.zeros(shape, cfg.param_dtype),
        "cross_k": cross_k, "cross_v": cross_v,
        "len": jnp.asarray(0, jnp.int32),
    }
    return cache


def decode_step(params, cfg, cache, tokens):
    x = params["emb"][tokens]
    clen = cache["len"]

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        xn = L.rmsnorm(x, lp["attn_norm"])
        att, nk, nv = L.decode_attention_block(
            lp["attn"], xn, ck, cv, clen,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
            rope_theta=cfg.rope_theta)
        h = x + att
        h = h + L.cross_attention_block(lp["cross"], L.rmsnorm(h, lp["cross_norm"]),
                                        xk, xv, n_heads=cfg.n_heads,
                                        n_kv=cfg.n_heads, d_head=cfg.d_head)
        h = h + L.mlp_block(lp["mlp"], L.rmsnorm(h, lp["mlp_norm"]), "gelu")
        return h, (nk, nv)

    x, (nks, nvs) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = L.rmsnorm(x, params["final_norm"])
    logits = x[:, -1].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    new_cache = dict(cache, k=nks, v=nvs, len=clen + 1)
    return logits, new_cache
