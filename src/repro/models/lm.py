"""Decoder-only transformer LM (dense / MoE / SWA / M-RoPE variants).

Design invariants:
  * scan-over-layers with stacked params — HLO size is O(1) in depth;
  * remat around each layer (configurable policy);
  * the LM loss is computed in sequence chunks so the (B, S, V) logits are
    never materialized (vocab can be 152k) — with vocab-sharded embeddings
    GSPMD turns the per-chunk logsumexp into a model-axis all-reduce;
  * decode carries a (L, B, Hkv, S, D) KV cache, updated functionally.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L


def init_lm(cfg, key) -> Dict[str, Any]:
    kemb, klay, kfin = L.split_keys(key, 3)
    dt = cfg.param_dtype
    p: Dict[str, Any] = {
        "emb": L.dense_init(kemb, (cfg.vocab, cfg.d_model), scale=0.02, dtype=dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    lkeys = jax.random.split(klay, cfg.n_layers)

    def one_layer(k):
        ka, km = jax.random.split(k)
        lp = {
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.d_head, cfg.qkv_bias, dtype=dt),
            "attn_norm": jnp.ones((cfg.d_model,), dt),
            "mlp_norm": jnp.ones((cfg.d_model,), dt),
        }
        if cfg.is_moe:
            lp["moe"] = L.init_moe(km, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype=dt)
        else:
            lp["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype=dt)
        return lp

    p["layers"] = jax.vmap(one_layer)(jnp.stack(lkeys))
    return p


def _layer_fwd(cfg, lp, x, positions, positions3):
    h = x + L.attention_block(
        lp["attn"], L.rmsnorm(x, lp["attn_norm"]), positions,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
        causal=cfg.causal, window=cfg.window, rope_theta=cfg.rope_theta,
        mrope_sections=cfg.mrope_sections, positions3=positions3,
        attn_mode=cfg.attn_mode, attn_unroll=cfg.scan_unroll,
    )
    z = L.rmsnorm(h, lp["mlp_norm"])
    if cfg.is_moe:
        y, aux = L.moe_block(lp["moe"], z, n_experts=cfg.n_experts, top_k=cfg.top_k,
                             capacity_factor=cfg.moe_capacity_factor)
    else:
        y, aux = L.mlp_block(lp["mlp"], z, cfg.mlp_type), jnp.zeros((), jnp.float32)
    return h + y, aux


def backbone(params, cfg, x, positions, positions3=None):
    """Run all layers (scan + remat). x: (B, S, D) → (x, aux_loss).

    ``remat_group`` > 1 checkpoints *groups* of layers (sqrt-remat): only
    L/g boundary activations are saved; within-group activations
    rematerialize transiently during backward.  Recompute FLOPs are
    unchanged (each layer is still recomputed exactly once) but saved-
    activation memory drops g× — what brings the 88-layer granite under
    the 16 GB budget (EXPERIMENTS §Perf iteration 6).
    """
    g = cfg.remat_group
    init = (x, jnp.zeros((), jnp.float32))

    def body(carry, lp):
        x, aux = carry
        x, a = _layer_fwd(cfg, lp, x, positions, positions3)
        return (x, aux + a), None

    if g > 1 and cfg.n_layers % g == 0 and not cfg.scan_unroll and cfg.remat:
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((cfg.n_layers // g, g) + a.shape[1:]),
            params["layers"])

        def group_body(carry, gp):
            c, _ = jax.lax.scan(body, carry, gp)
            return c, None

        group_body = jax.checkpoint(group_body, policy=None)
        (x, aux), _ = jax.lax.scan(group_body, init, grouped)
        return L.rmsnorm(x, params["final_norm"]), aux

    if cfg.remat:
        body = jax.checkpoint(body, policy=None)
    unroll = cfg.n_layers if cfg.scan_unroll else 1
    (x, aux), _ = jax.lax.scan(body, init, params["layers"], unroll=unroll)
    return L.rmsnorm(x, params["final_norm"]), aux


def embed(params, cfg, tokens=None, embeds=None):
    if embeds is not None:
        return embeds.astype(cfg.param_dtype)
    return params["emb"][tokens]


def forward(params, cfg, tokens=None, embeds=None, positions=None, positions3=None):
    """Full forward → logits (B, S, V). For tests/small shapes only."""
    x = embed(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, aux = backbone(params, cfg, x, positions, positions3)
    logits = (x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T)
    return logits, aux


def chunked_ce_loss(params, cfg, x_final, labels, mask, chunk: int = 512):
    """Next-token CE without materializing full logits.

    x_final: (B, S, D); labels, mask: (B, S).  lax.scan over sequence chunks,
    rematerialized so backward recomputes each chunk's logits.
    """
    b, s, d = x_final.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    emb = params["emb"].astype(jnp.float32)

    def body(carry, idx):
        tot, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x_final, idx * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, axis=1)
        logits = xs.astype(jnp.float32) @ emb.T                    # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - ll) * ms)
        cnt = cnt + jnp.sum(ms)
        return (tot, cnt), None

    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(s // chunk))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg, batch):
    """batch: {tokens|embeds, labels, mask[, positions3]} → scalar loss."""
    x = embed(params, cfg, batch.get("tokens"), batch.get("embeds"))
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    xf, aux = backbone(params, cfg, x, positions, batch.get("positions3"))
    ce = chunked_ce_loss(params, cfg, xf, batch["labels"], batch["mask"],
                         chunk=cfg.loss_chunk)
    return ce + cfg.moe_aux_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def _layer_kv(cfg, lp, x, positions, positions3=None):
    """Recompute K/V for the cache during prefill."""
    xn = L.rmsnorm(x, lp["attn_norm"])
    b, s, _ = xn.shape
    q, k, v = L._qkv(lp["attn"], xn, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    if cfg.mrope_sections is not None:
        k = L.apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope_theta > 0:
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def prefill(params, cfg, tokens=None, embeds=None, cache_capacity=None,
            positions3=None):
    """Process the prompt; returns (last-position hidden, kv cache pytree)."""
    x = embed(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    cap = cache_capacity or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope_sections is not None and positions3 is None:
        positions3 = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))

    def body(carry, lp):
        x, aux = carry
        k, v = _layer_kv(cfg, lp, x, positions, positions3)
        x, a = _layer_fwd(cfg, lp, x, positions, positions3)
        return (x, aux + a), (k, v)

    if cfg.remat:
        body = jax.checkpoint(body)
    (xf, _), (ks, vs) = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                     params["layers"],
                                     unroll=cfg.n_layers if cfg.scan_unroll else 1)
    xf = L.rmsnorm(xf, params["final_norm"])
    pad = cap - s
    if pad > 0:
        zk = jnp.zeros(ks.shape[:3] + (pad,) + ks.shape[4:], ks.dtype)
        ks = jnp.concatenate([ks, zk], axis=3)
        vs = jnp.concatenate([vs, zk], axis=3)
    cache = {"k": ks, "v": vs, "len": jnp.asarray(s, jnp.int32)}
    logits = xf[:, -1].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return logits, cache


def decode_step(params, cfg, cache, tokens):
    """One decode step. tokens: (B, 1) → (logits (B, V), new cache)."""
    x = embed(params, cfg, tokens)
    clen = cache["len"]

    def body(carry, layer):
        x = carry
        lp, ck, cv = layer
        xn = L.rmsnorm(x, lp["attn_norm"])
        att, nk, nv = L.decode_attention_block(
            lp["attn"], xn, ck, cv, clen,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
            window=cfg.window, rope_theta=cfg.rope_theta,
        )
        h = x + att
        z = L.rmsnorm(h, lp["mlp_norm"])
        if cfg.is_moe:
            y, _ = L.moe_block(lp["moe"], z, n_experts=cfg.n_experts, top_k=cfg.top_k,
                               capacity_factor=cfg.moe_capacity_factor)
        else:
            y = L.mlp_block(lp["mlp"], z, cfg.mlp_type)
        return h + y, (nk, nv)

    x, (nks, nvs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                                 unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = L.rmsnorm(x, params["final_norm"])
    logits = x[:, -1].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    new_cache = {"k": nks, "v": nvs, "len": clen + 1}
    return logits, new_cache
