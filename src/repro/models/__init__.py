"""Model zoo: the 10 assigned architectures as composable JAX modules.

All decoder stacks use scan-over-layers with stacked parameter pytrees so
the compiled HLO is O(1) in depth (critical for the 88-layer granite dry-run
and for XLA compile times).  Families:

  dense   — starcoder2-15b, glm4-9b, qwen2-1.5b, granite-34b, qwen2-vl-7b (M-RoPE)
  moe     — mixtral-8x7b (SWA), moonshot-v1-16b-a3b (64e top-6)
  hybrid  — zamba2-7b (Mamba2 + shared attention blocks)
  ssm     — rwkv6-1.6b (attention-free, data-dependent decay)
  encdec  — whisper-base (conv frontend stubbed to frame embeddings)
"""

from .api import ModelConfig, build_model  # noqa: F401
