"""Model API: config dataclass + family dispatch + step builders.

``build_model(cfg)`` returns a ``Model`` facade with uniform entry points
(init / loss / prefill / decode / state init) regardless of family; the
step builders produce the functions the launcher lowers through CVM →
pjit (train_step, prefill_step, serve_step).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..train.optimizer import AdamW, Optimizer
from . import hybrid, lm, ssm, whisper


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                      # dense | moe | hybrid | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # default: d_model // n_heads
    mlp_type: str = "swiglu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    window: Optional[int] = None     # SWA
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_aux_weight: float = 0.01
    moe_capacity_factor: float = 1.25
    # SSM / hybrid
    d_inner: int = 0
    ssm_state: int = 0
    attn_every: int = 6
    ssm_chunk: int = 64
    # enc-dec
    n_enc_layers: int = 0
    # VLM
    mrope_sections: Optional[Tuple[int, ...]] = None
    # engineering
    dtype: str = "float32"
    attn_mode: str = "chunked"
    remat: bool = True
    sub_quadratic: bool = False      # eligible for long_500k
    scan_unroll: bool = False        # unroll layer scans (roofline probes)
    loss_chunk: int = 512            # CE loss sequence-chunk size
    microbatch: int = 1              # gradient-accumulation microbatches
    remat_group: int = 1             # layers per remat unit (sqrt-remat when >1)

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_attn_points(self) -> int:
        return -(-self.n_layers // self.attn_every)

    def n_params(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = v * d
        if self.family in ("dense", "vlm", "moe"):
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d
            if self.is_moe:
                mlp = self.n_experts * 3 * d * f + d * self.n_experts
            else:
                mlp = (3 if self.mlp_type == "swiglu" else 2) * d * f
            return emb + l * (attn + mlp)
        if self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * n + di // 64) + di * d
            shared = 4 * d * d + 3 * d * f
            return emb + l * mamba + shared
        if self.family == "rwkv":
            return emb + l * (5 * d * d + 2 * d * f + d * 128)
        if self.family == "encdec":
            per = 4 * d * self.n_heads * self.d_head + 2 * d * f
            return emb + (self.n_enc_layers + l) * per + l * 4 * d * d
        raise ValueError(self.family)

    def n_active_params(self) -> int:
        if not self.is_moe:
            return self.n_params()
        d, f, l = self.d_model, self.d_ff, self.n_layers
        attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
            + self.n_heads * self.d_head * d
        mlp = self.top_k * 3 * d * f
        return self.vocab * d + l * (attn + mlp)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, Dict[str, jax.Array]], jax.Array]
    prefill: Optional[Callable] = None
    decode: Optional[Callable] = None
    init_state: Optional[Callable] = None  # (params_or_none, batch, cap) → decode state


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init=lambda key: lm.init_lm(cfg, key),
            loss=lambda p, b: lm.lm_loss(p, cfg, b),
            prefill=lambda p, b, cap: lm.prefill(
                p, cfg, tokens=b.get("tokens"), embeds=b.get("embeds"),
                cache_capacity=cap, positions3=b.get("positions3")),
            decode=lambda p, cache, toks: lm.decode_step(p, cfg, cache, toks),
            init_state=lambda bsz, cap: {
                "k": jnp.zeros((cfg.n_layers, bsz, cfg.n_kv_heads, cap, cfg.d_head),
                               cfg.param_dtype),
                "v": jnp.zeros((cfg.n_layers, bsz, cfg.n_kv_heads, cap, cfg.d_head),
                               cfg.param_dtype),
                "len": jnp.zeros((), jnp.int32),
            },
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: hybrid.init_hybrid(cfg, key),
            loss=lambda p, b: hybrid.lm_loss(p, cfg, b),
            prefill=lambda p, b, cap: hybrid.prefill(p, cfg, b["tokens"], cap),
            decode=lambda p, st, toks: hybrid.decode_step(p, cfg, st, toks),
            init_state=lambda bsz, cap: hybrid.init_decode_state(None, cfg, bsz, cap),
        )
    if cfg.family == "rwkv":
        return Model(
            cfg=cfg,
            init=lambda key: ssm.init_rwkv_lm(cfg, key),
            loss=lambda p, b: ssm.rwkv_lm_loss(p, cfg, b),
            prefill=lambda p, b, cap: ssm.rwkv_prefill(p, cfg, b["tokens"]),
            decode=lambda p, st, toks: ssm.rwkv_decode_step(p, cfg, st, toks),
            init_state=lambda bsz, cap: ssm.rwkv_init_state(cfg, bsz),
        )
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: whisper.init_encdec(cfg, key),
            loss=lambda p, b: whisper.encdec_loss(p, cfg, b),
            prefill=lambda p, b, cap: whisper.prefill(p, cfg, b["frames"], cap),
            decode=lambda p, cache, toks: whisper.decode_step(p, cfg, cache, toks),
        )
    raise ValueError(f"unknown family {cfg.family}")


# ---------------------------------------------------------------------------
# step builders (what the CVM tz.Pipeline instructions bind to)
# ---------------------------------------------------------------------------


def _microbatch_slices(batch: Dict[str, jax.Array], m: int) -> Dict[str, jax.Array]:
    """Reshape each batch leaf to (m, b/m, ...); positions3 batches on dim 1."""
    out = {}
    for k, v in batch.items():
        if k == "positions3":
            b = v.shape[1]
            out[k] = jnp.moveaxis(v.reshape(3, m, b // m, *v.shape[2:]), 1, 0)
        else:
            out[k] = v.reshape(m, v.shape[0] // m, *v.shape[1:])
    return out


def make_train_step(model: Model, optimizer: Optional[Optimizer] = None,
                    microbatch: Optional[int] = None,
                    grad_constraint: Optional[Callable[[Any], Any]] = None):
    """Gradient-accumulation train step.

    ``microbatch`` > 1 splits the global batch into that many slices and
    accumulates grads in a scan — bounding activation memory to one slice
    (with scan-over-layers remat this is what makes the deep configs fit
    16 GB/chip; see EXPERIMENTS.md §Dry-run).

    ``grad_constraint`` (optional) applies a sharding constraint to the f32
    gradient accumulator — ZeRO-2-style: the accumulator shards over the
    data axes instead of being replicated (EXPERIMENTS §Perf iteration 5).
    """
    opt = optimizer or AdamW()
    m = microbatch if microbatch is not None else model.cfg.microbatch

    def train_step(params, opt_state, batch):
        if m <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            slices = _microbatch_slices(batch, m)

            def body(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(model.loss)(params, mb)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                if grad_constraint is not None:
                    gacc = grad_constraint(gacc)
                return (gacc, lacc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if grad_constraint is not None:
                zeros = grad_constraint(zeros)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                           slices)
            grads = jax.tree_util.tree_map(lambda g: g / m, gsum)
            loss = lsum / m
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss}

    return train_step, opt


def make_serve_step(model: Model):
    def serve_step(params, state, tokens):
        logits, new_state = model.decode(params, state, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_state

    return serve_step


def make_prefill_step(model: Model, cache_capacity: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_capacity)

    return prefill_step
