"""State-space / linear-recurrence blocks: Mamba2 (SSD) and RWKV6.

Mamba2 uses the chunked SSD algorithm — intra-chunk work is matmul-shaped
(MXU-friendly) and inter-chunk state is a short scan: the TPU-native
formulation (vs. the CUDA selective-scan kernel of the paper's GPU world).
RWKV6 ("Finch") implements data-dependent decay with a time scan for
training and an O(1) recurrent state for decode.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------


def init_mamba2(key, d_model: int, d_inner: int, ssm_state: int, d_head: int = 64,
                d_conv: int = 4, dtype=jnp.float32) -> Dict[str, Any]:
    h = d_inner // d_head
    ks = L.split_keys(key, 4)
    return {
        # in_proj → [z (Di), x (Di), B (N), C (N), dt (H)]
        "in_proj": L.dense_init(ks[0], (d_model, 2 * d_inner + 2 * ssm_state + h), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": L.dense_init(ks[2], (d_inner, d_model), dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv1d. x: (B,S,Di); w: (K,Di). Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return jax.nn.silu(y), new_state


def ssd_chunked(x, a, Bm, Cm, chunk: int = 64, init_state=None):
    """Chunked SSD. x: (B,S,H,P); a: (B,S,H) log-decay ≤ 0; Bm, Cm: (B,S,N).

    Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    # SSD state math in f32 (decays are exp()s; bf16 states drift)
    xr = x.reshape(B_, nc, c, H, P).astype(jnp.float32)
    ar = a.reshape(B_, nc, c, H).astype(jnp.float32)
    Br = Bm.reshape(B_, nc, c, N).astype(jnp.float32)
    Cr = Cm.reshape(B_, nc, c, N).astype(jnp.float32)
    acum = jnp.cumsum(ar, axis=2)                                  # (B,nc,c,H)

    # intra-chunk (matmul-shaped)
    diff = acum[:, :, :, None, :] - acum[:, :, None, :, :]         # (B,nc,c,c,H)
    mask = jnp.tril(jnp.ones((c, c), bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bniN,bnjN->bnij", Cr, Br)                 # (B,nc,c,c)
    y_intra = jnp.einsum("bnij,bnijh,bnjhp->bnihp", scores, Lmat, xr)

    # chunk boundary states
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)              # (B,nc,c,H)
    states = jnp.einsum("bnjN,bnjh,bnjhp->bnhNp", Br, decay_to_end, xr)  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(acum[:, :, -1, :])                       # (B,nc,H)

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B_, H, N, P), jnp.float32))

    def scan_fn(s_prev, inp):
        st, dec = inp                                              # (B,H,N,P), (B,H)
        y_state = s_prev                                           # state BEFORE chunk
        s_next = s_prev * dec[..., None, None] + st
        return s_next, y_state

    states_t = jnp.moveaxis(states, 1, 0)                          # (nc,B,H,N,P)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                      # (nc,B,H)
    final_state, prev_states = jax.lax.scan(scan_fn, s0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                  # (B,nc,H,N,P)

    # inter-chunk contribution
    y_inter = jnp.einsum("bniN,bnhNp,bnih->bnihp", Cr, prev_states, jnp.exp(acum))
    y = (y_intra + y_inter).reshape(B_, S, H, P).astype(x.dtype)
    return y, final_state


def mamba2_block(p, x, *, d_inner: int, ssm_state: int, d_head: int = 64,
                 chunk: int = 64, state=None):
    """x: (B,S,D) → (y, new_state).  state = (conv_state, ssm_state) for decode."""
    B_, S, D = x.shape
    h = d_inner // d_head
    n = ssm_state
    u = x @ p["in_proj"]
    z, xs, Bm, Cm, dt = jnp.split(
        u, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1)
    conv_state = state[0] if state is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], conv_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    A = -jnp.exp(p["A_log"])                                        # (H,) < 0
    a = dt * A                                                      # log-decay
    xh = xs.reshape(B_, S, h, d_head) * dt[..., None].astype(xs.dtype)
    ssm0 = state[1] if state is not None else None
    y, new_ssm = ssd_chunked(xh, a, Bm, Cm, chunk=chunk, init_state=ssm0)
    y = y + xh * p["D"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(B_, S, d_inner) * jax.nn.silu(z)
    y = L.rmsnorm(y, p["norm"])
    return (y @ p["out_proj"]).astype(x.dtype), (new_conv, new_ssm)


def mamba2_decode(p, x, state, *, d_inner: int, ssm_state: int, d_head: int = 64):
    """Single-token recurrent step (S=1) — O(state) work."""
    return mamba2_block(p, x, d_inner=d_inner, ssm_state=ssm_state, d_head=d_head,
                        chunk=1, state=state)


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------


def init_rwkv6(key, d_model: int, d_ff: int, d_head: int = 64, w_lora: int = 64,
               dtype=jnp.float32) -> Dict[str, Any]:
    h = d_model // d_head
    ks = L.split_keys(key, 10)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d_model)) * 0.5).astype(dtype),  # r,k,v,g,w
        "w0": jnp.full((d_model,), -5.0, jnp.float32),
        "w1": L.dense_init(ks[1], (d_model, w_lora), dtype=dtype),
        "w2": L.dense_init(ks[2], (w_lora, d_model), scale=0.01, dtype=dtype),
        "u": (jax.random.normal(ks[3], (h, d_head)) * 0.1).astype(jnp.float32),
        "wr": L.dense_init(ks[4], (d_model, d_model), dtype=dtype),
        "wk": L.dense_init(ks[5], (d_model, d_model), dtype=dtype),
        "wv": L.dense_init(ks[6], (d_model, d_model), dtype=dtype),
        "wg": L.dense_init(ks[7], (d_model, d_model), dtype=dtype),
        "wo": L.dense_init(ks[8], (d_model, d_model), dtype=dtype),
        "ln_x": jnp.ones((d_model,), dtype),
        # channel mix
        "cm_mu": (jax.random.uniform(ks[9], (2, d_model)) * 0.5).astype(dtype),
        "cm_k": L.dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "cm_v": L.dense_init(ks[1], (d_ff, d_model), dtype=dtype),
        "cm_r": L.dense_init(ks[2], (d_model, d_model), dtype=dtype),
    }


def _token_shift(x, last=None):
    """Shift sequence right by one; ``last`` is the previous token for decode."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def rwkv6_time_mix(p, x, *, d_head: int = 64, state=None):
    """x: (B,S,D) → (y, (last_x, wkv_state))."""
    B_, S, D = x.shape
    h = D // d_head
    last_x = state[0] if state is not None else None
    xp = _token_shift(x, last_x)

    def mix(i):
        return x + p["mu"][i] * (xp - x)

    r = (mix(0) @ p["wr"]).reshape(B_, S, h, d_head)
    k = (mix(1) @ p["wk"]).reshape(B_, S, h, d_head)
    v = (mix(2) @ p["wv"]).reshape(B_, S, h, d_head)
    g = jax.nn.silu(mix(3) @ p["wg"])
    w = p["w0"] + jnp.tanh(mix(4) @ p["w1"]) @ p["w2"]              # (B,S,D)
    w = jnp.exp(-jnp.exp(w.astype(jnp.float32))).reshape(B_, S, h, d_head)  # decay∈(0,1)

    s0 = state[1] if state is not None else jnp.zeros((B_, h, d_head, d_head), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                                        # (B,h,P) each
        kv = kt[..., :, None] * vt[..., None, :]                    # (B,h,P,P)
        out = jnp.einsum("bhp,bhpq->bhq", rt, s + p["u"][..., None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, out

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in
                       (r.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), w))
    s_final, outs = jax.lax.scan(step, s0, (rs, ks_, vs, ws))
    y = jnp.moveaxis(outs, 0, 1).reshape(B_, S, D).astype(x.dtype)
    y = L.rmsnorm(y, p["ln_x"]) * g
    return y @ p["wo"], (x[:, -1:], s_final)


def rwkv6_channel_mix(p, x, state=None):
    last_x = state if state is not None else None
    xp = _token_shift(x, last_x)
    xk = x + p["cm_mu"][0] * (xp - x)
    xr = x + p["cm_mu"][1] * (xp - x)
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid(xr @ p["cm_r"]) * (k @ p["cm_v"]), x[:, -1:]


# ---------------------------------------------------------------------------
# RWKV6 full model
# ---------------------------------------------------------------------------


def init_rwkv_lm(cfg, key) -> Dict[str, Any]:
    dt = cfg.param_dtype
    kemb, klay = L.split_keys(key, 2)
    p: Dict[str, Any] = {
        "emb": L.dense_init(kemb, (cfg.vocab, cfg.d_model), scale=0.02, dtype=dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    lkeys = jax.random.split(klay, cfg.n_layers)

    def one(k):
        return {
            "tm": init_rwkv6(k, cfg.d_model, cfg.d_ff, dtype=dt),
            "tm_norm": jnp.ones((cfg.d_model,), dt),
            "cm_norm": jnp.ones((cfg.d_model,), dt),
        }

    p["layers"] = jax.vmap(one)(jnp.stack(lkeys))
    return p


def rwkv_backbone(params, cfg, x, state=None):
    """x: (B,S,D) → (x_final, new_state).  state: per-layer recurrent pytree."""

    def body(carry, inp):
        x = carry
        if state is None:
            lp = inp
            st_tm, st_cm = None, None
        else:
            lp, st_tm, st_cm = inp
        y, new_tm = rwkv6_time_mix(lp["tm"], L.rmsnorm(x, lp["tm_norm"]), state=st_tm)
        x = x + y
        y, new_cm = rwkv6_channel_mix(lp["tm"], L.rmsnorm(x, lp["cm_norm"]), state=st_cm)
        return x + y, (new_tm, new_cm)

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = params["layers"] if state is None else (params["layers"], state[0], state[1])
    x, new_state = jax.lax.scan(body, x, xs,
                                unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return L.rmsnorm(x, params["final_norm"]), new_state


def rwkv_lm_loss(params, cfg, batch):
    from .lm import chunked_ce_loss

    x = params["emb"][batch["tokens"]]
    xf, _ = rwkv_backbone(params, cfg, x)
    return chunked_ce_loss(params, cfg, xf, batch["labels"], batch["mask"],
                           chunk=cfg.loss_chunk)


def rwkv_init_state(cfg, batch_size: int):
    h = cfg.d_model // 64
    lt = cfg.n_layers
    tm = (jnp.zeros((lt, batch_size, 1, cfg.d_model), cfg.param_dtype),
          jnp.zeros((lt, batch_size, h, 64, 64), jnp.float32))
    cm = jnp.zeros((lt, batch_size, 1, cfg.d_model), cfg.param_dtype)
    return (tm, cm)


def rwkv_decode_step(params, cfg, state, tokens):
    """tokens: (B,1) → (logits, new_state). O(1) per token — no KV cache."""
    x = params["emb"][tokens]
    xf, new_state = rwkv_backbone(params, cfg, x, state=state)
    logits = xf[:, -1].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return logits, new_state


def rwkv_prefill(params, cfg, tokens):
    """Process a prompt in parallel; returns (logits, recurrent state).

    The scan ys of the backbone ARE the per-layer final states (the
    constant-size 'cache' of an attention-free model).
    """
    x = params["emb"][tokens]
    xf, states = rwkv_backbone(params, cfg, x)
    tm, cm = states
    logits = xf[:, -1].astype(jnp.float32) @ params["emb"].astype(jnp.float32).T
    return logits, (tm, cm)
