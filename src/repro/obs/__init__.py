"""End-to-end observability for the CVM stack.

Three pieces (see docs/observability.md):

* :mod:`repro.obs.trace` — the span tracer, counters/histograms, the
  process-global default (disabled by default, zero-overhead when off),
  and structured warnings;
* :mod:`repro.obs.export` — Chrome-trace JSON export
  (``chrome://tracing`` / Perfetto) with the metrics dict embedded;
* :mod:`repro.obs.feedback` — measured per-operator cardinalities joined
  against the cost model's estimates (the estimate-vs-actual table in
  ``CompileResult.explain()``), observed ``TableStats``, and the runtime
  :data:`~repro.compiler.cost.EXEC_CALIBRATION` feed.
"""

from .export import chrome_trace, write_chrome_trace  # noqa: F401
from .feedback import (  # noqa: F401
    FEEDBACK,
    TAPPED_OPS,
    FeedbackCatalog,
    OpObservation,
    RuntimeProfile,
    TapRecord,
    build_profile,
    tap_key,
)
from .trace import (  # noqa: F401
    NULL_SPAN,
    DegradedWarning,
    ObsWarning,
    Span,
    Tracer,
    get_tracer,
    set_tracer,
    tracing,
    warn_event,
)
