"""Span-based tracing + metrics for the whole CVM stack.

The compile driver already records what each rewrite pass did
(``PassRecord``); execution was a black box.  This module is the shared
measurement substrate for both sides:

  * :class:`Tracer` — nested wall-time spans with typed attributes, plus
    counters, histograms, and structured warning events.  One process-global
    default (:func:`get_tracer`), **disabled by default**: every hot-path
    entry point is a single ``enabled`` check and the disabled ``span()``
    returns one shared no-op object (no allocation, no clock read).
  * :func:`tracing` — context manager installing an enabled tracer (and
    restoring the previous one), the ergonomic way to trace one workload.
  * structured warnings (:func:`warn_event`) — always surfaced as a Python
    :class:`ObsWarning` so nothing is silently dropped, and additionally
    recorded as a trace event when tracing is on.

Spans are pure host-side bookkeeping: jitted bodies are never instrumented
from inside (no host callbacks) — backends record spans around ``jit``
boundaries and report per-operator cardinalities via returned scalars (see
``repro.obs.feedback``).

This module depends only on the standard library — importing it never pulls
in jax.
"""

from __future__ import annotations

import itertools
import threading
import time
import warnings
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Span", "Tracer", "ObsWarning", "DegradedWarning",
    "get_tracer", "set_tracer", "tracing", "warn_event",
]


class ObsWarning(UserWarning):
    """Structured warning raised through the observability layer."""


class DegradedWarning(ObsWarning):
    """The plan that ran is not the plan that was chosen.

    Raised by the driver's fallback chain (``repro.robust.fallback``) when a
    cost-chosen candidate failed and a safer variant — or the interp tier —
    answered the query instead.  Catch it (or filter it) to detect degraded
    service; the paired ``robust.fallback.*`` counters carry the same signal
    into metrics."""


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


_ids = itertools.count(1)


class Span:
    """One timed interval with typed attributes; records itself on exit."""

    __slots__ = ("tracer", "name", "cat", "args", "span_id", "parent_id",
                 "tid", "t0", "dur_s")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]) -> None:
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.span_id = next(_ids)
        self.parent_id: Optional[int] = None
        self.tid = threading.get_ident()
        self.t0 = 0.0
        self.dur_s = 0.0

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes mid-span (e.g. results known only at the end)."""
        self.args.update(attrs)
        return self

    def __enter__(self) -> "Span":
        stack = self.tracer._stack()
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        self.dur_s = time.perf_counter() - self.t0
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self.tracer._record(self)
        return False


class _NullSpan:
    """Shared no-op span: the disabled-mode zero-allocation fast path."""

    __slots__ = ()

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


NULL_SPAN = _NullSpan()

#: cap on retained samples per histogram — count/sum keep accumulating
_MAX_HIST_SAMPLES = 65_536


class Tracer:
    """Collects spans, counters, histograms, and events for one workload."""

    def __init__(self, enabled: bool = True, max_events: int = 100_000) -> None:
        self.enabled = enabled
        self.max_events = max_events
        self.epoch = time.perf_counter()      # span timestamps are relative
        self.epoch_wall = time.time()
        self.spans: List[Span] = []
        self.events: List[Dict[str, Any]] = []
        self.counters: Dict[str, float] = {}
        self.histograms: Dict[str, List[float]] = {}
        self.dropped = 0
        self._hist_totals: Dict[str, Tuple[int, float]] = {}
        self._local = threading.local()
        self._lock = threading.Lock()

    # -- span lifecycle ------------------------------------------------------
    def span(self, name: str, cat: str = "", **attrs: Any):
        """``with tracer.span("lower", cat="compile.pass", target="spmd"):``"""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, cat, attrs)

    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, span: Span) -> None:
        with self._lock:
            if len(self.spans) >= self.max_events:
                self.dropped += 1
                return
            self.spans.append(span)

    def record_complete(self, name: str, cat: str, t0: float, dur_s: float,
                        **attrs: Any) -> None:
        """Record an already-measured interval (e.g. a per-op span whose
        duration was derived outside the tracer, or a zero-duration
        cardinality annotation from a jitted body)."""
        if not self.enabled:
            return
        span = Span(self, name, cat, attrs)
        stack = self._stack()
        span.parent_id = stack[-1].span_id if stack else None
        span.t0 = t0
        span.dur_s = dur_s
        self._record(span)

    # -- metrics -------------------------------------------------------------
    def counter(self, name: str, value: float = 1.0) -> None:
        if not self.enabled:
            return
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def observe(self, name: str, value: float) -> None:
        """Add one sample to a histogram (per-request latencies etc.)."""
        if not self.enabled:
            return
        with self._lock:
            n, total = self._hist_totals.get(name, (0, 0.0))
            self._hist_totals[name] = (n + 1, total + value)
            samples = self.histograms.setdefault(name, [])
            if len(samples) < _MAX_HIST_SAMPLES:
                samples.append(value)

    def event(self, name: str, **attrs: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
                return
            self.events.append({"name": name,
                                "ts": time.perf_counter() - self.epoch,
                                **attrs})

    # -- summaries -----------------------------------------------------------
    def histogram_summary(self, name: str) -> Optional[Dict[str, float]]:
        samples = self.histograms.get(name)
        if not samples:
            return None
        n, total = self._hist_totals[name]
        s = sorted(samples)

        def pct(q: float) -> float:
            return s[min(len(s) - 1, int(q * len(s)))]

        return {"count": float(n), "sum": total, "mean": total / n,
                "min": s[0], "max": s[-1],
                "p50": pct(0.50), "p90": pct(0.90), "p99": pct(0.99)}

    def metrics(self) -> Dict[str, Any]:
        """Structured metrics dict: counters + histogram summaries + drops."""
        out: Dict[str, Any] = {"counters": dict(self.counters)}
        hists = {name: self.histogram_summary(name) for name in self.histograms}
        if hists:
            out["histograms"] = hists
        if self.dropped:
            out["dropped"] = self.dropped
        return out

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.events.clear()
            self.counters.clear()
            self.histograms.clear()
            self._hist_totals.clear()
            self.dropped = 0


# ---------------------------------------------------------------------------
# the process-global default
# ---------------------------------------------------------------------------

#: tracing is OFF by default; the disabled tracer's hot path is one
#: attribute check per instrumented site
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    global _TRACER
    previous = _TRACER
    _TRACER = tracer
    return previous


class _TracingContext:
    """Context manager + handle returned by :func:`tracing`."""

    def __init__(self, tracer: Tracer) -> None:
        self.tracer = tracer
        self._previous: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        self._previous = set_tracer(self.tracer)
        return self.tracer

    def __exit__(self, *exc: Any) -> bool:
        if self._previous is not None:
            set_tracer(self._previous)
        return False


def tracing(enabled: bool = True, max_events: int = 100_000) -> _TracingContext:
    """``with tracing() as tracer: ...`` — installs (and restores) the
    process-global tracer around one traced workload."""
    return _TracingContext(Tracer(enabled=enabled, max_events=max_events))


# ---------------------------------------------------------------------------
# structured warnings
# ---------------------------------------------------------------------------


def warn_event(code: str, category: type = ObsWarning, **fields: Any) -> None:
    """Emit a structured warning through the obs layer.

    Always raises a Python warning of ``category`` (an :class:`ObsWarning`
    subclass — so the condition is visible even with tracing off; nothing is
    silently swallowed); when tracing is on, the same record lands in the
    trace as an event and bumps the ``warnings.<code>`` counter.
    """
    tracer = get_tracer()
    tracer.event(code, **fields)
    tracer.counter(f"warnings.{code}")
    detail = " ".join(f"{k}={v}" for k, v in sorted(fields.items()))
    warnings.warn(f"{code}: {detail}" if detail else code, category,
                  stacklevel=2)
