"""Measured-cardinality feedback: runtime observations → catalog + cost model.

The cost-based driver plans with *estimates* (``compiler/stats.py``); this
module closes the loop with *measurements*:

  * backends tap the output cardinality of selected operators during a
    traced execution (``TAPPED_OPS``) — eagerly in the interpreter, via
    returned scalar counts from jitted bodies in the local/spmd backends
    (host-callback-free);
  * :func:`build_profile` joins those measurements against the propagated
    estimates of the *same lowered program* into a
    :class:`RuntimeProfile` — the estimated-vs-actual table that
    ``CompileResult.explain()`` renders;
  * :data:`FEEDBACK` accumulates observations across runs: measured base
    table row counts become *observed* ``TableStats``
    (:meth:`FeedbackCatalog.observed_statistics`), and measured wall time
    per estimated cost unit feeds :data:`~repro.compiler.cost.EXEC_CALIBRATION`
    — the measurement substrate for the ROADMAP's re-planning trigger
    (:meth:`FeedbackCatalog.plans_over_threshold`).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

from .trace import get_tracer

# NOTE: repro.compiler imports (cost, stats) are deferred to call sites —
# the compilation driver depends on repro.robust which depends on repro.obs,
# so a module-level compiler import here would close an import cycle.

__all__ = [
    "TAPPED_OPS", "tap_key", "TapRecord", "OpObservation", "RuntimeProfile",
    "build_profile", "FeedbackCatalog", "FEEDBACK",
]

#: operators whose output cardinality a traced execution measures — the
#: cardinality-carrying steps of a relational plan (selections, grouped and
#: scalar aggregations, joins, compaction/limits, scans for base-table truth,
#: and whole MeshExecute bodies on the spmd path)
TAPPED_OPS = frozenset({
    # vec flavor (local/spmd jitted bodies)
    "vec.ScanVec", "vec.MaskSelect", "vec.GroupAggSorted",
    "vec.GroupAggDirect", "vec.FusedSelectAgg", "vec.AggrVec",
    "vec.MergeJoinSorted", "vec.HashJoinDirect", "vec.FusedJoinGroupAgg",
    "vec.Compact", "vec.TopKVec", "vec.LimitVec",
    # encode cardinality: rows flowing through the rank lookup (the encode
    # cost driver — dictionary card itself is a static instruction param)
    "vec.DictEncode",
    # rel flavor (interpreter)
    "rel.Scan", "rel.Select", "rel.GroupByAggr", "rel.Aggr", "rel.Join",
    "rel.Limit", "rel.Distinct",
    # mesh / control flow boundaries
    "mesh.MeshExecute", "mesh.ExchangeByKey",
})

_SCAN_OPS = ("rel.Scan", "vec.ScanVec")


def tap_key(program_name: str, index: int, opcode: str, register: str) -> str:
    """Stable identity of one instruction: body position + opcode + names.

    Keys must be static across jit traces of the same program (they are
    pytree dict keys in the traced backends) and reconstructible by walking
    the lowered program (how estimates are joined back on).
    """
    return f"{index:03d}|{opcode}|{program_name}|{register}"


def _parse_key(key: str) -> Tuple[int, str, str, str]:
    index, opcode, program, register = key.split("|", 3)
    return int(index), opcode, program, register


@dataclass(frozen=True)
class TapRecord:
    """Aggregated measurement for one instruction across its executions
    (an op inside an unrolled ConcurrentExecute body taps once per chunk —
    row counts are summed, giving the global cardinality)."""

    occurrences: int
    rows_in: Optional[int]
    rows_out: int


@dataclass(frozen=True)
class OpObservation:
    """One operator's measured vs estimated cardinality."""

    key: str
    opcode: str
    program: str
    register: str
    occurrences: int
    rows_in: Optional[int]
    rows_out: int
    est_rows: Optional[float]
    wall_s: Optional[float] = None      # eager backends only (interpreter)
    table: Optional[str] = None         # scans: the base table measured

    @property
    def rel_miss(self) -> Optional[float]:
        """Signed relative estimation miss: (actual − est) / max(est, 1)."""
        if self.est_rows is None:
            return None
        return (self.rows_out - self.est_rows) / max(self.est_rows, 1.0)


@dataclass
class RuntimeProfile:
    """One traced execution: wall time + per-operator observations."""

    target: str
    program_name: str
    fingerprint: str
    wall_s: float
    observations: Tuple[OpObservation, ...]
    est_cost: float = 0.0

    @property
    def worst_miss(self) -> Optional[float]:
        misses = [abs(o.rel_miss) for o in self.observations
                  if o.rel_miss is not None]
        return max(misses) if misses else None

    def scan_rows(self) -> Dict[str, int]:
        """Measured base-table row counts (valid rows, not padded capacity)."""
        return {o.table: o.rows_out for o in self.observations
                if o.table is not None}

    def render(self) -> str:
        """The estimated-vs-actual cardinality table for ``explain()``."""
        head = (f"runtime[{self.target}] {self.program_name}: "
                f"{self.wall_s * 1e3:.3f} ms, "
                f"{len(self.observations)} measured op(s)")
        if self.worst_miss is not None:
            head += f", worst cardinality miss {self.worst_miss * 100:.0f}%"
        lines = [head,
                 "| op | register | est rows | actual rows | miss | wall ms |",
                 "|---|---|---:|---:|---:|---:|"]
        for o in self.observations:
            est = f"{o.est_rows:,.0f}" if o.est_rows is not None else "?"
            miss = (f"{o.rel_miss * 100:+.0f}%" if o.rel_miss is not None
                    else "—")
            wall = f"{o.wall_s * 1e3:.3f}" if o.wall_s is not None else "—"
            name = o.opcode + (f"[{o.table}]" if o.table else "")
            lines.append(f"| {name} | {o.register} | {est} | {o.rows_out:,} "
                         f"| {miss} | {wall} |")
        return "\n".join(lines)

    def records(self) -> List[Dict[str, Any]]:
        return [
            {"key": o.key, "op": o.opcode, "program": o.program,
             "register": o.register, "occurrences": o.occurrences,
             "rows_in": o.rows_in, "rows_out": o.rows_out,
             "est_rows": o.est_rows, "rel_miss": o.rel_miss,
             "wall_s": o.wall_s, "table": o.table}
            for o in self.observations
        ]


def build_profile(result: Any, cards: Mapping[str, TapRecord], wall_s: float,
                  wall_by_key: Optional[Mapping[str, float]] = None,
                  ) -> RuntimeProfile:
    """Join measured cardinalities against the lowered program's estimates.

    ``result`` is a :class:`~repro.compiler.driver.CompileResult`; the taps
    were collected from ``result.program`` (the exact program the backend
    executed), so estimates and measurements line up by construction.
    """
    from ..compiler.cost import estimate_cost
    from ..compiler.stats import propagate, seq_chunks

    program = result.program
    stats = getattr(result, "stats", None)
    env = propagate(program, stats)

    est_by_key: Dict[str, float] = {}
    table_by_key: Dict[str, str] = {}
    for p in program.walk():
        for i, ins in enumerate(p.body):
            if ins.opcode not in TAPPED_OPS or not ins.outputs:
                continue
            key = tap_key(p.name, i, ins.opcode, ins.outputs[0].name)
            est = env.get(p, ins.outputs[0]).rows
            if ins.opcode == "mesh.MeshExecute":
                # outputs are stacked Seq[n] chunks and the measurement sums
                # across shards; the propagated estimate is per shard
                est *= float(seq_chunks(ins.outputs[0]))
            est_by_key[key] = est
            if ins.opcode in _SCAN_OPS:
                table_by_key[key] = ins.param("table")

    observations = []
    for key in sorted(cards):
        rec = cards[key]
        index, opcode, pname, register = _parse_key(key)
        est = est_by_key.get(key)
        if est is not None and rec.occurrences > 1:
            # per-chunk estimate × chunks ↔ summed per-chunk measurements
            est *= rec.occurrences
        observations.append(OpObservation(
            key=key, opcode=opcode, program=pname, register=register,
            occurrences=rec.occurrences, rows_in=rec.rows_in,
            rows_out=rec.rows_out, est_rows=est,
            wall_s=(wall_by_key or {}).get(key),
            table=table_by_key.get(key),
        ))
    return RuntimeProfile(
        target=result.target,
        program_name=result.source.name,
        fingerprint=result.fingerprint,
        wall_s=wall_s,
        observations=tuple(observations),
        est_cost=estimate_cost(program, stats),
    )


# ---------------------------------------------------------------------------
# the accumulating catalog
# ---------------------------------------------------------------------------


@dataclass
class FeedbackCatalog:
    """Cross-run accumulator of measured statistics.

    Thread-safe; bounded (``max_profiles`` most recent profiles kept).  The
    observed numbers are what adaptive re-optimization consumes: pass
    :meth:`observed_statistics` as the catalog stats of a re-compile and the
    costed search now ranks candidates under *measured* cardinalities.
    """

    max_profiles: int = 64
    table_rows: Dict[str, int] = field(default_factory=dict)
    profiles: "OrderedDict[str, RuntimeProfile]" = field(
        default_factory=OrderedDict)  # latest profile per fingerprint
    runs: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def record(self, profile: RuntimeProfile) -> None:
        with self._lock:
            self.runs += 1
            self.table_rows.update(profile.scan_rows())
            self.profiles[profile.fingerprint] = profile
            self.profiles.move_to_end(profile.fingerprint)
            while len(self.profiles) > self.max_profiles:
                self.profiles.popitem(last=False)
        if profile.est_cost > 0 and profile.wall_s > 0:
            from ..compiler.cost import EXEC_CALIBRATION

            # abstract plan-cost units → measured execution seconds: the
            # runtime sibling of the compile-time CALIBRATION EMA
            EXEC_CALIBRATION.update(profile.est_cost, profile.wall_s)
        tracer = get_tracer()
        tracer.counter("feedback.profiles")
        if profile.worst_miss is not None:
            tracer.counter("feedback.worst_miss_pct",
                           profile.worst_miss * 100.0)

    def observed_statistics(self, base: Any = None) -> Any:
        """Catalog statistics with measured base-table row counts folded in.

        ``base`` is the estimate-time :class:`~repro.compiler.stats.Statistics`
        (or ``None``); measured scan cardinalities override its row counts —
        NDV and domain knowledge is preserved.
        """
        from ..compiler.stats import Statistics

        with self._lock:
            rows = dict(self.table_rows)
        base = base if base is not None else Statistics()
        return base.with_observed_rows(rows)

    def plans_over_threshold(self, threshold: float = 1.0,
                             ) -> List[Tuple[str, float]]:
        """Fingerprints whose worst cardinality miss exceeds ``threshold``
        (relative) — the candidates for adaptive re-planning."""
        with self._lock:
            out = [(fp, p.worst_miss) for fp, p in self.profiles.items()
                   if p.worst_miss is not None and p.worst_miss > threshold]
        return sorted(out, key=lambda kv: -kv[1])

    def clear(self) -> None:
        with self._lock:
            self.table_rows.clear()
            self.profiles.clear()
            self.runs = 0


#: process-wide feedback catalog — every traced execution lands here
FEEDBACK = FeedbackCatalog()
