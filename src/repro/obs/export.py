"""Trace exporters: Chrome-trace JSON (chrome://tracing / Perfetto) + metrics.

The Chrome trace event format is the lowest-common-denominator viewer
interchange: a ``{"traceEvents": [...]}`` object whose entries are complete
("ph": "X") events with microsecond timestamps.  Nesting is implicit —
events on the same pid/tid whose intervals contain each other render as a
flame graph, which is exactly what :class:`~repro.obs.trace.Span` records.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

from .trace import Tracer, get_tracer

__all__ = ["chrome_trace", "write_chrome_trace"]


def chrome_trace(tracer: Optional[Tracer] = None,
                 process_name: str = "repro-cvm") -> Dict[str, Any]:
    """Render a tracer's spans/events as a Chrome trace event object."""
    tracer = tracer or get_tracer()
    pid = os.getpid()
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    tids = {}
    for span in tracer.spans:
        tid = tids.setdefault(span.tid, len(tids))
        args = {k: _jsonable(v) for k, v in span.args.items()}
        if span.parent_id is not None:
            args["parent"] = span.parent_id
        events.append({
            "name": span.name,
            "cat": span.cat or "default",
            "ph": "X",
            "pid": pid,
            "tid": tid,
            "ts": (span.t0 - tracer.epoch) * 1e6,
            "dur": span.dur_s * 1e6,
            "id": span.span_id,
            "args": args,
        })
    for ev in tracer.events:
        events.append({
            "name": ev["name"], "cat": "event", "ph": "i", "s": "p",
            "pid": pid, "tid": 0, "ts": ev["ts"] * 1e6,
            "args": {k: _jsonable(v) for k, v in ev.items()
                     if k not in ("name", "ts")},
        })
    for name, value in sorted(tracer.counters.items()):
        events.append({
            "name": name, "cat": "counter", "ph": "C", "pid": pid, "tid": 0,
            "ts": 0.0, "args": {"value": value},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "metadata": {"epoch_wall_s": tracer.epoch_wall,
                         "metrics": tracer.metrics()}}


def write_chrome_trace(path: Union[str, Path],
                       tracer: Optional[Tracer] = None,
                       process_name: str = "repro-cvm") -> Path:
    """Write the Chrome-trace JSON; load the file in chrome://tracing."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(chrome_trace(tracer, process_name), indent=1))
    return path


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)
