"""VecTable: the physical ``Vec⟨tuple⟩`` collection on JAX.

A VecTable is a struct-of-arrays block with a static capacity and a
validity mask.  All relational operators are pure functions VecTable →
VecTable with static output shapes (XLA requirement); cardinality lives in
the mask.  This file is the executable meaning of the ``vec.*`` IR flavor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.expr import AggSpec, Expr, evaluate

_I64_MAX = np.iinfo(np.int64).max
_F32_INF = np.float32(np.inf)


@jax.tree_util.register_pytree_node_class
@dataclass
class VecTable:
    cols: Dict[str, jax.Array]
    valid: jax.Array  # bool (cap,)

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.cols))
        return tuple(self.cols[n] for n in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(cols=dict(zip(names, children[:-1])), valid=children[-1])

    # -- basics ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    @staticmethod
    def from_numpy(data: Mapping[str, np.ndarray], capacity: Optional[int] = None) -> "VecTable":
        n = len(next(iter(data.values())))
        cap = capacity or n
        if cap < n:
            raise ValueError(f"capacity {cap} < rows {n}")
        cols = {}
        for k, v in data.items():
            v = np.asarray(v)
            pad = np.zeros((cap - n,) + v.shape[1:], dtype=v.dtype)
            cols[k] = jnp.asarray(np.concatenate([v, pad]))
        valid = jnp.asarray(np.arange(cap) < n)
        return VecTable(cols, valid)

    def to_numpy(self) -> Dict[str, np.ndarray]:
        mask = np.asarray(self.valid)
        return {k: np.asarray(v)[mask] for k, v in self.cols.items()}

    def astuple_cols(self, names: Sequence[str]) -> List[jax.Array]:
        return [self.cols[n] for n in names]


# ---------------------------------------------------------------------------
# operators (pure functions — the vec.* flavor semantics)
# ---------------------------------------------------------------------------


def mask_select(t: VecTable, pred: Expr) -> VecTable:
    """Predicated (late-materialized) selection: narrow the mask only."""
    p = evaluate(pred, t.cols, jnp)
    return VecTable(t.cols, t.valid & p)


def proj(t: VecTable, names: Sequence[str]) -> VecTable:
    return VecTable({n: t.cols[n] for n in names}, t.valid)


def exproj(t: VecTable, exprs: Sequence[Tuple[str, Expr]]) -> VecTable:
    cap = t.capacity
    out = {}
    for name, e in exprs:
        v = evaluate(e, t.cols, jnp)
        if jnp.ndim(v) == 0:
            v = jnp.full((cap,), v)
        out[name] = v
    return VecTable(out, t.valid)


def _masked(fn: str, arr: jax.Array, valid: jax.Array) -> jax.Array:
    if fn == "count":
        return jnp.sum(valid.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32))
    if jnp.issubdtype(arr.dtype, jnp.integer) or jnp.issubdtype(arr.dtype, jnp.bool_):
        arr = arr.astype(jnp.float32)
    if fn == "sum":
        return jnp.sum(jnp.where(valid, arr, 0))
    if fn == "min":
        return jnp.min(jnp.where(valid, arr, _F32_INF))
    if fn == "max":
        return jnp.max(jnp.where(valid, arr, -_F32_INF))
    raise ValueError(fn)


def aggr(t: VecTable, aggs: Sequence[AggSpec]) -> Dict[str, jax.Array]:
    """Masked scalar aggregation → Single⟨aggs⟩ (dict of scalars)."""
    out = {}
    for a in aggs:
        arr = evaluate(a.expr, t.cols, jnp) if a.fn != "count" else t.valid
        if jnp.ndim(arr) == 0:
            arr = jnp.full((t.capacity,), arr)
        out[a.name] = _masked(a.fn, arr, t.valid)
    return out


def combine_partials(partials: Sequence[Dict[str, jax.Array]], aggs: Sequence[AggSpec]) -> Dict[str, jax.Array]:
    out = {}
    for a in aggs:
        vals = jnp.stack([p[a.name] for p in partials])
        fn = a.combine_fn
        out[a.name] = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[fn](vals)
    return out


def _sort_perm(t: VecTable, keys: Sequence[str], ascending: Sequence[bool]) -> jax.Array:
    """Permutation: valid rows first, ordered by keys (stable)."""
    arrays = []
    for k, asc in zip(reversed(list(keys)), reversed(list(ascending))):
        arr = t.cols[k]
        if not asc:
            if jnp.issubdtype(arr.dtype, jnp.bool_):
                arr = ~arr
            else:
                arr = -arr.astype(jnp.float32) if not jnp.issubdtype(arr.dtype, jnp.integer) else -arr
        arrays.append(arr)
    arrays.append(~t.valid)  # primary: valid first
    return jnp.lexsort(tuple(arrays), axis=0)


def sort_by_key(t: VecTable, keys: Sequence[str], ascending: Optional[Sequence[bool]] = None) -> VecTable:
    asc = list(ascending or [True] * len(keys))
    perm = _sort_perm(t, keys, asc)
    return VecTable({k: v[perm] for k, v in t.cols.items()}, t.valid[perm])


def compact(t: VecTable, max_count: Optional[int] = None) -> VecTable:
    """Densify valid rows to the front — O(n) prefix-sum scatter.

    Position of each valid row is its prefix count of valid rows; rows
    beyond ``max_count`` (and all invalid rows) scatter out of bounds and
    are dropped.  Replaces the old argsort(~valid) shuffle (O(n log n)).
    """
    out_cap = int(max_count) if max_count is not None else t.capacity
    valid_i = t.valid.astype(jnp.int32)
    pos = jnp.cumsum(valid_i) - 1
    idx = jnp.where(t.valid, pos, out_cap)  # invalid rows → out of bounds
    n = jnp.minimum(jnp.sum(valid_i), out_cap)

    def scatter(col: jax.Array) -> jax.Array:
        out = jnp.zeros((out_cap,) + col.shape[1:], col.dtype)
        return out.at[idx].set(col, mode="drop")

    cols = {k: scatter(v) for k, v in t.cols.items()}
    valid = jnp.arange(out_cap) < n
    return VecTable(cols, valid)


#: composite-key packings with more buckets than this raise instead of
#: silently colliding in the 32-bit accumulator
_PACK_LIMIT = 1 << 31


def _composite_key(t: VecTable, keys: Sequence[str],
                   key_domains: Optional[Sequence[Tuple[int, int]]] = None,
                   lows: Optional[Sequence[jax.Array]] = None,
                   sizes: Optional[Sequence[jax.Array]] = None) -> jax.Array:
    """Pack key columns into one i32, preserving lexicographic order.

    Packing needs per-column value bounds.  Three sources, in order:
    static ``key_domains`` from the catalog (checked against the 32-bit
    budget — overpacking raises instead of colliding); dynamic
    ``lows``/``sizes`` traced from the data (collision-free whenever the
    actual domain product fits 32 bits); neither → single column only.
    """
    if key_domains is not None:
        n_buckets = 1
        for lo, hi in key_domains:
            n_buckets *= int(hi) - int(lo) + 1
        if n_buckets > _PACK_LIMIT:
            raise ValueError(
                f"composite key domain for {tuple(keys)} has {n_buckets} "
                f"buckets and cannot be packed into a 32-bit accumulator; "
                "reduce the key domain or use a single integer key column")
        acc = jnp.zeros((t.capacity,), jnp.int32)
        for k, (lo, hi) in zip(keys, key_domains):
            size = int(hi) - int(lo) + 1
            arr = _int_key(t.cols[k])
            arr = jnp.clip(arr - jnp.int32(lo), 0, size - 1)
            acc = acc * jnp.int32(size) + arr
        return acc
    if lows is not None and sizes is not None:
        acc = jnp.zeros((t.capacity,), jnp.int32)
        for k, lo, size in zip(keys, lows, sizes):
            arr = _int_key(t.cols[k])
            acc = acc * size.astype(jnp.int32) + (arr - lo.astype(jnp.int32))
        return acc
    if len(keys) == 1:
        return _int_key(t.cols[keys[0]])
    raise ValueError(
        f"cannot pack composite key {tuple(keys)} without per-column domain "
        "bounds; provide catalog key domains (see Catalog.stats) or derive "
        "dynamic bounds from the data")


def _int_key(arr: jax.Array) -> jax.Array:
    if jnp.issubdtype(arr.dtype, jnp.floating):
        arr = arr.view(jnp.int32) if arr.dtype == jnp.float32 else arr.astype(jnp.int32)
    return arr.astype(jnp.int32)


def _key_change(t: VecTable, keys: Sequence[str]) -> jax.Array:
    """Per-row "starts a new group" flags for a key-sorted block.

    Per-column comparison against the previous row — collision-free for any
    key dtype, domain, and column count (unlike composite-key packing)."""
    change = jnp.zeros((t.capacity,), bool).at[0].set(True)
    for k in keys:
        col = t.cols[k]
        change = change | (col != jnp.concatenate([col[:1], col[:-1]]))
    return change & t.valid


def group_agg_sorted(t: VecTable, keys: Sequence[str], aggs: Sequence[AggSpec],
                     max_groups: int) -> VecTable:
    """Grouped aggregation over a key-sorted block via segment reduction.

    The TPU-native replacement of hash aggregation: valid rows are sorted by
    key (invalid at the end), segment ids are the prefix count of key
    changes, and each agg is a masked ``jax.ops.segment_*``.
    """
    change = _key_change(t, keys)
    seg = jnp.cumsum(change.astype(jnp.int32)) - 1  # -1 before first valid group
    seg = jnp.where(t.valid, seg, max_groups)  # dump invalid rows
    seg = jnp.clip(seg, 0, max_groups)

    out_cols: Dict[str, jax.Array] = {}
    for k in keys:
        out_cols[k] = jax.ops.segment_max(
            jnp.where(t.valid, t.cols[k], jnp.zeros((), t.cols[k].dtype)),
            seg, num_segments=max_groups + 1)[:max_groups]
    for a in aggs:
        red = _segment_agg(a, t.cols, t.valid, seg, max_groups + 1)[:max_groups]
        out_cols[a.name] = red
    n_groups = jnp.sum(change.astype(jnp.int32))
    group_valid = jnp.arange(max_groups) < n_groups
    return VecTable(out_cols, group_valid)


def _segment_agg(a: AggSpec, cols: Mapping[str, jax.Array], valid: jax.Array,
                 seg: jax.Array, num_segments: int) -> jax.Array:
    """One masked segment reduction (shared by the sorted and direct tiers)."""
    if a.fn == "count":
        return jax.ops.segment_sum(valid.astype(jnp.int32), seg,
                                   num_segments=num_segments)
    arr = evaluate(a.expr, cols, jnp)
    if jnp.issubdtype(arr.dtype, jnp.integer) or jnp.issubdtype(arr.dtype, jnp.bool_):
        arr = arr.astype(jnp.float32)
    if a.fn == "sum":
        return jax.ops.segment_sum(jnp.where(valid, arr, 0), seg,
                                   num_segments=num_segments)
    if a.fn == "min":
        return jax.ops.segment_min(jnp.where(valid, arr, _F32_INF), seg,
                                   num_segments=num_segments)
    if a.fn == "max":
        return jax.ops.segment_max(jnp.where(valid, arr, -_F32_INF), seg,
                                   num_segments=num_segments)
    raise ValueError(a.fn)


def bucket_ids(t: VecTable, keys: Sequence[str],
               key_domains: Sequence[Tuple[int, int]]) -> jax.Array:
    """Dense bucket id per row: lexicographic rank in the static key domain."""
    acc = jnp.zeros((t.capacity,), jnp.int32)
    for k, (lo, hi) in zip(keys, key_domains):
        size = int(hi) - int(lo) + 1
        arr = jnp.clip(_int_key(t.cols[k]) - jnp.int32(lo), 0, size - 1)
        acc = acc * jnp.int32(size) + arr
    return acc


def decode_bucket_keys(keys: Sequence[str], key_domains: Sequence[Tuple[int, int]],
                       dtypes: Sequence[Any], num_buckets: int) -> Dict[str, jax.Array]:
    """Key column values for each dense bucket id (inverse of bucket_ids)."""
    b = jnp.arange(num_buckets, dtype=jnp.int32)
    sizes = [int(hi) - int(lo) + 1 for lo, hi in key_domains]
    out: Dict[str, jax.Array] = {}
    stride = num_buckets
    for k, (lo, _), size, dt in zip(keys, key_domains, sizes, dtypes):
        stride //= size
        vals = (b // stride) % size + jnp.int32(lo)
        out[k] = vals.astype(dt)
    return out


def group_agg_direct(t: VecTable, keys: Sequence[str], aggs: Sequence[AggSpec],
                     max_groups: int, key_domains: Sequence[Tuple[int, int]],
                     num_buckets: int, pred: Optional[Expr] = None) -> VecTable:
    """Grouped aggregation WITHOUT sorting: dense-bucket segment reduction.

    When the catalog bounds the composite key domain, every row's group is a
    static function of its key values — segment-reduce straight into
    ``num_buckets`` dense buckets (O(n), no lexsort, no per-column gather),
    then prefix-sum-compact the non-empty buckets to ``max_groups``.  Bucket
    order is lexicographic key order, so the output matches
    ``sort_by_key + group_agg_sorted`` row for row.  An optional fused
    predicate narrows validity in the same pass (MaskSelect fusion).
    """
    valid = t.valid
    if pred is not None:
        valid = valid & evaluate(pred, t.cols, jnp)
    bid = bucket_ids(t, keys, key_domains)
    seg = jnp.where(valid, bid, num_buckets)  # dump invalid rows

    counts = jax.ops.segment_sum(valid.astype(jnp.int32), seg,
                                 num_segments=num_buckets + 1)[:num_buckets]
    out_cols = decode_bucket_keys(keys, key_domains,
                                  [t.cols[k].dtype for k in keys], num_buckets)
    for a in aggs:
        out_cols[a.name] = _segment_agg(a, t.cols, valid, seg,
                                        num_buckets + 1)[:num_buckets]
    buckets = VecTable(out_cols, counts > 0)
    return compact(buckets, max_groups)


def merge_join_sorted(left: VecTable, right: VecTable, left_on: Sequence[str],
                      right_on: Sequence[str], max_count: int,
                      key_domains: Optional[Sequence[Tuple[int, int]]] = None,
                      ) -> VecTable:
    """PK-FK inner equi-join: ``right`` must be key-sorted with unique keys.

    searchsorted + gather — the TPU-native rewrite of Build/ProbeHTable.
    Multi-column keys are packed with catalog ``key_domains`` when the
    lowering provides them (static overflow check — overpacking raises),
    otherwise with bounds traced jointly from both sides (collision-free
    whenever the actual domain product fits the 32-bit accumulator).
    """
    if len(left_on) != 1 or len(right_on) != 1:
        if key_domains is not None:
            lk = _composite_key(left, left_on, key_domains=key_domains)
            rk = _composite_key(right, right_on, key_domains=key_domains)
        else:
            lows, sizes = _joint_key_bounds(left, right, left_on, right_on)
            lk = _composite_key(left, left_on, lows=lows, sizes=sizes)
            rk = _composite_key(right, right_on, lows=lows, sizes=sizes)
    else:
        lk = left.cols[left_on[0]].astype(jnp.int32)
        rk = right.cols[right_on[0]].astype(jnp.int32)
    sentinel = jnp.iinfo(jnp.int32).max
    rk = jnp.where(right.valid, rk, sentinel)
    idx = jnp.searchsorted(rk, lk)
    idx_c = jnp.clip(idx, 0, right.capacity - 1)
    match = (rk[idx_c] == lk) & left.valid

    out = dict(left.cols)
    lnames = set(left.cols)
    for k, v in right.cols.items():
        if k in right_on:
            continue
        name = k if k not in lnames else k + "_r"
        out[name] = v[idx_c]
    joined = VecTable(out, match)
    if max_count != left.capacity:
        joined = compact(joined, max_count)
    return joined


def _joint_key_bounds(left: VecTable, right: VecTable, left_on: Sequence[str],
                      right_on: Sequence[str]) -> Tuple[List[jax.Array], List[jax.Array]]:
    """Shared per-column (lo, size) over the valid rows of BOTH join sides —
    packing must agree across sides or equal keys stop matching."""
    big = jnp.iinfo(jnp.int32).max
    lows, sizes = [], []
    for lk, rk in zip(left_on, right_on):
        la, ra = _int_key(left.cols[lk]), _int_key(right.cols[rk])
        lo = jnp.minimum(jnp.min(jnp.where(left.valid, la, big)),
                         jnp.min(jnp.where(right.valid, ra, big)))
        hi = jnp.maximum(jnp.max(jnp.where(left.valid, la, -big)),
                         jnp.max(jnp.where(right.valid, ra, -big)))
        lows.append(lo)
        sizes.append(jnp.maximum(hi - lo + 1, 1))
    return lows, sizes


def topk(t: VecTable, keys: Sequence[str], ascending: Sequence[bool], k: int) -> VecTable:
    if len(keys) == 1 and not jnp.issubdtype(t.cols[keys[0]].dtype, jnp.bool_):
        # single numeric key: jax.lax.top_k over a validity-masked score
        # instead of a full lexsort + gather.  top_k breaks ties by lowest
        # index, matching the stable sort.  Ascending ints flip via bitwise
        # NOT (~x = -x-1): strictly decreasing over the FULL int32 range,
        # unlike negation which overflows at INT32_MIN.  (A valid key whose
        # score equals the sentinel can still lose its slot to an earlier
        # invalid row; the sort path remains the general-purpose tier.)
        arr = t.cols[keys[0]]
        k_eff = min(int(k), t.capacity)
        if jnp.issubdtype(arr.dtype, jnp.integer):
            sentinel = jnp.iinfo(jnp.int32).min
            score = jnp.invert(arr.astype(jnp.int32)) if ascending[0] else arr.astype(jnp.int32)
        else:
            sentinel = -_F32_INF
            score = jnp.negative(arr) if ascending[0] else arr
        score = jnp.where(t.valid, score, sentinel)
        _, idx = jax.lax.top_k(score, k_eff)
        return VecTable({kk: v[idx] for kk, v in t.cols.items()}, t.valid[idx])
    s = sort_by_key(t, keys, ascending)
    return VecTable({kk: v[:k] for kk, v in s.cols.items()}, s.valid[:k])


def concat(tables: Sequence[VecTable]) -> VecTable:
    cols = {k: jnp.concatenate([t.cols[k] for t in tables]) for k in tables[0].cols}
    valid = jnp.concatenate([t.valid for t in tables])
    return VecTable(cols, valid)


def split(t: VecTable, n: int) -> List[VecTable]:
    cap = t.capacity
    if cap % n != 0:
        raise ValueError(f"capacity {cap} not divisible by {n}")
    c = cap // n
    return [
        VecTable({k: v[i * c:(i + 1) * c] for k, v in t.cols.items()},
                 t.valid[i * c:(i + 1) * c])
        for i in range(n)
    ]


def limit(t: VecTable, k: int) -> VecTable:
    c = compact(t)
    keep = jnp.arange(t.capacity) < k
    return VecTable(c.cols, c.valid & keep)
