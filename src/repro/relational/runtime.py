"""VecTable: the physical ``Vec⟨tuple⟩`` collection on JAX.

A VecTable is a struct-of-arrays block with a static capacity and a
validity mask.  All relational operators are pure functions VecTable →
VecTable with static output shapes (XLA requirement); cardinality lives in
the mask.  This file is the executable meaning of the ``vec.*`` IR flavor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.expr import AggSpec, Expr, evaluate

_I64_MAX = np.iinfo(np.int64).max
_F32_INF = np.float32(np.inf)


@jax.tree_util.register_pytree_node_class
@dataclass
class VecTable:
    cols: Dict[str, jax.Array]
    valid: jax.Array  # bool (cap,)

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.cols))
        return tuple(self.cols[n] for n in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(cols=dict(zip(names, children[:-1])), valid=children[-1])

    # -- basics ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    @staticmethod
    def from_numpy(data: Mapping[str, np.ndarray], capacity: Optional[int] = None) -> "VecTable":
        n = len(next(iter(data.values())))
        cap = capacity or n
        if cap < n:
            raise ValueError(f"capacity {cap} < rows {n}")
        cols = {}
        for k, v in data.items():
            v = np.asarray(v)
            pad = np.zeros((cap - n,) + v.shape[1:], dtype=v.dtype)
            cols[k] = jnp.asarray(np.concatenate([v, pad]))
        valid = jnp.asarray(np.arange(cap) < n)
        return VecTable(cols, valid)

    def to_numpy(self) -> Dict[str, np.ndarray]:
        mask = np.asarray(self.valid)
        return {k: np.asarray(v)[mask] for k, v in self.cols.items()}

    def astuple_cols(self, names: Sequence[str]) -> List[jax.Array]:
        return [self.cols[n] for n in names]


# ---------------------------------------------------------------------------
# operators (pure functions — the vec.* flavor semantics)
# ---------------------------------------------------------------------------


def mask_select(t: VecTable, pred: Expr) -> VecTable:
    """Predicated (late-materialized) selection: narrow the mask only."""
    p = evaluate(pred, t.cols, jnp)
    return VecTable(t.cols, t.valid & p)


def proj(t: VecTable, names: Sequence[str]) -> VecTable:
    return VecTable({n: t.cols[n] for n in names}, t.valid)


def exproj(t: VecTable, exprs: Sequence[Tuple[str, Expr]]) -> VecTable:
    cap = t.capacity
    out = {}
    for name, e in exprs:
        v = evaluate(e, t.cols, jnp)
        if jnp.ndim(v) == 0:
            v = jnp.full((cap,), v)
        out[name] = v
    return VecTable(out, t.valid)


def _masked(fn: str, arr: jax.Array, valid: jax.Array) -> jax.Array:
    if fn == "count":
        return jnp.sum(valid.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32))
    if jnp.issubdtype(arr.dtype, jnp.integer) or jnp.issubdtype(arr.dtype, jnp.bool_):
        arr = arr.astype(jnp.float32)
    if fn == "sum":
        return jnp.sum(jnp.where(valid, arr, 0))
    if fn == "min":
        return jnp.min(jnp.where(valid, arr, _F32_INF))
    if fn == "max":
        return jnp.max(jnp.where(valid, arr, -_F32_INF))
    raise ValueError(fn)


def aggr(t: VecTable, aggs: Sequence[AggSpec]) -> Dict[str, jax.Array]:
    """Masked scalar aggregation → Single⟨aggs⟩ (dict of scalars)."""
    out = {}
    for a in aggs:
        arr = evaluate(a.expr, t.cols, jnp) if a.fn != "count" else t.valid
        if jnp.ndim(arr) == 0:
            arr = jnp.full((t.capacity,), arr)
        out[a.name] = _masked(a.fn, arr, t.valid)
    return out


def combine_partials(partials: Sequence[Dict[str, jax.Array]], aggs: Sequence[AggSpec]) -> Dict[str, jax.Array]:
    out = {}
    for a in aggs:
        vals = jnp.stack([p[a.name] for p in partials])
        fn = a.combine_fn
        out[a.name] = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[fn](vals)
    return out


def _sort_perm(t: VecTable, keys: Sequence[str], ascending: Sequence[bool]) -> jax.Array:
    """Permutation: valid rows first, ordered by keys (stable)."""
    arrays = []
    for k, asc in zip(reversed(list(keys)), reversed(list(ascending))):
        arr = t.cols[k]
        if not asc:
            if jnp.issubdtype(arr.dtype, jnp.bool_):
                arr = ~arr
            else:
                arr = -arr.astype(jnp.float32) if not jnp.issubdtype(arr.dtype, jnp.integer) else -arr
        arrays.append(arr)
    arrays.append(~t.valid)  # primary: valid first
    return jnp.lexsort(tuple(arrays), axis=0)


def sort_by_key(t: VecTable, keys: Sequence[str], ascending: Optional[Sequence[bool]] = None) -> VecTable:
    asc = list(ascending or [True] * len(keys))
    perm = _sort_perm(t, keys, asc)
    return VecTable({k: v[perm] for k, v in t.cols.items()}, t.valid[perm])


def compact(t: VecTable, max_count: Optional[int] = None) -> VecTable:
    """Densify valid rows to the front — O(n) prefix-sum scatter.

    Position of each valid row is its prefix count of valid rows; rows
    beyond ``max_count`` (and all invalid rows) scatter out of bounds and
    are dropped.  Replaces the old argsort(~valid) shuffle (O(n log n)).
    """
    out_cap = int(max_count) if max_count is not None else t.capacity
    valid_i = t.valid.astype(jnp.int32)
    pos = jnp.cumsum(valid_i) - 1
    idx = jnp.where(t.valid, pos, out_cap)  # invalid rows → out of bounds
    n = jnp.minimum(jnp.sum(valid_i), out_cap)

    def scatter(col: jax.Array) -> jax.Array:
        out = jnp.zeros((out_cap,) + col.shape[1:], col.dtype)
        return out.at[idx].set(col, mode="drop")

    cols = {k: scatter(v) for k, v in t.cols.items()}
    valid = jnp.arange(out_cap) < n
    return VecTable(cols, valid)


def dict_encode(t: VecTable, cols: Sequence[str], modes: Sequence[str],
                tables: Sequence, lows: Sequence[int],
                cards: Sequence[int]) -> VecTable:
    """Per-column value→rank encoding against static sorted dictionaries.

    ``mode == "remap"``: one gather through a span-sized rank table whose
    out-of-dictionary slots already hold the sentinel.  Otherwise a
    searchsorted rank lookup.  Out-of-dictionary values (possible on join
    probe sides) get the sentinel rank ``card`` — one past every declared
    rank domain, so downstream direct tables treat them as out-of-domain
    rather than aliasing a real bucket.
    """
    out = dict(t.cols)
    for c, mode, table, lo, card in zip(cols, modes, tables, lows, cards):
        arr = t.cols[c]
        tab = jnp.asarray(table)
        if mode == "remap":
            span = tab.shape[0]
            idx = arr.astype(jnp.int32) - jnp.int32(lo)
            ok = (idx >= 0) & (idx < span)
            ranks = tab[jnp.clip(idx, 0, span - 1)]
            out[c] = jnp.where(ok, ranks, jnp.int32(card)).astype(jnp.int32)
        else:
            tab = tab.astype(arr.dtype)
            i = jnp.searchsorted(tab, arr)
            ic = jnp.clip(i, 0, card - 1)
            out[c] = jnp.where(tab[ic] == arr, ic,
                               jnp.int32(card)).astype(jnp.int32)
    return VecTable(out, t.valid)


def dict_decode(t: VecTable, cols: Sequence[str], tables: Sequence) -> VecTable:
    """Gather ranks back to raw values through the sorted value tables.

    Sentinel/invalid ranks clip to the last dictionary entry — such rows
    are already masked out by validity."""
    out = dict(t.cols)
    for c, table in zip(cols, tables):
        tab = jnp.asarray(table)
        ranks = jnp.clip(t.cols[c].astype(jnp.int32), 0, tab.shape[0] - 1)
        out[c] = tab[ranks]
    return VecTable(out, t.valid)


#: composite-key packings with more buckets than this raise instead of
#: silently colliding in the 32-bit accumulator
_PACK_LIMIT = 1 << 31


def _composite_key(t: VecTable, keys: Sequence[str],
                   key_domains: Optional[Sequence[Tuple[int, int]]] = None,
                   lows: Optional[Sequence[jax.Array]] = None,
                   sizes: Optional[Sequence[jax.Array]] = None) -> jax.Array:
    """Pack key columns into one i32, preserving lexicographic order.

    Packing needs per-column value bounds.  Three sources, in order:
    static ``key_domains`` from the catalog (checked against the 32-bit
    budget — overpacking raises instead of colliding); dynamic
    ``lows``/``sizes`` traced from the data (collision-free whenever the
    actual domain product fits 32 bits); neither → single column only.
    """
    if key_domains is not None:
        n_buckets = 1
        for lo, hi in key_domains:
            n_buckets *= int(hi) - int(lo) + 1
        if n_buckets > _PACK_LIMIT:
            raise ValueError(
                f"composite key domain for {tuple(keys)} has {n_buckets} "
                f"buckets and cannot be packed into a 32-bit accumulator; "
                "reduce the key domain or use a single integer key column")
        acc = jnp.zeros((t.capacity,), jnp.int32)
        for k, (lo, hi) in zip(keys, key_domains):
            size = int(hi) - int(lo) + 1
            arr = _int_key(t.cols[k])
            arr = jnp.clip(arr - jnp.int32(lo), 0, size - 1)
            acc = acc * jnp.int32(size) + arr
        return acc
    if lows is not None and sizes is not None:
        acc = jnp.zeros((t.capacity,), jnp.int32)
        for k, lo, size in zip(keys, lows, sizes):
            arr = _int_key(t.cols[k])
            acc = acc * size.astype(jnp.int32) + (arr - lo.astype(jnp.int32))
        return acc
    if len(keys) == 1:
        return _int_key(t.cols[keys[0]])
    raise ValueError(
        f"cannot pack composite key {tuple(keys)} without per-column domain "
        "bounds; provide catalog key domains (see Catalog.stats) or derive "
        "dynamic bounds from the data")


def _int_key(arr: jax.Array) -> jax.Array:
    if jnp.issubdtype(arr.dtype, jnp.floating):
        arr = arr.view(jnp.int32) if arr.dtype == jnp.float32 else arr.astype(jnp.int32)
    return arr.astype(jnp.int32)


def _key_change(t: VecTable, keys: Sequence[str]) -> jax.Array:
    """Per-row "starts a new group" flags for a key-sorted block.

    Per-column comparison against the previous row — collision-free for any
    key dtype, domain, and column count (unlike composite-key packing)."""
    change = jnp.zeros((t.capacity,), bool).at[0].set(True)
    for k in keys:
        col = t.cols[k]
        change = change | (col != jnp.concatenate([col[:1], col[:-1]]))
    return change & t.valid


def group_agg_sorted(t: VecTable, keys: Sequence[str], aggs: Sequence[AggSpec],
                     max_groups: int) -> VecTable:
    """Grouped aggregation over a key-sorted block via segment reduction.

    The TPU-native replacement of hash aggregation: valid rows are sorted by
    key (invalid at the end), segment ids are the prefix count of key
    changes, and each agg is a masked ``jax.ops.segment_*``.
    """
    change = _key_change(t, keys)
    seg = jnp.cumsum(change.astype(jnp.int32)) - 1  # -1 before first valid group
    seg = jnp.where(t.valid, seg, max_groups)  # dump invalid rows
    seg = jnp.clip(seg, 0, max_groups)

    out_cols: Dict[str, jax.Array] = {}
    for k in keys:
        out_cols[k] = jax.ops.segment_max(
            jnp.where(t.valid, t.cols[k], jnp.zeros((), t.cols[k].dtype)),
            seg, num_segments=max_groups + 1)[:max_groups]
    for a in aggs:
        red = _segment_agg(a, t.cols, t.valid, seg, max_groups + 1)[:max_groups]
        out_cols[a.name] = red
    n_groups = jnp.sum(change.astype(jnp.int32))
    group_valid = jnp.arange(max_groups) < n_groups
    return VecTable(out_cols, group_valid)


def _segment_agg(a: AggSpec, cols: Mapping[str, jax.Array], valid: jax.Array,
                 seg: jax.Array, num_segments: int) -> jax.Array:
    """One masked segment reduction (shared by the sorted and direct tiers)."""
    if a.fn == "count":
        return jax.ops.segment_sum(valid.astype(jnp.int32), seg,
                                   num_segments=num_segments)
    arr = evaluate(a.expr, cols, jnp)
    if jnp.issubdtype(arr.dtype, jnp.integer) or jnp.issubdtype(arr.dtype, jnp.bool_):
        arr = arr.astype(jnp.float32)
    if a.fn == "sum":
        return jax.ops.segment_sum(jnp.where(valid, arr, 0), seg,
                                   num_segments=num_segments)
    if a.fn == "min":
        return jax.ops.segment_min(jnp.where(valid, arr, _F32_INF), seg,
                                   num_segments=num_segments)
    if a.fn == "max":
        return jax.ops.segment_max(jnp.where(valid, arr, -_F32_INF), seg,
                                   num_segments=num_segments)
    raise ValueError(a.fn)


def bucket_ids(t: VecTable, keys: Sequence[str],
               key_domains: Sequence[Tuple[int, int]]) -> jax.Array:
    """Dense bucket id per row: lexicographic rank in the static key domain."""
    acc = jnp.zeros((t.capacity,), jnp.int32)
    for k, (lo, hi) in zip(keys, key_domains):
        size = int(hi) - int(lo) + 1
        arr = jnp.clip(_int_key(t.cols[k]) - jnp.int32(lo), 0, size - 1)
        acc = acc * jnp.int32(size) + arr
    return acc


def decode_bucket_keys(keys: Sequence[str], key_domains: Sequence[Tuple[int, int]],
                       dtypes: Sequence[Any], num_buckets: int) -> Dict[str, jax.Array]:
    """Key column values for each dense bucket id (inverse of bucket_ids)."""
    b = jnp.arange(num_buckets, dtype=jnp.int32)
    sizes = [int(hi) - int(lo) + 1 for lo, hi in key_domains]
    out: Dict[str, jax.Array] = {}
    stride = num_buckets
    for k, (lo, _), size, dt in zip(keys, key_domains, sizes, dtypes):
        stride //= size
        vals = (b // stride) % size + jnp.int32(lo)
        out[k] = vals.astype(dt)
    return out


def group_agg_direct(t: VecTable, keys: Sequence[str], aggs: Sequence[AggSpec],
                     max_groups: int, key_domains: Sequence[Tuple[int, int]],
                     num_buckets: int, pred: Optional[Expr] = None) -> VecTable:
    """Grouped aggregation WITHOUT sorting: dense-bucket segment reduction.

    When the catalog bounds the composite key domain, every row's group is a
    static function of its key values — segment-reduce straight into
    ``num_buckets`` dense buckets (O(n), no lexsort, no per-column gather),
    then prefix-sum-compact the non-empty buckets to ``max_groups``.  Bucket
    order is lexicographic key order, so the output matches
    ``sort_by_key + group_agg_sorted`` row for row.  An optional fused
    predicate narrows validity in the same pass (MaskSelect fusion).
    """
    valid = t.valid
    if pred is not None:
        valid = valid & evaluate(pred, t.cols, jnp)
    bid = bucket_ids(t, keys, key_domains)
    seg = jnp.where(valid, bid, num_buckets)  # dump invalid rows

    counts = jax.ops.segment_sum(valid.astype(jnp.int32), seg,
                                 num_segments=num_buckets + 1)[:num_buckets]
    out_cols = decode_bucket_keys(keys, key_domains,
                                  [t.cols[k].dtype for k in keys], num_buckets)
    for a in aggs:
        out_cols[a.name] = _segment_agg(a, t.cols, valid, seg,
                                        num_buckets + 1)[:num_buckets]
    buckets = VecTable(out_cols, counts > 0)
    return compact(buckets, max_groups)


def merge_join_sorted(left: VecTable, right: VecTable, left_on: Sequence[str],
                      right_on: Sequence[str], max_count: int,
                      key_domains: Optional[Sequence[Tuple[int, int]]] = None,
                      ) -> VecTable:
    """PK-FK inner equi-join: ``right`` must be key-sorted with unique keys.

    searchsorted + gather — the TPU-native rewrite of Build/ProbeHTable.
    Multi-column keys are packed with catalog ``key_domains`` when the
    lowering provides them (static overflow check — overpacking raises),
    otherwise with bounds traced jointly from both sides (collision-free
    whenever the actual domain product fits the 32-bit accumulator).
    """
    if len(left_on) != 1 or len(right_on) != 1:
        if key_domains is not None:
            lk = _composite_key(left, left_on, key_domains=key_domains)
            rk = _composite_key(right, right_on, key_domains=key_domains)
        else:
            lows, sizes = _joint_key_bounds(left, right, left_on, right_on)
            lk = _composite_key(left, left_on, lows=lows, sizes=sizes)
            rk = _composite_key(right, right_on, lows=lows, sizes=sizes)
    else:
        lk = left.cols[left_on[0]].astype(jnp.int32)
        rk = right.cols[right_on[0]].astype(jnp.int32)
    sentinel = jnp.iinfo(jnp.int32).max
    rk = jnp.where(right.valid, rk, sentinel)
    idx = jnp.searchsorted(rk, lk)
    idx_c = jnp.clip(idx, 0, right.capacity - 1)
    match = (rk[idx_c] == lk) & left.valid

    out = dict(left.cols)
    lnames = set(left.cols)
    for k, v in right.cols.items():
        if k in right_on:
            continue
        name = k if k not in lnames else k + "_r"
        out[name] = v[idx_c]
    joined = VecTable(out, match)
    if max_count != left.capacity:
        joined = compact(joined, max_count)
    return joined


def _bucket_ids_checked(t: VecTable, keys: Sequence[str],
                        key_domains: Sequence[Tuple[int, int]],
                        ) -> Tuple[jax.Array, jax.Array]:
    """Dense bucket id per row + an in-domain mask.

    Unlike :func:`bucket_ids` (which clips — fine for grouping, where the
    catalog domains are exact by construction), joins must KNOW whether a
    key was inside the declared domain: a clipped out-of-domain probe key
    would silently alias the boundary bucket and fabricate a match.
    """
    acc = jnp.zeros((t.capacity,), jnp.int32)
    ok = jnp.ones((t.capacity,), bool)
    for k, (lo, hi) in zip(keys, key_domains):
        size = int(hi) - int(lo) + 1
        arr = _int_key(t.cols[k]) - jnp.int32(lo)
        ok = ok & (arr >= 0) & (arr < size)
        acc = acc * jnp.int32(size) + jnp.clip(arr, 0, size - 1)
    return acc, ok


def _direct_probe(left: VecTable, right: VecTable, right_on: Sequence[str],
                  num_buckets: int, lbid: jax.Array, lok: jax.Array,
                  rbid: jax.Array, rok: jax.Array,
                  columns: Optional[Sequence[str]] = None) -> VecTable:
    """Dense direct-table probe shared by the hash-join tiers.

    Build: scatter each valid right row's index into its key bucket with a
    ``min`` combiner — deterministic under duplicate build keys (the lowest
    row index wins, matching searchsorted's first occurrence).  Probe: one
    O(1) gather per left row.  Bucket ids are collision-free within the
    domain (bijective packing), so no key re-verification is needed; rows
    outside the domain are masked via ``lok``/``rok``.  Output rows stay at
    ``left.capacity`` (caller compacts).  ``columns`` optionally restricts
    which right columns are gathered (fusion gathers only what the
    downstream aggregation reads).
    """
    cap_r = right.capacity
    slot = jnp.where(rok & right.valid, rbid, num_buckets)
    table = jnp.full((num_buckets + 1,), cap_r, jnp.int32)
    table = table.at[slot].min(jnp.arange(cap_r, dtype=jnp.int32), mode="drop")
    idx = table[jnp.clip(lbid, 0, num_buckets - 1)]
    match = left.valid & lok & (idx < cap_r)
    idx_c = jnp.minimum(idx, cap_r - 1)
    out = dict(left.cols)
    lnames = set(left.cols)
    for k, v in right.cols.items():
        if k in right_on or (columns is not None and k not in columns):
            continue
        name = k if k not in lnames else k + "_r"
        out[name] = v[idx_c]
    return VecTable(out, match)


def hash_join_direct(left: VecTable, right: VecTable, left_on: Sequence[str],
                     right_on: Sequence[str], max_count: int,
                     key_domains: Optional[Sequence[Tuple[int, int]]] = None,
                     num_buckets: Optional[int] = None) -> VecTable:
    """Sort-free PK-FK inner equi-join via a dense direct table.

    The O(n) sibling of :func:`merge_join_sorted` — no sort of the build
    side, no searchsorted: when the composite key domain is bounded, the
    build side scatters into a dense table indexed by bucket id and every
    probe is a single gather (the dense-bucket analogue of BuildHTable /
    ProbeHTable, exactly as GroupAggDirect is to hash aggregation).

    Two variants:

    * static ``key_domains`` (catalog-derived): bucket ids are checked
      against the declared domain, out-of-domain rows never match;
    * dynamic (``key_domains=None``): per-column bounds are traced jointly
      from both sides; when the traced domain product exceeds the static
      ``num_buckets`` budget the instruction falls back to the sorted merge
      join *inside* the trace (``lax.cond``), so the plan stays valid for
      any data.
    """
    if key_domains is not None:
        nb = 1
        for lo, hi in key_domains:
            nb *= int(hi) - int(lo) + 1
        lbid, lok = _bucket_ids_checked(left, left_on, key_domains)
        rbid, rok = _bucket_ids_checked(right, right_on, key_domains)
        joined = _direct_probe(left, right, right_on, nb, lbid, lok, rbid, rok)
        if max_count != left.capacity:
            joined = compact(joined, max_count)
        return joined

    if num_buckets is None:
        raise ValueError("hash_join_direct without key_domains needs a "
                         "static num_buckets budget")
    nb = int(num_buckets)
    lows, sizes = _joint_key_bounds(left, right, left_on, right_on)
    prod = jnp.ones((), jnp.float32)
    for s in sizes:
        prod = prod * s.astype(jnp.float32)  # f32: no i32 overflow on product
    fits = prod <= jnp.float32(nb)

    def _dyn_bid(t: VecTable, keys: Sequence[str]) -> jax.Array:
        acc = jnp.zeros((t.capacity,), jnp.int32)
        for k, lo, size in zip(keys, lows, sizes):
            arr = _int_key(t.cols[k]) - lo.astype(jnp.int32)
            acc = acc * size.astype(jnp.int32) \
                + jnp.clip(arr, 0, size.astype(jnp.int32) - 1)
        return acc

    def _direct(args):
        l, r = args
        # joint bounds cover every valid row of both sides by construction
        lbid = _dyn_bid(l, left_on)
        rbid = _dyn_bid(r, right_on)
        lok = jnp.ones((l.capacity,), bool)
        rok = jnp.ones((r.capacity,), bool)
        return _direct_probe(l, r, right_on, nb, lbid, lok, rbid, rok)

    def _sorted(args):
        l, r = args
        rs = sort_by_key(r, right_on)
        return merge_join_sorted(l, rs, left_on, right_on, l.capacity)

    joined = jax.lax.cond(fits, _direct, _sorted, (left, right))
    if max_count != left.capacity:
        joined = compact(joined, max_count)
    return joined


def fused_join_group_agg(left: VecTable, right: VecTable,
                         left_on: Sequence[str], right_on: Sequence[str],
                         join_key_domains: Sequence[Tuple[int, int]],
                         join_num_buckets: int, keys: Sequence[str],
                         aggs: Sequence[AggSpec], max_groups: int,
                         key_domains: Sequence[Tuple[int, int]],
                         num_buckets: int, pred: Optional[Expr] = None,
                         ) -> VecTable:
    """Whole-pipeline select→join→group in one pass, join never materialized.

    Predicate, direct-table probe, bucket id and all accumulators are
    computed per input row; only the right columns the grouping actually
    reads are gathered, and the joined rows go straight into the dense
    grouped reduction without an intermediate compact.
    """
    valid = left.valid
    if pred is not None:
        valid = valid & evaluate(pred, left.cols, jnp)
    lbid, lok = _bucket_ids_checked(left, left_on, join_key_domains)
    rbid, rok = _bucket_ids_checked(right, right_on, join_key_domains)
    needed = set(keys)
    for a in aggs:
        if a.fn != "count":
            needed.update(a.expr.fields())
    joined = _direct_probe(VecTable(left.cols, valid), right, right_on,
                           join_num_buckets, lbid, lok, rbid, rok,
                           columns=sorted(needed))
    return group_agg_direct(joined, keys, aggs, max_groups, key_domains,
                            num_buckets)


def _joint_key_bounds(left: VecTable, right: VecTable, left_on: Sequence[str],
                      right_on: Sequence[str]) -> Tuple[List[jax.Array], List[jax.Array]]:
    """Shared per-column (lo, size) over the valid rows of BOTH join sides —
    packing must agree across sides or equal keys stop matching."""
    big = jnp.iinfo(jnp.int32).max
    lows, sizes = [], []
    for lk, rk in zip(left_on, right_on):
        la, ra = _int_key(left.cols[lk]), _int_key(right.cols[rk])
        lo = jnp.minimum(jnp.min(jnp.where(left.valid, la, big)),
                         jnp.min(jnp.where(right.valid, ra, big)))
        hi = jnp.maximum(jnp.max(jnp.where(left.valid, la, -big)),
                         jnp.max(jnp.where(right.valid, ra, -big)))
        lows.append(lo)
        sizes.append(jnp.maximum(hi - lo + 1, 1))
    return lows, sizes


def topk(t: VecTable, keys: Sequence[str], ascending: Sequence[bool], k: int) -> VecTable:
    if len(keys) == 1 and not jnp.issubdtype(t.cols[keys[0]].dtype, jnp.bool_):
        # single numeric key: jax.lax.top_k over a validity-masked score
        # instead of a full lexsort + gather.  top_k breaks ties by lowest
        # index, matching the stable sort.  Ascending ints flip via bitwise
        # NOT (~x = -x-1): strictly decreasing over the FULL int32 range,
        # unlike negation which overflows at INT32_MIN.  (A valid key whose
        # score equals the sentinel can still lose its slot to an earlier
        # invalid row; the sort path remains the general-purpose tier.)
        arr = t.cols[keys[0]]
        k_eff = min(int(k), t.capacity)
        if jnp.issubdtype(arr.dtype, jnp.integer):
            sentinel = jnp.iinfo(jnp.int32).min
            score = jnp.invert(arr.astype(jnp.int32)) if ascending[0] else arr.astype(jnp.int32)
        else:
            sentinel = -_F32_INF
            score = jnp.negative(arr) if ascending[0] else arr
        score = jnp.where(t.valid, score, sentinel)
        _, idx = jax.lax.top_k(score, k_eff)
        return VecTable({kk: v[idx] for kk, v in t.cols.items()}, t.valid[idx])
    s = sort_by_key(t, keys, ascending)
    return VecTable({kk: v[:k] for kk, v in s.cols.items()}, s.valid[:k])


def concat(tables: Sequence[VecTable]) -> VecTable:
    cols = {k: jnp.concatenate([t.cols[k] for t in tables]) for k in tables[0].cols}
    valid = jnp.concatenate([t.valid for t in tables])
    return VecTable(cols, valid)


def split(t: VecTable, n: int) -> List[VecTable]:
    cap = t.capacity
    if cap % n != 0:
        raise ValueError(f"capacity {cap} not divisible by {n}")
    c = cap // n
    return [
        VecTable({k: v[i * c:(i + 1) * c] for k, v in t.cols.items()},
                 t.valid[i * c:(i + 1) * c])
        for i in range(n)
    ]


def limit(t: VecTable, k: int) -> VecTable:
    c = compact(t)
    keep = jnp.arange(t.capacity) < k
    return VecTable(c.cols, c.valid & keep)


# ---------------------------------------------------------------------------
# incremental (streaming) state: init / merge across micro-batches
# ---------------------------------------------------------------------------
#
# The streaming target (core/passes/lower_stream.py) splits a lowered plan
# at its terminal aggregation: each micro-batch produces a *partial*
# aggregate (the batch segment reuses the ordinary grouped/scalar operators
# above), and the running state is folded forward with the functions below.
# Every AggSpec is self-decomposable (count combines with sum), so
# merge-of-partials is itself a grouped aggregation over the concatenated
# (state, delta) block — the GroupAggDirect dense-bucket accumulators carry
# straight across micro-batches instead of being recomputed.


def _merge_aggs(aggs: Sequence[AggSpec]) -> List[AggSpec]:
    """The partial-combining AggSpecs: ``fn=combine_fn`` over the partial
    column itself (sum-of-sums, min-of-mins, sum-of-counts)."""
    from ..core.expr import Col

    return [AggSpec(a.combine_fn, Col(a.name), a.name) for a in aggs]


def empty_grouped_state(template: VecTable) -> VecTable:
    """The identity element for grouped merge: same schema/capacity as a
    partial-aggregate block, zero valid rows."""
    return VecTable({k: jnp.zeros_like(v) for k, v in template.cols.items()},
                    jnp.zeros_like(template.valid))


def merge_grouped_partials(state: VecTable, delta: VecTable,
                           keys: Sequence[str], aggs: Sequence[AggSpec],
                           max_groups: int,
                           key_domains: Optional[Sequence[Tuple[int, int]]] = None,
                           num_buckets: Optional[int] = None) -> VecTable:
    """Fold one micro-batch's grouped partial aggregate into the running
    state (both capacity ``max_groups``) — the streaming step/merge op.

    With catalog ``key_domains`` the merge is the sort-free dense-bucket
    tier (O(state+delta), the carried GroupAggDirect accumulator); without
    them it falls back to sort + segment reduction.  Aggregate columns are
    cast back to the delta's dtypes so integer counts stay integers across
    arbitrarily many merges.
    """
    both = concat([state, delta])
    merge_aggs = _merge_aggs(aggs)
    if key_domains is not None and num_buckets is not None:
        merged = group_agg_direct(both, keys, merge_aggs, max_groups,
                                  key_domains, int(num_buckets))
    else:
        merged = group_agg_sorted(sort_by_key(both, keys), keys, merge_aggs,
                                  max_groups)
    cols = {k: merged.cols[k].astype(delta.cols[k].dtype)
            for k in merged.cols}
    return VecTable(cols, merged.valid)


def merge_scalar_partials(state: Dict[str, jax.Array],
                          delta: Dict[str, jax.Array],
                          aggs: Sequence[AggSpec]) -> Dict[str, jax.Array]:
    """Fold one micro-batch's scalar partial aggregate (Single) into the
    running state, dtype-preserving (counts stay integral)."""
    out: Dict[str, jax.Array] = {}
    for a in aggs:
        fn = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}[a.combine_fn]
        out[a.name] = fn(state[a.name], delta[a.name])
    return out
