"""VecTable: the physical ``Vec⟨tuple⟩`` collection on JAX.

A VecTable is a struct-of-arrays block with a static capacity and a
validity mask.  All relational operators are pure functions VecTable →
VecTable with static output shapes (XLA requirement); cardinality lives in
the mask.  This file is the executable meaning of the ``vec.*`` IR flavor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.expr import AggSpec, Expr, evaluate

_I64_MAX = np.iinfo(np.int64).max
_F32_INF = np.float32(np.inf)


@jax.tree_util.register_pytree_node_class
@dataclass
class VecTable:
    cols: Dict[str, jax.Array]
    valid: jax.Array  # bool (cap,)

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.cols))
        return tuple(self.cols[n] for n in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(cols=dict(zip(names, children[:-1])), valid=children[-1])

    # -- basics ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    @staticmethod
    def from_numpy(data: Mapping[str, np.ndarray], capacity: Optional[int] = None) -> "VecTable":
        n = len(next(iter(data.values())))
        cap = capacity or n
        if cap < n:
            raise ValueError(f"capacity {cap} < rows {n}")
        cols = {}
        for k, v in data.items():
            v = np.asarray(v)
            pad = np.zeros((cap - n,) + v.shape[1:], dtype=v.dtype)
            cols[k] = jnp.asarray(np.concatenate([v, pad]))
        valid = jnp.asarray(np.arange(cap) < n)
        return VecTable(cols, valid)

    def to_numpy(self) -> Dict[str, np.ndarray]:
        mask = np.asarray(self.valid)
        return {k: np.asarray(v)[mask] for k, v in self.cols.items()}

    def astuple_cols(self, names: Sequence[str]) -> List[jax.Array]:
        return [self.cols[n] for n in names]


# ---------------------------------------------------------------------------
# operators (pure functions — the vec.* flavor semantics)
# ---------------------------------------------------------------------------


def mask_select(t: VecTable, pred: Expr) -> VecTable:
    """Predicated (late-materialized) selection: narrow the mask only."""
    p = evaluate(pred, t.cols, jnp)
    return VecTable(t.cols, t.valid & p)


def proj(t: VecTable, names: Sequence[str]) -> VecTable:
    return VecTable({n: t.cols[n] for n in names}, t.valid)


def exproj(t: VecTable, exprs: Sequence[Tuple[str, Expr]]) -> VecTable:
    cap = t.capacity
    out = {}
    for name, e in exprs:
        v = evaluate(e, t.cols, jnp)
        if jnp.ndim(v) == 0:
            v = jnp.full((cap,), v)
        out[name] = v
    return VecTable(out, t.valid)


def _masked(fn: str, arr: jax.Array, valid: jax.Array) -> jax.Array:
    if fn == "count":
        return jnp.sum(valid.astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32))
    if jnp.issubdtype(arr.dtype, jnp.integer) or jnp.issubdtype(arr.dtype, jnp.bool_):
        arr = arr.astype(jnp.float32)
    if fn == "sum":
        return jnp.sum(jnp.where(valid, arr, 0))
    if fn == "min":
        return jnp.min(jnp.where(valid, arr, _F32_INF))
    if fn == "max":
        return jnp.max(jnp.where(valid, arr, -_F32_INF))
    raise ValueError(fn)


def aggr(t: VecTable, aggs: Sequence[AggSpec]) -> Dict[str, jax.Array]:
    """Masked scalar aggregation → Single⟨aggs⟩ (dict of scalars)."""
    out = {}
    for a in aggs:
        arr = evaluate(a.expr, t.cols, jnp) if a.fn != "count" else t.valid
        if jnp.ndim(arr) == 0:
            arr = jnp.full((t.capacity,), arr)
        out[a.name] = _masked(a.fn, arr, t.valid)
    return out


def combine_partials(partials: Sequence[Dict[str, jax.Array]], aggs: Sequence[AggSpec]) -> Dict[str, jax.Array]:
    out = {}
    for a in aggs:
        vals = jnp.stack([p[a.name] for p in partials])
        fn = a.combine_fn
        out[a.name] = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[fn](vals)
    return out


def _sort_perm(t: VecTable, keys: Sequence[str], ascending: Sequence[bool]) -> jax.Array:
    """Permutation: valid rows first, ordered by keys (stable)."""
    arrays = []
    for k, asc in zip(reversed(list(keys)), reversed(list(ascending))):
        arr = t.cols[k]
        if not asc:
            if jnp.issubdtype(arr.dtype, jnp.bool_):
                arr = ~arr
            else:
                arr = -arr.astype(jnp.float32) if not jnp.issubdtype(arr.dtype, jnp.integer) else -arr
        arrays.append(arr)
    arrays.append(~t.valid)  # primary: valid first
    return jnp.lexsort(tuple(arrays), axis=0)


def sort_by_key(t: VecTable, keys: Sequence[str], ascending: Optional[Sequence[bool]] = None) -> VecTable:
    asc = list(ascending or [True] * len(keys))
    perm = _sort_perm(t, keys, asc)
    return VecTable({k: v[perm] for k, v in t.cols.items()}, t.valid[perm])


def compact(t: VecTable, max_count: Optional[int] = None) -> VecTable:
    """Densify valid rows to the front (argsort on ~valid, stable)."""
    perm = jnp.argsort(~t.valid, stable=True)
    cols = {k: v[perm] for k, v in t.cols.items()}
    valid = t.valid[perm]
    if max_count is not None and max_count != t.capacity:
        cols = {k: v[:max_count] for k, v in cols.items()}
        valid = valid[:max_count]
    return VecTable(cols, valid)


def _composite_key(t: VecTable, keys: Sequence[str]) -> jax.Array:
    """Combine (small-domain) key columns into one i64 for segmenting."""
    acc = None
    for k in keys:
        arr = t.cols[k]
        if jnp.issubdtype(arr.dtype, jnp.floating):
            arr = arr.view(jnp.int32) if arr.dtype == jnp.float32 else arr.astype(jnp.int32)
        arr = arr.astype(jnp.int32)
        acc = arr if acc is None else acc * jnp.int32(65536) + (arr & jnp.int32(0xFFFF))
    return acc


def group_agg_sorted(t: VecTable, keys: Sequence[str], aggs: Sequence[AggSpec],
                     max_groups: int) -> VecTable:
    """Grouped aggregation over a key-sorted block via segment reduction.

    The TPU-native replacement of hash aggregation: valid rows are sorted by
    key (invalid at the end), segment ids are the prefix count of key
    changes, and each agg is a masked ``jax.ops.segment_*``.
    """
    ck = _composite_key(t, keys)
    prev = jnp.concatenate([ck[:1] - 1, ck[:-1]])
    change = (ck != prev) & t.valid
    seg = jnp.cumsum(change.astype(jnp.int32)) - 1  # -1 before first valid group
    seg = jnp.where(t.valid, seg, max_groups)  # dump invalid rows
    seg = jnp.clip(seg, 0, max_groups)

    out_cols: Dict[str, jax.Array] = {}
    for k in keys:
        out_cols[k] = jax.ops.segment_max(
            jnp.where(t.valid, t.cols[k], jnp.zeros((), t.cols[k].dtype)),
            seg, num_segments=max_groups + 1)[:max_groups]
    for a in aggs:
        if a.fn == "count":
            arr = t.valid.astype(jnp.int32)
            red = jax.ops.segment_sum(arr, seg, num_segments=max_groups + 1)[:max_groups]
        else:
            arr = evaluate(a.expr, t.cols, jnp)
            if jnp.issubdtype(arr.dtype, jnp.integer):
                arr = arr.astype(jnp.float32)
            if a.fn == "sum":
                red = jax.ops.segment_sum(jnp.where(t.valid, arr, 0), seg,
                                          num_segments=max_groups + 1)[:max_groups]
            elif a.fn == "min":
                red = jax.ops.segment_min(jnp.where(t.valid, arr, _F32_INF), seg,
                                          num_segments=max_groups + 1)[:max_groups]
            elif a.fn == "max":
                red = jax.ops.segment_max(jnp.where(t.valid, arr, -_F32_INF), seg,
                                          num_segments=max_groups + 1)[:max_groups]
            else:
                raise ValueError(a.fn)
        out_cols[a.name] = red
    n_groups = jnp.sum(change.astype(jnp.int32))
    group_valid = jnp.arange(max_groups) < n_groups
    return VecTable(out_cols, group_valid)


def merge_join_sorted(left: VecTable, right: VecTable, left_on: Sequence[str],
                      right_on: Sequence[str], max_count: int) -> VecTable:
    """PK-FK inner equi-join: ``right`` must be key-sorted with unique keys.

    searchsorted + gather — the TPU-native rewrite of Build/ProbeHTable.
    Multi-column keys are composited (16-bit fields); larger domains need a
    single integer key column (documented limitation of this backend).
    """
    if len(left_on) != 1 or len(right_on) != 1:
        lk = _composite_key(left, left_on)
        rk = _composite_key(right, right_on)
    else:
        lk = left.cols[left_on[0]].astype(jnp.int32)
        rk = right.cols[right_on[0]].astype(jnp.int32)
    sentinel = jnp.iinfo(jnp.int32).max
    rk = jnp.where(right.valid, rk, sentinel)
    idx = jnp.searchsorted(rk, lk)
    idx_c = jnp.clip(idx, 0, right.capacity - 1)
    match = (rk[idx_c] == lk) & left.valid

    out = dict(left.cols)
    lnames = set(left.cols)
    for k, v in right.cols.items():
        if k in right_on:
            continue
        name = k if k not in lnames else k + "_r"
        out[name] = v[idx_c]
    joined = VecTable(out, match)
    if max_count != left.capacity:
        joined = compact(joined, max_count)
    return joined


def topk(t: VecTable, keys: Sequence[str], ascending: Sequence[bool], k: int) -> VecTable:
    s = sort_by_key(t, keys, ascending)
    return VecTable({kk: v[:k] for kk, v in s.cols.items()}, s.valid[:k])


def concat(tables: Sequence[VecTable]) -> VecTable:
    cols = {k: jnp.concatenate([t.cols[k] for t in tables]) for k in tables[0].cols}
    valid = jnp.concatenate([t.valid for t in tables])
    return VecTable(cols, valid)


def split(t: VecTable, n: int) -> List[VecTable]:
    cap = t.capacity
    if cap % n != 0:
        raise ValueError(f"capacity {cap} not divisible by {n}")
    c = cap // n
    return [
        VecTable({k: v[i * c:(i + 1) * c] for k, v in t.cols.items()},
                 t.valid[i * c:(i + 1) * c])
        for i in range(n)
    ]


def limit(t: VecTable, k: int) -> VecTable:
    c = compact(t)
    keep = jnp.arange(t.capacity) < k
    return VecTable(c.cols, c.valid & keep)
