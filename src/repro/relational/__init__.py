"""Physical relational runtime: padded, masked column blocks on JAX.

The TPU adaptation of the paper's Volcano pipelines (DESIGN.md §2):
static-shape ``VecTable`` blocks with validity masks instead of dynamic
tuple streams; selection is late-materialized (predicated), joins are
sort-based, grouped aggregation is segment reduction.
"""

from .runtime import VecTable  # noqa: F401
