"""TPC-H workload: generator, queries (paper Figs. 2–4), numpy references.

Strings are dictionary-encoded i32 codes (TPU adaptation, DESIGN.md §2);
dates are epoch days.  The generator is a statistical look-alike of dbgen
(uniform value distributions per the spec's ranges) — adequate for
performance work and for validating plans against the numpy references,
which share the same tables.

Queries implemented: Q1, Q4, Q6, Q12, Q14, Q19 — the set reported across
the paper's three experiments.
"""

from __future__ import annotations

from datetime import date
from typing import Callable, Dict

import numpy as np

from ..core.expr import col, const
from ..frontends.dataflow import Context, Frame, avg_, count_, max_, min_, sum_

# ---------------------------------------------------------------------------
# dictionaries
# ---------------------------------------------------------------------------

RETURNFLAGS = ["A", "N", "R"]
LINESTATUS = ["O", "F"]
SHIPMODES = ["AIR", "AIR REG", "MAIL", "SHIP", "TRUCK", "RAIL", "FOB"]
SHIPINSTRUCT = ["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"]
PRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
BRANDS = [f"Brand#{i}{j}" for i in range(1, 6) for j in range(1, 6)]
CONTAINERS = [f"{a} {b}" for a in ["SM", "MED", "LG", "JUMBO", "WRAP"]
              for b in ["CASE", "BOX", "BAG", "JAR", "PKG", "PACK", "CAN", "DRUM"]]
N_PTYPES = 150
PROMO_PTYPES = 30  # codes < 30 mean "PROMO%"


def _day(y: int, m: int, d: int) -> int:
    return date(y, m, d).toordinal() - date(1970, 1, 1).toordinal()


def code(vocab, name) -> int:
    return vocab.index(name)


# ---------------------------------------------------------------------------
# generator
# ---------------------------------------------------------------------------


def generate(sf: float = 0.01, seed: int = 0) -> Dict[str, Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n_orders = max(64, int(150_000 * sf))
    n_part = max(32, int(200_000 * sf))

    # orders ---------------------------------------------------------------
    o_orderkey = np.arange(1, n_orders + 1, dtype=np.int32)
    o_orderdate = rng.integers(_day(1992, 1, 1), _day(1998, 8, 2), n_orders).astype(np.int32)
    o_orderpriority = rng.integers(0, len(PRIORITIES), n_orders).astype(np.int32)
    orders = {
        "o_orderkey": o_orderkey,
        "o_orderdate": o_orderdate,
        "o_orderpriority": o_orderpriority,
    }

    # part -----------------------------------------------------------------
    part = {
        "p_partkey": np.arange(1, n_part + 1, dtype=np.int32),
        "p_brand": rng.integers(0, len(BRANDS), n_part).astype(np.int32),
        "p_container": rng.integers(0, len(CONTAINERS), n_part).astype(np.int32),
        "p_size": rng.integers(1, 51, n_part).astype(np.int32),
        "p_type": rng.integers(0, N_PTYPES, n_part).astype(np.int32),
    }

    # lineitem (1..7 lines per order) ---------------------------------------
    lines_per = rng.integers(1, 8, n_orders)
    n_li = int(lines_per.sum())
    l_orderkey = np.repeat(o_orderkey, lines_per)
    odate = np.repeat(o_orderdate, lines_per)
    l_shipdate = (odate + rng.integers(1, 122, n_li)).astype(np.int32)
    l_commitdate = (odate + rng.integers(30, 91, n_li)).astype(np.int32)
    l_receiptdate = (l_shipdate + rng.integers(1, 31, n_li)).astype(np.int32)
    qty = rng.integers(1, 51, n_li).astype(np.float32)
    price = (qty * rng.uniform(900, 1100, n_li)).astype(np.float32)
    lineitem = {
        "l_orderkey": l_orderkey.astype(np.int32),
        "l_partkey": rng.integers(1, n_part + 1, n_li).astype(np.int32),
        "l_quantity": qty,
        "l_extendedprice": price,
        "l_discount": np.round(rng.uniform(0.0, 0.10, n_li), 2).astype(np.float32),
        "l_tax": np.round(rng.uniform(0.0, 0.08, n_li), 2).astype(np.float32),
        "l_returnflag": rng.integers(0, len(RETURNFLAGS), n_li).astype(np.int32),
        "l_linestatus": rng.integers(0, len(LINESTATUS), n_li).astype(np.int32),
        "l_shipdate": l_shipdate,
        "l_commitdate": l_commitdate,
        "l_receiptdate": l_receiptdate,
        "l_shipmode": rng.integers(0, len(SHIPMODES), n_li).astype(np.int32),
        "l_shipinstruct": rng.integers(0, len(SHIPINSTRUCT), n_li).astype(np.int32),
    }
    return {"lineitem": lineitem, "orders": orders, "part": part}


def make_context(tables: Dict[str, Dict[str, np.ndarray]], pad_to: int = 256) -> Context:
    ctx = Context(pad_to=pad_to)
    for name, data in tables.items():
        ctx.register(name, data)
    return ctx


# ---------------------------------------------------------------------------
# queries (frontend builders)
# ---------------------------------------------------------------------------

Q1_CUTOFF = _day(1998, 12, 1) - 90


def q1(ctx: Context) -> Frame:
    li = ctx.table("lineitem")
    return (
        li.filter(col("l_shipdate") <= Q1_CUTOFF)
        .with_columns(
            disc_price=col("l_extendedprice") * (1.0 - col("l_discount")),
            charge=col("l_extendedprice") * (1.0 - col("l_discount")) * (1.0 + col("l_tax")),
        )
        .group_by("l_returnflag", "l_linestatus", max_groups=8)
        .agg(
            sum_("l_quantity").as_("sum_qty"),
            sum_("l_extendedprice").as_("sum_base_price"),
            sum_("disc_price").as_("sum_disc_price"),
            sum_("charge").as_("sum_charge"),
            avg_("l_quantity").as_("avg_qty"),
            avg_("l_extendedprice").as_("avg_price"),
            avg_("l_discount").as_("avg_disc"),
            count_().as_("count_order"),
        )
        .order_by("l_returnflag", "l_linestatus")
    )


def q4(ctx: Context) -> Frame:
    li = ctx.table("lineitem")
    orders = ctx.table("orders")
    cnt = (
        li.filter(col("l_commitdate") < col("l_receiptdate"))
        .group_by("l_orderkey", max_groups=ctx.capacity("orders"))
        .agg(count_().as_("n_late"))
    )
    return (
        orders.filter(
            (col("o_orderdate") >= _day(1993, 7, 1)) & (col("o_orderdate") < _day(1993, 10, 1))
        )
        .join(cnt, left_on="o_orderkey", right_on="l_orderkey")
        .group_by("o_orderpriority", max_groups=8)
        .agg(count_().as_("order_count"))
        .order_by("o_orderpriority")
    )


def q6(ctx: Context) -> Frame:
    li = ctx.table("lineitem")
    return li.filter(
        (col("l_shipdate") >= _day(1994, 1, 1))
        & (col("l_shipdate") < _day(1995, 1, 1))
        & col("l_discount").between(0.05, 0.07)
        & (col("l_quantity") < 24.0)
    ).agg(sum_(col("l_extendedprice") * col("l_discount")).as_("revenue"))


def q12(ctx: Context) -> Frame:
    li = ctx.table("lineitem")
    orders = ctx.table("orders")
    mail, ship = code(SHIPMODES, "MAIL"), code(SHIPMODES, "SHIP")
    filtered = li.filter(
        (col("l_shipmode").isin((mail, ship)))
        & (col("l_commitdate") < col("l_receiptdate"))
        & (col("l_shipdate") < col("l_commitdate"))
        & (col("l_receiptdate") >= _day(1994, 1, 1))
        & (col("l_receiptdate") < _day(1995, 1, 1))
    )
    joined = filtered.join(orders, left_on="l_orderkey", right_on="o_orderkey")
    high = col("o_orderpriority") <= 1  # 1-URGENT or 2-HIGH
    return (
        joined.group_by("l_shipmode", max_groups=8)
        .agg(
            sum_(high).as_("high_line_count"),
            sum_(~high).as_("low_line_count"),
        )
        .order_by("l_shipmode")
    )


def q14(ctx: Context) -> Frame:
    li = ctx.table("lineitem")
    part = ctx.table("part")
    joined = (
        li.filter(
            (col("l_shipdate") >= _day(1995, 9, 1)) & (col("l_shipdate") < _day(1995, 10, 1))
        )
        .join(part, left_on="l_partkey", right_on="p_partkey")
        .with_columns(
            rev=col("l_extendedprice") * (1.0 - col("l_discount")),
            promo=(col("p_type") < PROMO_PTYPES) * (col("l_extendedprice") * (1.0 - col("l_discount"))),
        )
    )
    return joined.agg(
        sum_("promo").as_("promo_rev"), sum_("rev").as_("total_rev")
    ).project(promo_revenue=const(100.0) * col("promo_rev") / col("total_rev"))


def q19(ctx: Context) -> Frame:
    li = ctx.table("lineitem")
    part = ctx.table("part")
    sm = [code(CONTAINERS, c) for c in ("SM CASE", "SM BOX", "SM PACK", "SM PKG")]
    med = [code(CONTAINERS, c) for c in ("MED BAG", "MED BOX", "MED PKG", "MED PACK")]
    lg = [code(CONTAINERS, c) for c in ("LG CASE", "LG BOX", "LG PACK", "LG PKG")]
    air = (code(SHIPMODES, "AIR"), code(SHIPMODES, "AIR REG"))
    dip = code(SHIPINSTRUCT, "DELIVER IN PERSON")

    joined = li.join(part, left_on="l_partkey", right_on="p_partkey")
    common = col("l_shipmode").isin(air) & col("l_shipinstruct").eq(dip)
    c1 = (
        col("p_brand").eq(code(BRANDS, "Brand#12")) & col("p_container").isin(tuple(sm))
        & col("l_quantity").between(1.0, 11.0) & col("p_size").between(1, 5)
    )
    c2 = (
        col("p_brand").eq(code(BRANDS, "Brand#23")) & col("p_container").isin(tuple(med))
        & col("l_quantity").between(10.0, 20.0) & col("p_size").between(1, 10)
    )
    c3 = (
        col("p_brand").eq(code(BRANDS, "Brand#34")) & col("p_container").isin(tuple(lg))
        & col("l_quantity").between(20.0, 30.0) & col("p_size").between(1, 15)
    )
    return joined.filter(common & (c1 | c2 | c3)).agg(
        sum_(col("l_extendedprice") * (1.0 - col("l_discount"))).as_("revenue")
    )


QUERIES: Dict[str, Callable[[Context], Frame]] = {
    "q1": q1, "q4": q4, "q6": q6, "q12": q12, "q14": q14, "q19": q19,
}


# ---------------------------------------------------------------------------
# numpy references (oracles)
# ---------------------------------------------------------------------------


def ref_q1(t):
    li = t["lineitem"]
    m = li["l_shipdate"] <= Q1_CUTOFF
    rf, ls = li["l_returnflag"][m], li["l_linestatus"][m]
    qty = li["l_quantity"][m].astype(np.float64)
    ep = li["l_extendedprice"][m].astype(np.float64)
    disc = li["l_discount"][m].astype(np.float64)
    tax = li["l_tax"][m].astype(np.float64)
    out = {k: [] for k in ("l_returnflag", "l_linestatus", "sum_qty", "sum_base_price",
                           "sum_disc_price", "sum_charge", "avg_qty", "avg_price",
                           "avg_disc", "count_order")}
    for f in np.unique(rf):
        for s in np.unique(ls):
            g = (rf == f) & (ls == s)
            if not g.any():
                continue
            out["l_returnflag"].append(f)
            out["l_linestatus"].append(s)
            out["sum_qty"].append(qty[g].sum())
            out["sum_base_price"].append(ep[g].sum())
            out["sum_disc_price"].append((ep[g] * (1 - disc[g])).sum())
            out["sum_charge"].append((ep[g] * (1 - disc[g]) * (1 + tax[g])).sum())
            out["avg_qty"].append(qty[g].mean())
            out["avg_price"].append(ep[g].mean())
            out["avg_disc"].append(disc[g].mean())
            out["count_order"].append(int(g.sum()))
    return {k: np.asarray(v) for k, v in out.items()}


def ref_q4(t):
    li, o = t["lineitem"], t["orders"]
    late = np.unique(li["l_orderkey"][li["l_commitdate"] < li["l_receiptdate"]])
    m = (o["o_orderdate"] >= _day(1993, 7, 1)) & (o["o_orderdate"] < _day(1993, 10, 1))
    sel = m & np.isin(o["o_orderkey"], late)
    prio = o["o_orderpriority"][sel]
    ks = np.unique(prio)
    return {"o_orderpriority": ks,
            "order_count": np.asarray([(prio == k).sum() for k in ks])}


def ref_q6(t):
    li = t["lineitem"]
    m = (
        (li["l_shipdate"] >= _day(1994, 1, 1)) & (li["l_shipdate"] < _day(1995, 1, 1))
        & (li["l_discount"] >= 0.05) & (li["l_discount"] <= 0.07)
        & (li["l_quantity"] < 24.0)
    )
    return {"revenue": np.asarray(
        (li["l_extendedprice"][m].astype(np.float64) * li["l_discount"][m]).sum())}


def ref_q12(t):
    li, o = t["lineitem"], t["orders"]
    mail, ship = code(SHIPMODES, "MAIL"), code(SHIPMODES, "SHIP")
    m = (
        np.isin(li["l_shipmode"], [mail, ship])
        & (li["l_commitdate"] < li["l_receiptdate"])
        & (li["l_shipdate"] < li["l_commitdate"])
        & (li["l_receiptdate"] >= _day(1994, 1, 1))
        & (li["l_receiptdate"] < _day(1995, 1, 1))
    )
    ok = li["l_orderkey"][m]
    sm = li["l_shipmode"][m]
    pr = o["o_orderpriority"][np.searchsorted(o["o_orderkey"], ok)]
    out_modes = np.unique(sm)
    high = pr <= 1
    return {
        "l_shipmode": out_modes,
        "high_line_count": np.asarray([int(high[sm == x].sum()) for x in out_modes]),
        "low_line_count": np.asarray([int((~high)[sm == x].sum()) for x in out_modes]),
    }


def ref_q14(t):
    li, p = t["lineitem"], t["part"]
    m = (li["l_shipdate"] >= _day(1995, 9, 1)) & (li["l_shipdate"] < _day(1995, 10, 1))
    pk = li["l_partkey"][m]
    ptype = p["p_type"][np.searchsorted(p["p_partkey"], pk)]
    rev = (li["l_extendedprice"][m] * (1 - li["l_discount"][m])).astype(np.float64)
    promo = rev * (ptype < PROMO_PTYPES)
    return {"promo_revenue": np.asarray(100.0 * promo.sum() / rev.sum())}


def ref_q19(t):
    li, p = t["lineitem"], t["part"]
    idx = np.searchsorted(p["p_partkey"], li["l_partkey"])
    brand = p["p_brand"][idx]
    cont = p["p_container"][idx]
    size = p["p_size"][idx]
    sm = [code(CONTAINERS, c) for c in ("SM CASE", "SM BOX", "SM PACK", "SM PKG")]
    med = [code(CONTAINERS, c) for c in ("MED BAG", "MED BOX", "MED PKG", "MED PACK")]
    lg = [code(CONTAINERS, c) for c in ("LG CASE", "LG BOX", "LG PACK", "LG PKG")]
    air = [code(SHIPMODES, "AIR"), code(SHIPMODES, "AIR REG")]
    dip = code(SHIPINSTRUCT, "DELIVER IN PERSON")
    common = np.isin(li["l_shipmode"], air) & (li["l_shipinstruct"] == dip)
    q = li["l_quantity"]
    c1 = (brand == code(BRANDS, "Brand#12")) & np.isin(cont, sm) & (q >= 1) & (q <= 11) & (size >= 1) & (size <= 5)
    c2 = (brand == code(BRANDS, "Brand#23")) & np.isin(cont, med) & (q >= 10) & (q <= 20) & (size >= 1) & (size <= 10)
    c3 = (brand == code(BRANDS, "Brand#34")) & np.isin(cont, lg) & (q >= 20) & (q <= 30) & (size >= 1) & (size <= 15)
    m = common & (c1 | c2 | c3)
    return {"revenue": np.asarray(
        (li["l_extendedprice"][m].astype(np.float64) * (1 - li["l_discount"][m])).sum())}


REFERENCES: Dict[str, Callable] = {
    "q1": ref_q1, "q4": ref_q4, "q6": ref_q6, "q12": ref_q12, "q14": ref_q14, "q19": ref_q19,
}
