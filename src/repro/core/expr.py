"""Scalar expression language for instruction parameters.

``rel.Select`` predicates, ``rel.ExProj`` computations, join conditions and
the fused-kernel instruction all carry small scalar expressions over tuple
fields as *constant parameters* (the paper's "instructions may be
parameterized with constant items").  Expressions are immutable, hashable,
typeable against a tuple schema, and lowerable to jnp column arithmetic.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

from .types import Atom, TupleType, BOOL, F32, F64, I32, I64, STR

# numeric promotion lattice
_RANK = {"bool": 0, "i8": 1, "i16": 2, "i32": 3, "date": 3, "str": 3, "id": 3,
         "u32": 3, "i64": 4, "f16": 5, "bf16": 5, "num": 6, "f32": 6, "f64": 7}
_RANK_TO_ATOM = {0: BOOL, 3: I32, 4: I64, 6: F32, 7: F64}


def _promote(a: Atom, b: Atom) -> Atom:
    r = max(_RANK[a.domain], _RANK[b.domain])
    while r not in _RANK_TO_ATOM:
        r += 1
    return _RANK_TO_ATOM[r]


class Expr:
    """Base class; combinators build the tree."""

    def infer(self, schema: TupleType) -> Atom:
        raise NotImplementedError

    def fields(self) -> Tuple[str, ...]:
        raise NotImplementedError

    # -- operator sugar ----------------------------------------------------
    def _bin(self, op: str, other: Any) -> "Expr":
        return BinOp(op, self, _as_expr(other))

    def __add__(self, o: Any) -> "Expr": return self._bin("add", o)
    def __radd__(self, o: Any) -> "Expr": return _as_expr(o)._bin("add", self)
    def __sub__(self, o: Any) -> "Expr": return self._bin("sub", o)
    def __rsub__(self, o: Any) -> "Expr": return _as_expr(o)._bin("sub", self)
    def __mul__(self, o: Any) -> "Expr": return self._bin("mul", o)
    def __rmul__(self, o: Any) -> "Expr": return _as_expr(o)._bin("mul", self)
    def __truediv__(self, o: Any) -> "Expr": return self._bin("div", o)
    def __lt__(self, o: Any) -> "Expr": return self._bin("lt", o)
    def __le__(self, o: Any) -> "Expr": return self._bin("le", o)
    def __gt__(self, o: Any) -> "Expr": return self._bin("gt", o)
    def __ge__(self, o: Any) -> "Expr": return self._bin("ge", o)
    def eq(self, o: Any) -> "Expr": return self._bin("eq", o)
    def ne(self, o: Any) -> "Expr": return self._bin("ne", o)
    def __and__(self, o: Any) -> "Expr": return self._bin("and", o)
    def __or__(self, o: Any) -> "Expr": return self._bin("or", o)
    def __invert__(self) -> "Expr": return UnOp("not", self)
    def isin(self, values: Tuple[Any, ...]) -> "Expr":
        e: Expr = self.eq(values[0])
        for v in values[1:]:
            e = e | self.eq(v)
        return e
    def between(self, lo: Any, hi: Any) -> "Expr":
        return (self >= lo) & (self <= hi)


@dataclass(frozen=True)
class Col(Expr):
    name: str

    def infer(self, schema: TupleType) -> Atom:
        t = schema.field(self.name)
        if not isinstance(t, Atom):
            raise TypeError(f"column {self.name} is not atomic: {t.render()}")
        return t

    def fields(self) -> Tuple[str, ...]:
        return (self.name,)

    def __repr__(self) -> str:
        return f"${self.name}"


@dataclass(frozen=True)
class Const(Expr):
    value: Any
    atom: Atom

    def infer(self, schema: TupleType) -> Atom:
        return self.atom

    def fields(self) -> Tuple[str, ...]:
        return ()

    def __repr__(self) -> str:
        return repr(self.value)


_CMP = {"lt", "le", "gt", "ge", "eq", "ne"}
_LOGIC = {"and", "or"}
_ARITH = {"add", "sub", "mul", "div", "min", "max"}


@dataclass(frozen=True)
class BinOp(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def infer(self, schema: TupleType) -> Atom:
        lt, rt = self.lhs.infer(schema), self.rhs.infer(schema)
        if self.op in _CMP:
            return BOOL
        if self.op in _LOGIC:
            if lt != BOOL or rt != BOOL:
                raise TypeError(f"logic op {self.op} on non-bool {lt.render()},{rt.render()}")
            return BOOL
        if self.op in _ARITH:
            if self.op == "div":
                return _promote(_promote(lt, rt), F32)
            return _promote(lt, rt)
        raise TypeError(f"unknown binop {self.op}")

    def fields(self) -> Tuple[str, ...]:
        seen = []
        for f in self.lhs.fields() + self.rhs.fields():
            if f not in seen:
                seen.append(f)
        return tuple(seen)

    def __repr__(self) -> str:
        return f"({self.lhs!r} {self.op} {self.rhs!r})"


@dataclass(frozen=True)
class UnOp(Expr):
    op: str
    arg: Expr

    def infer(self, schema: TupleType) -> Atom:
        t = self.arg.infer(schema)
        if self.op == "not":
            if t != BOOL:
                raise TypeError("not on non-bool")
            return BOOL
        if self.op in ("neg", "abs"):
            return t
        raise TypeError(f"unknown unop {self.op}")

    def fields(self) -> Tuple[str, ...]:
        return self.arg.fields()

    def __repr__(self) -> str:
        return f"{self.op}({self.arg!r})"


def _as_expr(v: Any) -> Expr:
    if isinstance(v, Expr):
        return v
    if isinstance(v, bool):
        return Const(v, BOOL)
    if isinstance(v, int):
        return Const(v, I64 if abs(v) > 2**31 - 1 else I32)
    if isinstance(v, float):
        return Const(v, F64)
    if isinstance(v, str):
        # string literals stay raw here; the vec lowering remaps them into
        # global-dictionary code space (interp compares them directly)
        return Const(v, STR)
    raise TypeError(f"cannot lift {v!r} into an expression")


def col(name: str) -> Col:
    return Col(name)


def const(v: Any, atom: Atom | None = None) -> Const:
    e = _as_expr(v)
    if atom is not None:
        return Const(e.value, atom)  # type: ignore[union-attr]
    return e  # type: ignore[return-value]


def substitute(e: Expr, mapping: Dict[str, Expr]) -> Expr:
    """Replace column references by expressions (used by fusion rewrites)."""
    if isinstance(e, Col):
        return mapping.get(e.name, e)
    if isinstance(e, Const):
        return e
    if isinstance(e, UnOp):
        return UnOp(e.op, substitute(e.arg, mapping))
    if isinstance(e, BinOp):
        return BinOp(e.op, substitute(e.lhs, mapping), substitute(e.rhs, mapping))
    raise TypeError(f"cannot substitute into {e!r}")


# ---------------------------------------------------------------------------
# Evaluation over column dictionaries (used by lowering and by oracles)
# ---------------------------------------------------------------------------

def evaluate(e: Expr, cols: Dict[str, Any], np_mod: Any) -> Any:
    """Evaluate columnar: ``cols`` maps field name -> array (or scalar)."""
    if isinstance(e, Col):
        return cols[e.name]
    if isinstance(e, Const):
        return e.value
    if isinstance(e, UnOp):
        a = evaluate(e.arg, cols, np_mod)
        if e.op == "not":
            return np_mod.logical_not(a)
        if e.op == "neg":
            return -a
        if e.op == "abs":
            return np_mod.abs(a)
    if isinstance(e, BinOp):
        a = evaluate(e.lhs, cols, np_mod)
        b = evaluate(e.rhs, cols, np_mod)
        return {
            "add": lambda: a + b,
            "sub": lambda: a - b,
            "mul": lambda: a * b,
            "div": lambda: a / b,
            "min": lambda: np_mod.minimum(a, b),
            "max": lambda: np_mod.maximum(a, b),
            "lt": lambda: a < b,
            "le": lambda: a <= b,
            "gt": lambda: a > b,
            "ge": lambda: a >= b,
            "eq": lambda: a == b,
            "ne": lambda: a != b,
            "and": lambda: np_mod.logical_and(a, b),
            "or": lambda: np_mod.logical_or(a, b),
        }[e.op]()
    raise TypeError(f"cannot evaluate {e!r}")


# ---------------------------------------------------------------------------
# Aggregation specs (constant parameters of Aggr/GroupByAggr)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AggSpec:
    """``fn`` ∈ {sum,count,min,max}; ``expr`` the aggregated expression.

    ``avg`` is desugared by frontends into sum/count + a finalizing ExProj so
    that every AggSpec is *self-decomposable*: pre-aggregate per shard with
    ``fn``, combine partials with ``combine_fn`` (count combines with sum).
    This is what makes the paper's pre-aggregation rewrite (Alg. 2) generic.
    """

    fn: str
    expr: Expr
    name: str

    def __post_init__(self) -> None:
        if self.fn not in ("sum", "count", "min", "max"):
            raise ValueError(f"non-decomposable agg fn {self.fn!r}; desugar first")

    @property
    def combine_fn(self) -> str:
        return "sum" if self.fn == "count" else self.fn

    def result_atom(self, schema: TupleType) -> Atom:
        if self.fn == "count":
            return I64
        t = self.expr.infer(schema)
        if self.fn == "sum":
            if t == BOOL:
                return I64  # sum of a predicate = conditional count
            return _promote(t, t)  # canonicalized rank
        return t
