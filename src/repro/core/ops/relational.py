"""Relational IR flavor (paper Table 2, top).

High-level, domain-specific instructions for (bag/set/seq) relational
algebra.  These are what the SQL/dataflow frontends produce; rewritings
lower them into ``vec.*`` physical instructions.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence, Tuple

from ..expr import AggSpec, Expr
from ..registry import op
from ..types import (
    BAG, SEQ, SET,
    Atom, Bag, CollectionType, I64, ItemType, Single, TupleType,
    common_kind, is_coll, schema_of,
)


def _rel(t: ItemType) -> CollectionType:
    if not is_coll(t) or not isinstance(t.item, TupleType):  # type: ignore[union-attr]
        raise TypeError(f"expected a relation (collection of tuples), got {t.render()}")
    return t  # type: ignore[return-value]


@op("rel.Scan", source=True)
def _scan(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Scan(table, schema, kind) → relation. Data source (orchestration layer)."""
    schema: TupleType = params["schema"]
    kind = params.get("kind", BAG)
    return [CollectionType(kind, schema)]


@op("rel.Select", elementwise=True)
def _select(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Select(p)(C) → C — keep tuples where p holds; kind preserved."""
    c = _rel(ins[0])
    pred: Expr = params["pred"]
    if pred.infer(c.schema).domain != "bool":
        raise TypeError("Select predicate is not boolean")
    return [c]


@op("rel.Proj", elementwise=True)
def _proj(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Proj(A1..Ak)(C) — restrict to fields; Set→Set, Seq→Seq, else Bag."""
    c = _rel(ins[0])
    names: Tuple[str, ...] = tuple(params["names"])
    item = c.schema.project(names)
    kind = c.kind if c.kind in (SET, SEQ) else BAG
    return [CollectionType(kind, item)]


@op("rel.ExProj", elementwise=True)
def _exproj(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ExProj({A'i ← fi})(C) — compute new fields; Seq→Seq, Single→Single, else Bag."""
    c = _rel(ins[0])
    exprs: Tuple[Tuple[str, Expr], ...] = tuple(params["exprs"])
    fields = tuple((n, e.infer(c.schema)) for n, e in exprs)
    kind = SEQ if c.kind is SEQ else c.kind if c.kind.name == "Single" else BAG
    return [CollectionType(kind, TupleType(fields))]


@op("rel.Aggr", aggregation={"kind": "scalar"})
def _aggr(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Aggr({(fn, expr) → A})(C) → Single⟨A1,...⟩ — full-collection aggregation.

    Every agg is decomposable (see AggSpec); the parallelization rewrite
    copies this instruction inside ConcurrentExecute as a pre-aggregation and
    re-aggregates partials with the combine fns (paper Alg. 2).
    """
    c = _rel(ins[0])
    aggs: Tuple[AggSpec, ...] = tuple(params["aggs"])
    fields = tuple((a.name, a.result_atom(c.schema)) for a in aggs)
    return [Single(TupleType(fields))]


@op("rel.GroupByAggr", aggregation={"kind": "grouped"})
def _groupby(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """GroupByAggr(keys, aggs)(C) → Bag⟨keys..., aggs...⟩."""
    c = _rel(ins[0])
    keys: Tuple[str, ...] = tuple(params["keys"])
    aggs: Tuple[AggSpec, ...] = tuple(params["aggs"])
    fields = tuple((k, c.schema.field(k)) for k in keys)
    fields += tuple((a.name, a.result_atom(c.schema)) for a in aggs)
    return [Bag(TupleType(fields))]


def join_schema(left: TupleType, right: TupleType, left_on: Sequence[str],
                right_on: Sequence[str]) -> TupleType:
    """Left fields + right fields minus right keys; collisions suffixed ``_r``."""
    fields = list(left.fields)
    names = {n for n, _ in fields}
    for n, t in right.fields:
        if n in right_on:
            continue
        nn = n if n not in names else n + "_r"
        names.add(nn)
        fields.append((nn, t))
    return TupleType(tuple(fields))


@op("rel.Join")
def _join(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Join(left_on, right_on, how="inner")(L, R) → Bag⟨L ⋈ R⟩."""
    l, r = _rel(ins[0]), _rel(ins[1])
    left_on = tuple(params["left_on"])
    right_on = tuple(params["right_on"])
    if len(left_on) != len(right_on):
        raise TypeError("Join key arity mismatch")
    for lk, rk in zip(left_on, right_on):
        if l.schema.field(lk) != r.schema.field(rk):
            raise TypeError(f"Join key type mismatch on {lk}/{rk}")
    return [Bag(join_schema(l.schema, r.schema, left_on, right_on))]


@op("rel.CombinePartials")
def _combine_partials(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """CombinePartials(aggs)(Seq[n]⟨Single⟨T⟩⟩) → Single⟨T⟩.

    Re-aggregates per-worker scalar pre-aggregates with each agg's combine
    fn (count→sum, sum→sum, min→min, max→max).  Introduced by the
    pre-aggregation step of the parallelization rewrite (paper Alg. 2).
    """
    (s,) = ins
    if not is_coll(s, SEQ) or not is_coll(s.item):
        raise TypeError(f"CombinePartials of non-split type {s.render()}")
    return [s.item]


@op("rel.OrderBy")
def _orderby(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """OrderBy(keys, ascending)(C) → Seq⟨item⟩."""
    c = _rel(ins[0])
    return [CollectionType(SEQ, c.item)]


@op("rel.Limit")
def _limit(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Limit(k)(C) → C (first k; requires Seq for determinism)."""
    c = _rel(ins[0])
    return [c]


@op("rel.Distinct")
def _distinct(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Distinct()(C) → Set⟨item⟩."""
    c = _rel(ins[0])
    return [CollectionType(SET, c.item)]
