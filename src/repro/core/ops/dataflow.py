"""Generic dataflow flavor — the paper's generic Python frontend.

Works on arbitrary item types (not just tuples of atoms); ``df.Map`` is the
higher-order workhorse.  The k-means frontend and the quickstart example use
this flavor mixed with ``rel.*``/``la.*`` instructions — mixing flavors in
one program is the point of the shared IR language.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

from ..program import Program
from ..registry import op
from ..types import BAG, SEQ, CollectionType, ItemType, Single, is_coll


@op("df.Source", source=True)
def _source(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Source(name, type) — named external collection."""
    return [params["type"]]


@op("df.Literal", source=True)
def _literal(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Literal(value, type) — constant collection baked into the program."""
    return [params["type"]]


@op("df.Map", elementwise=True)
def _map(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Map(P: I1 → I2)(C) → Bag⟨I2⟩ (Seq→Seq) — per-item transformation."""
    (c,) = ins
    if not is_coll(c):
        raise TypeError(f"Map over non-collection {c.render()}")
    p: Program = params["P"]
    if len(p.inputs) != 1 or len(p.results) != 1:
        raise TypeError("Map program must be I1 → I2")
    if p.inputs[0].type != c.item:
        raise TypeError(
            f"Map program input {p.inputs[0].type.render()} != item {c.item.render()}"
        )
    kind = SEQ if c.kind is SEQ else BAG
    return [CollectionType(kind, p.results[0].type)]


@op("df.Reduce", aggregation={"kind": "generic"})
def _reduce(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Reduce(P: (I,I) → I [assoc+comm])(C) → Single⟨I⟩."""
    (c,) = ins
    if not is_coll(c):
        raise TypeError("Reduce over non-collection")
    p: Program = params["P"]
    ok = (
        len(p.inputs) == 2
        and len(p.results) == 1
        and p.inputs[0].type == p.inputs[1].type == p.results[0].type == c.item
    )
    if not ok:
        raise TypeError("Reduce program must be (I, I) → I over the item type")
    return [Single(c.item)]


@op("df.Zip")
def _zip(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Zip()(Seq⟨A⟩, Seq⟨B⟩) → Seq⟨⟨l:A, r:B⟩⟩."""
    from ..types import TupleType

    a, b = ins
    if not (is_coll(a, SEQ) and is_coll(b, SEQ)):
        raise TypeError("Zip requires Seq inputs")
    return [CollectionType(SEQ, TupleType.of(l=a.item, r=b.item))]


@op("df.Collect", sink=True)
def _collect(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Collect()(C) → C — marks a result for host materialization."""
    return [ins[0]]
