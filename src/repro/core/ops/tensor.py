"""Tensor/step-pipeline flavor — how the LM stack rides on CVM.

The paper's lowering extracts tree-shaped data paths into *pipelines* that
are JIT-compiled, with orchestration around them.  For the LM workloads the
data path is the model's forward/backward — represented as an opaque-but-
typed ``tz.Pipeline`` instruction whose ``fn`` parameter names a pure
function in the pipeline table (registered by ``repro.models.api``).  The
parallelization/backend rewrites manipulate the *orchestration* around
pipelines (Split / MeshExecute / AllReduce / OptUpdate) exactly as they do
for relational programs; the lowering JITs the whole thing with XLA.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, Sequence, Tuple

from ..registry import op
from ..types import ItemType

# pipeline table: name -> (callable, signature_fn(params, in_types) -> out_types)
_PIPELINES: Dict[str, Tuple[Callable[..., Any], Any]] = {}


def register_pipeline(name: str, fn: Callable[..., Any],
                      out_types_fn: Callable[[Mapping[str, Any], Sequence[ItemType]], Sequence[ItemType]] | None = None,
                      overwrite: bool = False) -> None:
    if name in _PIPELINES and not overwrite:
        raise ValueError(f"pipeline {name!r} already registered")
    _PIPELINES[name] = (fn, out_types_fn)


def get_pipeline(name: str) -> Callable[..., Any]:
    if name not in _PIPELINES:
        raise KeyError(f"pipeline {name!r} not registered")
    return _PIPELINES[name][0]


@op("tz.Pipeline", aggregation={"kind": "segmented"})
def _pipeline(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Pipeline(fn, out_types)(X1..Xk) — JIT-compiled tree-shaped data path.

    ``out_types`` may be given explicitly (frontends know their shapes) or
    derived from the registered signature function.  Declared sum-
    decomposable over its first (data) input: a gradient pipeline returns
    per-chunk sums, so the parallelization rewrite may run it per shard and
    combine with ``cf.CombineChunks(sum)`` (→ all-reduce on the mesh
    backend).  Non-decomposable pipelines belong in a different opcode.
    """
    if "out_types" in params and params["out_types"] is not None:
        return list(params["out_types"])
    name = params["fn"]
    if name in _PIPELINES and _PIPELINES[name][1] is not None:
        return list(_PIPELINES[name][1](params, ins))
    raise TypeError(f"tz.Pipeline {name!r}: no out_types and no signature registered")


@op("tz.Source", source=True)
def _source(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Source(name, type) — a model input / parameter tree / data batch."""
    return [params["type"]]


@op("tz.OptUpdate")
def _optupdate(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """OptUpdate(opt)(params, opt_state, grads) → (params', opt_state').

    Typed pass-through: output types equal the first two input types.
    """
    if len(ins) < 3:
        raise TypeError("OptUpdate(params, opt_state, grads)")
    return [ins[0], ins[1]]
