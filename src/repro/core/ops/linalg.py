"""Linear-algebra IR flavor.

The abstract LA types of the paper (Seq⟨Num⟩, 2DSeq⟨Num⟩, kDSeq⟨Num⟩) are
flavored here as ``Tensor`` collections — a kDSeq with static shape + dtype,
which is the information XLA needs.  High-level mathematical rewrites
(e.g. (AB)ᵀ → BᵀAᵀ, matmul re-association) happen on this flavor before
lowering.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence, Tuple

from ..registry import op
from ..types import Atom, F32, I32, ItemType, Tensor, is_tensor, tensor_dtype, tensor_shape


def _t(x: ItemType) -> Tuple[Tuple[int, ...], Atom]:
    if not is_tensor(x):
        raise TypeError(f"expected Tensor, got {x.render()}")
    return tensor_shape(x), tensor_dtype(x)


def _broadcast(a: Tuple[int, ...], b: Tuple[int, ...]) -> Tuple[int, ...]:
    la, lb = len(a), len(b)
    n = max(la, lb)
    out = []
    for i in range(n):
        da = a[la - n + i] if la - n + i >= 0 else 1
        db = b[lb - n + i] if lb - n + i >= 0 else 1
        if da != db and 1 not in (da, db):
            raise TypeError(f"broadcast mismatch {a} vs {b}")
        out.append(max(da, db))
    return tuple(out)


@op("la.Literal", source=True)
def _literal(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Literal(shape, dtype[, name]) — tensor source."""
    return [Tensor(params.get("dtype", F32), tuple(params["shape"]))]


@op("la.MMMult", elementwise=True)
def _mmmult(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """MMMult()(A: (m,k), B: (k,n)) → (m,n) — matrix-matrix multiplication."""
    (sa, da), (sb, db) = _t(ins[0]), _t(ins[1])
    if len(sa) != 2 or len(sb) != 2 or sa[1] != sb[0]:
        raise TypeError(f"MMMult shape mismatch {sa} @ {sb}")
    if da != db:
        raise TypeError("MMMult dtype mismatch")
    return [Tensor(da, (sa[0], sb[1]))]


@op("la.Transpose")
def _transpose(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    (s, d) = _t(ins[0])
    if len(s) != 2:
        raise TypeError("Transpose expects a matrix")
    return [Tensor(d, (s[1], s[0]))]


@op("la.Ewise", elementwise=True)
def _ewise(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Ewise(op)(A[, B]) — broadcasting elementwise arithmetic."""
    (sa, da) = _t(ins[0])
    if len(ins) == 1:
        return [Tensor(da, sa)]
    (sb, db) = _t(ins[1])
    return [Tensor(da, _broadcast(sa, sb))]


@op("la.ReduceSum")
def _reducesum(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ReduceSum(axis)(A) — sum along one axis."""
    (s, d) = _t(ins[0])
    ax = int(params["axis"]) % len(s)
    return [Tensor(d, tuple(x for i, x in enumerate(s) if i != ax))]


@op("la.CDist2", elementwise=True)
def _cdist2(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """CDist2()(X: (n,d), C: (k,d)) → (n,k) squared euclidean distances.

    The k-means hot loop; lowered to the MXU-friendly expansion
    ‖x‖² − 2XCᵀ + ‖c‖² and, on the TPU backend, to the fused Pallas kernel.
    """
    (sx, dx), (sc, dc) = _t(ins[0]), _t(ins[1])
    if len(sx) != 2 or len(sc) != 2 or sx[1] != sc[1]:
        raise TypeError(f"CDist2 shape mismatch {sx} vs {sc}")
    return [Tensor(dx, (sx[0], sc[0]))]


@op("la.ArgMinRow", elementwise=True)
def _argminrow(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ArgMinRow()(A: (n,k)) → (n,) i32 — index of the row-wise minimum."""
    (s, _) = _t(ins[0])
    if len(s) != 2:
        raise TypeError("ArgMinRow expects a matrix")
    return [Tensor(I32, (s[0],))]


@op("la.SegSum", aggregation={"kind": "segmented"})
def _segsum(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """SegSum(k)(X: (n,d), labels: (n,) i32) → (k,d) — sum rows by label.

    Decomposable: per-shard SegSum then elementwise sum of partials — the LA
    counterpart of the relational pre-aggregation rewrite.
    """
    (sx, dx) = _t(ins[0])
    (sl, dl) = _t(ins[1])
    if len(sx) != 2 or sl != (sx[0],):
        raise TypeError(f"SegSum shape mismatch {sx} vs labels {sl}")
    return [Tensor(dx, (int(params["k"]), sx[1]))]


@op("la.SegCount", aggregation={"kind": "segmented"})
def _segcount(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """SegCount(k)(labels: (n,) i32) → (k,) f32 — occurrences per label."""
    (sl, _) = _t(ins[0])
    return [Tensor(F32, (int(params["k"]),))]


@op("la.KMeansStep", aggregation={"kind": "segmented"})
def _kmeans_step(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """KMeansStep()(X: (n,d), C: (k,d)) → (sums: (k,d), counts: (k,)).

    Fused assignment + accumulation — the "run-based aggregation enabled by
    plan analysis" the paper credits for matching hand-written C++ k-means.
    Produced by the fusion rewrite from CDist2+ArgMinRow+SegSum+SegCount;
    lowered to the ``kmeans_step`` Pallas kernel on the TPU backend.
    """
    (sx, dx), (sc, dc) = _t(ins[0]), _t(ins[1])
    if len(sx) != 2 or len(sc) != 2 or sx[1] != sc[1]:
        raise TypeError("KMeansStep shape mismatch")
    k = sc[0]
    return [Tensor(dx, (k, sx[1])), Tensor(F32, (k,))]
