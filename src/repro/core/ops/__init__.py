"""Standard IR flavors.

Importing this package registers the standard opcode vocabularies:

  * ``cf.*``   control-flow-like higher-order instructions (paper Table 2 mid)
  * ``df.*``   generic dataflow frontend flavor
  * ``rel.*``  relational flavor (Select/Proj/ExProj/Aggr/Join/...)
  * ``la.*``   linear-algebra flavor (MMMult, ...)
  * ``vec.*``  physical vector flavor (ScanVec/SplitVec/BuildHTable/...)
  * ``mesh.*`` SPMD mesh backend flavor (MeshExecute/AllReduce/Exchange/...)
  * ``tz.*``   tensor/step-pipeline flavor used by the LM stack
"""

from . import controlflow, dataflow, linalg, mesh, relational, tensor, vec  # noqa: F401
