"""Control-flow-like higher-order instructions (paper Table 2, middle).

CVM has no jumps by design; loops/conditionals/parallelism are higher-order
instructions parameterized by nested programs.  ``cf.Split`` /
``cf.ConcurrentExecute`` / ``cf.Merge`` are the generic parallelism trio the
parallelization rewrite introduces (Alg. 1 → Alg. 2).
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

from ..program import Program
from ..registry import op
from ..types import (
    BAG, SEQ, SINGLE, CollectionType, ItemType, Single, assert_type_eq, is_coll,
)


def chunk_type(c: CollectionType, n: int, axis: int = 0) -> CollectionType:
    """The per-chunk type of an n-way split of ``c``.

    Size-less abstract collections (Bag/Set/Seq of items) are unchanged;
    statically-sized collections divide: Tensor/KDSeq divide ``shape[axis]``,
    Vec divides ``max_count``.
    """
    shape = c.attr("shape")
    if shape is not None:
        if shape[axis] % n != 0:
            raise TypeError(f"cannot split shape {shape} axis {axis} into {n}")
        new_shape = tuple(s // n if i == axis else s for i, s in enumerate(shape))
        return c.with_attr("shape", new_shape)
    cap = c.attr("max_count")
    if cap is not None:
        if cap % n != 0:
            raise TypeError(f"cannot split capacity {cap} into {n}")
        return c.with_attr("max_count", cap // n)
    return c


def unchunk_type(c: CollectionType, n: int, axis: int = 0) -> CollectionType:
    """Inverse of ``chunk_type``: the type of n concatenated chunks."""
    shape = c.attr("shape")
    if shape is not None:
        new_shape = tuple(s * n if i == axis else s for i, s in enumerate(shape))
        return c.with_attr("shape", new_shape)
    cap = c.attr("max_count")
    if cap is not None:
        return c.with_attr("max_count", cap * n)
    return c


def split_type(inner: ItemType, n: int, axis: int = 0, bcast: bool = False) -> CollectionType:
    """The type of an n-way split: Seq[n]⟨inner⟩ (``inner`` = chunk type)."""
    attrs: tuple = (("n", int(n)), ("axis", int(axis)))
    if bcast:
        attrs += (("bcast", True),)
    return CollectionType(SEQ, inner, attrs)


@op("cf.Split", elementwise=False)
def _split(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Split(n[, axis])(C) → Seq[n]⟨chunk(C)⟩ — partition into n chunks.

    The partitioning is an implementation choice of the backend (range,
    round-robin, ...); semantics only promise that Merge(Split(C)) ≡ C as a
    multiset (and preserves order for Seq inputs).
    """
    (c,) = ins
    if not is_coll(c):
        raise TypeError(f"Split of non-collection {c.render()}")
    n = int(params["n"])
    axis = int(params.get("axis", 0))
    return [split_type(chunk_type(c, n, axis), n, axis)]


@op("cf.Broadcast")
def _broadcast(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Broadcast(n)(X) → Seq[n]⟨X⟩ — every worker receives the same value.

    Introduced by the parallelization rewrite for loop-invariant side inputs
    of absorbed instructions (e.g. k-means centroids, model parameters).
    """
    (x,) = ins
    return [split_type(x, int(params["n"]), bcast=True)]


@op("cf.Merge")
def _merge(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Merge()(Seq[n]⟨C⟩) → unchunk(C) — concatenate chunks (inverse of Split)."""
    (s,) = ins
    if not (is_coll(s, SEQ) and isinstance(s.item, CollectionType)):
        raise TypeError(f"Merge of non-split type {s.render()}")
    if s.attr("bcast"):
        raise TypeError("Merge of a Broadcast is ill-defined; use TakeChunk")
    n = s.attr("n")
    if n is None:
        raise TypeError(f"Merge of Seq without chunk count: {s.render()}")
    return [unchunk_type(s.item, int(n), int(s.attr("axis", 0)))]


@op("cf.ConcurrentExecute")
def _concurrent_execute(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ConcurrentExecute(P)(S1..Sk) — run P once per chunk, concurrently.

    Each input is a Seq[n]⟨Xi⟩; worker j receives element j of every input
    and produces element j of every output.  Workers may exchange data if P
    contains collective instructions (that is the difference to a plain Map).
    """
    p: Program = params["P"]
    n = None
    if not ins:
        raise TypeError("ConcurrentExecute needs at least one input")
    if len(ins) != len(p.inputs):
        raise TypeError(
            f"ConcurrentExecute: {len(ins)} inputs but program {p.name} takes {len(p.inputs)}"
        )
    for t, pin in zip(ins, p.inputs):
        if not is_coll(t, SEQ):
            raise TypeError(f"ConcurrentExecute input must be Seq-of-chunks, got {t.render()}")
        tn = t.attr("n")
        if n is None:
            n = tn
        elif tn != n:
            raise TypeError(f"ConcurrentExecute inputs disagree on worker count: {tn} vs {n}")
        assert_type_eq(t.item, pin.type, f"ConcurrentExecute input vs {p.name}")
    assert n is not None
    return [split_type(r.type, n) for r in p.results]


@op("cf.Loop")
def _loop(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Loop(n, P)(C1..Ck) — run P n times, feeding results back as inputs."""
    p: Program = params["P"]
    if list(p.input_types()) != list(ins):
        raise TypeError(f"Loop body {p.name} input types != loop inputs")
    if list(p.result_types()) != list(ins):
        raise TypeError(f"Loop body {p.name} must be type-preserving")
    return list(ins)


@op("cf.While")
def _while(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """While(P)(C1..Ck) — P returns (Single⟨bool⟩, C1..Ck); loop while true."""
    from ..types import Atom

    p: Program = params["P"]
    if list(p.input_types()) != list(ins):
        raise TypeError(f"While body {p.name} input types != inputs")
    res = list(p.result_types())
    cond, rest = res[0], res[1:]
    if not (is_coll(cond, SINGLE) and isinstance(cond.item, Atom) and cond.item.domain == "bool"):
        raise TypeError(f"While body must first return Single⟨bool⟩, got {cond.render()}")
    if rest != list(ins):
        raise TypeError("While body must be type-preserving on carried registers")
    return list(ins)


@op("cf.Cond")
def _cond(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Cond(Pthen, Pelse)(pred: Single⟨bool⟩, C1..Ck)."""
    pt: Program = params["Pthen"]
    pe: Program = params["Pelse"]
    if list(pt.result_types()) != list(pe.result_types()):
        raise TypeError("Cond branches disagree on result types")
    body_ins = list(ins[1:])
    if list(pt.input_types()) != body_ins or list(pe.input_types()) != body_ins:
        raise TypeError("Cond branch inputs must match instruction inputs (after pred)")
    return list(pt.result_types())


@op("cf.Call")
def _call(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Call(P)(C1..Ck) — straight nested-program invocation."""
    p: Program = params["P"]
    if list(p.input_types()) != list(ins):
        raise TypeError(f"Call of {p.name}: argument types mismatch")
    return list(p.result_types())


@op("cf.CombineChunks", barrier=True)
def _combine_chunks(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """CombineChunks(op)(Seq[n]⟨X⟩) → X — fold chunks with an elementwise op.

    ``op`` ∈ {"sum","min","max"}.  The generic combiner of per-worker partial
    results (gradients, LA partial aggregates).  The SPMD backend rewrites a
    CombineChunks that follows a MeshExecute into an AllReduce *inside* the
    mesh program (turning a centralized combine into a collective).
    """
    (s,) = ins
    if not is_coll(s, SEQ) or s.attr("n") is None:
        raise TypeError(f"CombineChunks of non-split type {s.render()}")
    return [s.item]


@op("cf.TakeChunk")
def _take_chunk(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """TakeChunk(i)(Seq[n]⟨X⟩) → X — select one chunk (e.g. a replicated result)."""
    (s,) = ins
    if not is_coll(s, SEQ) or s.attr("n") is None:
        raise TypeError(f"TakeChunk of non-split type {s.render()}")
    return [s.item]
