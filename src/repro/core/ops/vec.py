"""Physical vector flavor (paper Table 2, bottom): backend building blocks.

Low-level philosophy (paper §3.4): operators as small as possible —
"cleverness as a sophisticated combination of simple operators".  Physical
collections are ``Vec``s: padded fixed-capacity column blocks with a count
(static shapes are the TPU adaptation; see DESIGN.md §2).

``BuildHTable``/``ProbeHTable`` exist for IR completeness (they are the
paper's canonical low-level pair); the TPU backend *rewrites* them into
sort/searchsorted sequences because random scatter is not MXU-friendly.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence, Tuple

from ..expr import AggSpec, Expr
from ..registry import op
from ..types import (
    BAG, SEQ,
    Atom, CollectionType, HTab, I32, ItemType, Single, TupleType, Vec, is_coll,
)
from .controlflow import split_type
from .relational import join_schema


def _vec(t: ItemType) -> CollectionType:
    if not is_coll(t) or t.kind.name != "Vec":
        raise TypeError(f"expected Vec, got {t.render()}")
    return t  # type: ignore[return-value]


def _cap(t: CollectionType) -> int:
    c = t.attr("max_count")
    if c is None:
        raise TypeError(f"Vec without static capacity: {t.render()}")
    return int(c)


@op("vec.ScanVec", source=True)
def _scanvec(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ScanVec(table, schema, max_count) → Vec⟨T⟩ — materialized column block."""
    return [Vec(params["schema"], params["max_count"])]


@op("vec.MatVec")
def _matvec(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """MatVec()(C) → Vec — materialize any collection into a vector block."""
    (c,) = ins
    if not is_coll(c):
        raise TypeError("MatVec of non-collection")
    cap = params.get("max_count") or c.attr("max_count")
    return [Vec(c.item, cap)]


@op("vec.SplitVec")
def _splitvec(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """SplitVec(n)(Vec⟨I⟩) → Seq[n]⟨Vec⟨I⟩⟩ — even range partition."""
    v = _vec(ins[0])
    n = int(params["n"])
    cap = _cap(v)
    if cap % n != 0:
        raise TypeError(f"SplitVec: capacity {cap} not divisible by {n}")
    return [split_type(Vec(v.item, cap // n), n)]


@op("vec.ConcatVec")
def _concatvec(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ConcatVec()(Seq[n]⟨Vec⟨I⟩⟩) → Vec⟨I⟩."""
    (s,) = ins
    if not is_coll(s, SEQ) or not is_coll(s.item):
        raise TypeError("ConcatVec of non-split vec")
    inner = _vec(s.item)
    n = s.attr("n")
    return [Vec(inner.item, _cap(inner) * int(n))]


@op("vec.MaskSelect", elementwise=True)
def _maskselect(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """MaskSelect(pred)(Vec⟨T⟩) → Vec⟨T⟩ — late-materialized (predicated) select.

    Capacity unchanged; only the validity mask is narrowed.  This is the TPU
    analogue of the paper's "predicated scan" low-level technique.
    """
    v = _vec(ins[0])
    pred: Expr = params["pred"]
    if pred.infer(v.schema).domain != "bool":
        raise TypeError("MaskSelect predicate not boolean")
    return [v]


@op("vec.Compact")
def _compact(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """Compact()(Vec⟨T⟩) → Vec⟨T⟩ — densify valid rows to the front.

    Inserted by the selectivity-aware rewrite when a selective filter pays
    for the shuffle (sort by ~validity).
    """
    v = _vec(ins[0])
    cap = params.get("max_count")
    return [Vec(v.item, int(cap) if cap else _cap(v))]


@op("vec.ProjVec", elementwise=True)
def _projvec(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ProjVec(names)(Vec⟨T⟩) → Vec⟨T'⟩ — drop columns (free: layout is SoA)."""
    v = _vec(ins[0])
    return [Vec(v.schema.project(tuple(params["names"])), _cap(v))]


@op("vec.ExProjVec", elementwise=True)
def _exprojvec(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ExProjVec(exprs)(Vec⟨T⟩) → Vec⟨T'⟩ — compute new columns."""
    v = _vec(ins[0])
    exprs: Tuple[Tuple[str, Expr], ...] = tuple(params["exprs"])
    fields = tuple((n, e.infer(v.schema)) for n, e in exprs)
    return [Vec(TupleType(fields), _cap(v))]


@op("vec.AggrVec", aggregation={"kind": "scalar"})
def _aggrvec(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """AggrVec(aggs)(Vec⟨T⟩) → Single⟨aggs⟩ — masked block aggregation."""
    v = _vec(ins[0])
    aggs: Tuple[AggSpec, ...] = tuple(params["aggs"])
    fields = tuple((a.name, a.result_atom(v.schema)) for a in aggs)
    return [Single(TupleType(fields))]


@op("vec.FusedSelectAgg", aggregation={"kind": "scalar"})
def _fused_select_agg(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """FusedSelectAgg(pred, aggs)(Vec⟨T⟩) → Single⟨aggs⟩.

    Single-pass select+project+aggregate pipeline — the shape JITQ compiles
    TPC-H Q6 into.  Lowered to the ``fused_select_agg`` Pallas kernel.
    """
    v = _vec(ins[0])
    pred: Expr = params["pred"]
    if pred.infer(v.schema).domain != "bool":
        raise TypeError("FusedSelectAgg predicate not boolean")
    aggs: Tuple[AggSpec, ...] = tuple(params["aggs"])
    fields = tuple((a.name, a.result_atom(v.schema)) for a in aggs)
    return [Single(TupleType(fields))]


@op("vec.FinalizeSingle")
def _finalize_single(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """FinalizeSingle(exprs)(Single⟨T⟩) → Single⟨T'⟩ — scalar post-arithmetic.

    Finalizes decomposed aggregates (avg = sum/count, ratios, ...)."""
    (s,) = ins
    if not is_coll(s) or s.kind.name != "Single":
        raise TypeError(f"FinalizeSingle of non-Single {s.render()}")
    exprs: Tuple[Tuple[str, Expr], ...] = tuple(params["exprs"])
    fields = tuple((n, e.infer(s.schema)) for n, e in exprs)
    return [Single(TupleType(fields))]


@op("vec.SortByKey")
def _sortbykey(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """SortByKey(keys)(Vec⟨T⟩) → Vec⟨T⟩ (valid rows first, stable)."""
    v = _vec(ins[0])
    return [v.with_kind(v.kind)]


@op("vec.GroupAggSorted", aggregation={"kind": "grouped"})
def _groupagg_sorted(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """GroupAggSorted(keys, aggs, max_groups)(Vec⟨T⟩) → Vec⟨keys+aggs⟩.

    Grouped aggregation over key-sorted input via segment reduction — the
    TPU-native replacement for hash aggregation (lowered to the ``segsum``
    Pallas kernel for the numeric part).
    """
    v = _vec(ins[0])
    keys: Tuple[str, ...] = tuple(params["keys"])
    aggs: Tuple[AggSpec, ...] = tuple(params["aggs"])
    fields = tuple((k, v.schema.field(k)) for k in keys)
    fields += tuple((a.name, a.result_atom(v.schema)) for a in aggs)
    return [Vec(TupleType(fields), int(params["max_groups"]))]


@op("vec.GroupAggDirect", aggregation={"kind": "grouped"})
def _groupagg_direct(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """GroupAggDirect(keys, aggs, max_groups, key_domains, num_buckets[, pred])
    (Vec⟨T⟩) → Vec⟨keys+aggs⟩.

    The sort-FREE grouped aggregation: when catalog statistics bound the
    composite key domain (``key_domains`` = per-key (lo, hi)), each row's
    group is a static function of its key values, so the backend
    segment-reduces straight into ``num_buckets`` dense buckets — O(n), no
    sort, no gather — and compacts non-empty buckets to ``max_groups``.
    The optional ``pred`` is a fused MaskSelect predicate (lowered to the
    ``grouped_select_agg`` Pallas kernel under ``use_kernels``).
    """
    v = _vec(ins[0])
    keys: Tuple[str, ...] = tuple(params["keys"])
    key_domains = tuple(params["key_domains"])
    if len(key_domains) != len(keys):
        raise TypeError("GroupAggDirect: key_domains must match keys")
    n_buckets = 1
    for lo, hi in key_domains:
        n_buckets *= int(hi) - int(lo) + 1
    if int(params["num_buckets"]) != n_buckets:
        raise TypeError(
            f"GroupAggDirect: num_buckets {params['num_buckets']} does not "
            f"match key domain product {n_buckets}")
    pred = params.get("pred")
    if pred is not None and pred.infer(v.schema).domain != "bool":
        raise TypeError("GroupAggDirect predicate not boolean")
    aggs: Tuple[AggSpec, ...] = tuple(params["aggs"])
    fields = tuple((k, v.schema.field(k)) for k in keys)
    fields += tuple((a.name, a.result_atom(v.schema)) for a in aggs)
    return [Vec(TupleType(fields), int(params["max_groups"]))]


@op("vec.DictEncode", elementwise=True)
def _dictencode(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """DictEncode(cols, modes, tables, lows, cards)(Vec⟨T⟩) → Vec⟨T'⟩.

    Re-encodes key columns to dense dictionary ranks ``[0, card)`` so the
    sort-free direct tiers apply to sparse/wide key domains.  Per column:
    ``mode`` is ``"remap"`` (O(1) gather through a span-sized rank table)
    or ``"searchsorted"`` (log(card) binary search in the sorted value
    table); out-of-dictionary values get the sentinel rank ``card`` —
    outside every declared rank domain, so a direct probe can never alias a
    real bucket.  Encoded columns become i32.
    """
    v = _vec(ins[0])
    cols = tuple(params["cols"])
    if not cols:
        raise TypeError("DictEncode with no columns")
    for name in ("modes", "tables", "lows", "cards"):
        if len(tuple(params[name])) != len(cols):
            raise TypeError(f"DictEncode: {name} must match cols")
    for c in cols:
        v.schema.field(c)  # raises on unknown column
    enc = set(cols)
    fields = tuple((n, I32 if n in enc else t) for n, t in v.schema.fields)
    return [Vec(TupleType(fields), _cap(v))]


@op("vec.DictDecode", elementwise=True)
def _dictdecode(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """DictDecode(cols, tables, atoms)(Vec⟨T⟩) → Vec⟨T'⟩.

    Gathers ranks back to raw values through the sorted value tables —
    applied *decode-late*: only to surviving group/join key columns after
    compaction, never to full inputs.  ``atoms`` restores each column's
    pre-encoding atom.
    """
    v = _vec(ins[0])
    cols = tuple(params["cols"])
    atoms = tuple(params["atoms"])
    if len(tuple(params["tables"])) != len(cols) or len(atoms) != len(cols):
        raise TypeError("DictDecode: tables/atoms must match cols")
    back = dict(zip(cols, atoms))
    for c in cols:
        v.schema.field(c)
    fields = tuple((n, back.get(n, t)) for n, t in v.schema.fields)
    return [Vec(TupleType(fields), _cap(v))]


@op("vec.BuildHTable")
def _buildhtable(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """BuildHTable()(Vec⟨T⟩) → Single⟨HTab⟨T⟩⟩ (keys = params['keys'])."""
    v = _vec(ins[0])
    return [Single(HTab(v.item))]


@op("vec.ProbeHTable")
def _probehtable(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ProbeHTable(left_on, right_on, max_count)(Vec⟨T1⟩, Single⟨HTab⟨T2⟩⟩) → Vec⟨T3⟩."""
    probe = _vec(ins[0])
    ht = ins[1]
    if not is_coll(ht) or not is_coll(ht.item):
        raise TypeError("ProbeHTable second input must be Single⟨HTab⟩")
    build_item = ht.item.item
    schema = join_schema(probe.schema, build_item, tuple(params["left_on"]), tuple(params["right_on"]))
    return [Vec(schema, int(params["max_count"]))]


@op("vec.MergeJoinSorted")
def _mergejoin(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """MergeJoinSorted(left_on, right_on, max_count)(Vec⟨L⟩, Vec⟨R⟩) → Vec⟨L⋈R⟩.

    Sort-based equi-join (searchsorted + gather) — the TPU-native rewrite
    target of BuildHTable+ProbeHTable.  ``max_count`` is the static output
    bound (for FK joins: the probe-side capacity).
    """
    l, r = _vec(ins[0]), _vec(ins[1])
    schema = join_schema(l.schema, r.schema, tuple(params["left_on"]), tuple(params["right_on"]))
    return [Vec(schema, int(params["max_count"]))]


@op("vec.HashJoinDirect")
def _hashjoin_direct(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """HashJoinDirect(left_on, right_on, max_count[, key_domains | num_buckets])
    (Vec⟨L⟩, Vec⟨R⟩) → Vec⟨L⋈R⟩.

    Sort-free PK-FK equi-join: the build side scatters into a dense direct
    table over the composite key domain and every probe is one gather — no
    sort, no searchsorted (the join sibling of GroupAggDirect).  With static
    ``key_domains`` the table size is the domain product; without, the
    bounds are traced jointly from the data against a static ``num_buckets``
    budget, with a per-instruction in-trace fallback to the sorted merge.
    """
    l, r = _vec(ins[0]), _vec(ins[1])
    left_on = tuple(params["left_on"])
    right_on = tuple(params["right_on"])
    key_domains = params.get("key_domains")
    if key_domains is not None:
        if len(tuple(key_domains)) != len(left_on):
            raise TypeError("HashJoinDirect: key_domains must match join keys")
        n_buckets = 1
        for lo, hi in key_domains:
            n_buckets *= int(hi) - int(lo) + 1
        if n_buckets <= 0:
            raise TypeError("HashJoinDirect: empty key domain")
    elif params.get("num_buckets") is None:
        raise TypeError("HashJoinDirect needs key_domains or a num_buckets "
                        "budget for the dynamic-bounds variant")
    schema = join_schema(l.schema, r.schema, left_on, right_on)
    return [Vec(schema, int(params["max_count"]))]


@op("vec.FusedJoinGroupAgg", aggregation={"kind": "grouped"})
def _fused_join_group_agg(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """FusedJoinGroupAgg(pred, left_on, right_on, join_key_domains,
    join_num_buckets, keys, aggs, max_groups, key_domains, num_buckets)
    (Vec⟨L⟩, Vec⟨R⟩) → Vec⟨keys+aggs⟩.

    Whole-pipeline select→join→group operator: the probe-side predicate,
    the direct-table probe and the dense grouped reduction run in a single
    pass — the join result is never materialized (no intermediate Vec, no
    compact).  Both the join key domain and the group key domain must be
    statically bounded; the ``grouped_join_agg`` Pallas kernel backs it
    under ``use_kernels``.
    """
    l, r = _vec(ins[0]), _vec(ins[1])
    left_on = tuple(params["left_on"])
    right_on = tuple(params["right_on"])
    jkd = tuple(params["join_key_domains"])
    if len(jkd) != len(left_on):
        raise TypeError("FusedJoinGroupAgg: join_key_domains must match join keys")
    njb = 1
    for lo, hi in jkd:
        njb *= int(hi) - int(lo) + 1
    if int(params["join_num_buckets"]) != njb:
        raise TypeError(
            f"FusedJoinGroupAgg: join_num_buckets {params['join_num_buckets']} "
            f"does not match join key domain product {njb}")
    joined = join_schema(l.schema, r.schema, left_on, right_on)
    pred = params.get("pred")
    if pred is not None:
        if pred.infer(l.schema).domain != "bool":
            raise TypeError("FusedJoinGroupAgg predicate not boolean")
    keys: Tuple[str, ...] = tuple(params["keys"])
    key_domains = tuple(params["key_domains"])
    if len(key_domains) != len(keys):
        raise TypeError("FusedJoinGroupAgg: key_domains must match keys")
    ngb = 1
    for lo, hi in key_domains:
        ngb *= int(hi) - int(lo) + 1
    if int(params["num_buckets"]) != ngb:
        raise TypeError(
            f"FusedJoinGroupAgg: num_buckets {params['num_buckets']} does not "
            f"match group key domain product {ngb}")
    aggs: Tuple[AggSpec, ...] = tuple(params["aggs"])
    fields = tuple((k, joined.field(k)) for k in keys)
    fields += tuple((a.name, a.result_atom(joined)) for a in aggs)
    return [Vec(TupleType(fields), int(params["max_groups"]))]


@op("vec.LimitVec")
def _limitvec(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """LimitVec(k)(Vec⟨T⟩) → Vec⟨T⟩ — keep the first k valid rows."""
    v = _vec(ins[0])
    return [v]


@op("vec.TopKVec")
def _topk(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """TopKVec(keys, ascending, k)(Vec⟨T⟩) → Vec⟨T⟩[k]."""
    v = _vec(ins[0])
    return [Vec(v.item, int(params["k"]))]


@op("vec.HistogramPartition")
def _histpart(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """HistogramPartition(key, n)(Vec⟨T⟩) → Seq[n]⟨Vec⟨T⟩⟩.

    Radix/range partition by key — the building block of the distributed
    Exchange (paper: MPIHistogram + MPIExchange).  Per-partition capacity is
    the full input capacity (worst-case skew) unless ``per_cap`` given.
    """
    v = _vec(ins[0])
    n = int(params["n"])
    cap = int(params.get("per_cap") or _cap(v))
    return [split_type(Vec(v.item, cap), n)]


# ---------------------------------------------------------------------------
# streaming state (micro-batched incremental execution)
# ---------------------------------------------------------------------------


@op("vec.MergeGroupedState", aggregation={"kind": "grouped"})
def _merge_grouped_state(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """MergeGroupedState(keys, aggs, max_groups[, key_domains, num_buckets])
    (Vec⟨keys+aggs⟩, Vec⟨keys+aggs⟩) → Vec⟨keys+aggs⟩.

    The streaming merge op: fold a micro-batch's grouped *partial*
    aggregate (delta) into the running state.  Both operands and the result
    share one schema and capacity ``max_groups`` — the op is the carried
    accumulator of the streaming target's step function.  ``aggs`` are the
    ORIGINAL AggSpecs; the backend combines each partial column with its
    ``combine_fn`` (sum-of-sums, sum-of-counts, min-of-mins).  With
    ``key_domains``/``num_buckets`` the merge runs on the sort-free dense
    buckets (the GroupAggDirect accumulator carried across batches).
    """
    state, delta = _vec(ins[0]), _vec(ins[1])
    if state.item != delta.item:
        raise TypeError(
            f"MergeGroupedState: state schema {state.render()} != delta "
            f"schema {delta.render()}")
    keys: Tuple[str, ...] = tuple(params["keys"])
    aggs: Tuple[AggSpec, ...] = tuple(params["aggs"])
    names = set(state.schema.names)
    for k in keys:
        if k not in names:
            raise TypeError(f"MergeGroupedState: key {k!r} not in state schema")
    for a in aggs:
        if a.name not in names:
            raise TypeError(f"MergeGroupedState: agg {a.name!r} not in state schema")
    key_domains = params.get("key_domains")
    if key_domains is not None and len(tuple(key_domains)) != len(keys):
        raise TypeError("MergeGroupedState: key_domains must match keys")
    return [Vec(state.item, int(params["max_groups"]))]


@op("vec.MergeScalarState", aggregation={"kind": "scalar"})
def _merge_scalar_state(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """MergeScalarState(aggs)(Single⟨aggs⟩, Single⟨aggs⟩) → Single⟨aggs⟩.

    Scalar sibling of MergeGroupedState: combine two Single partial
    aggregates field-wise with each agg's ``combine_fn``.
    """
    state, delta = ins
    for s in (state, delta):
        if not is_coll(s) or s.kind.name != "Single":
            raise TypeError(f"MergeScalarState of non-Single {s.render()}")
    if state.item != delta.item:
        raise TypeError("MergeScalarState: state/delta schema mismatch")
    names = set(state.schema.names)
    for a in tuple(params["aggs"]):
        if a.name not in names:
            raise TypeError(f"MergeScalarState: agg {a.name!r} not in state schema")
    return [Single(state.schema)]
