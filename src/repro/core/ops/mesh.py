"""SPMD mesh backend flavor — the Modularis/Lambada analogue on TPU.

``mesh.MeshExecute`` is the platform-specific version of
``cf.ConcurrentExecute`` (paper: MPIExecutor / ParallelLambdaMap): the chunk
axis becomes a named mesh axis and the nested program runs as one SPMD
program per device along that axis.  Collective instructions appear *inside*
the nested program and lower to ``jax.lax`` collectives.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

from ..program import Program
from ..registry import op
from ..types import SEQ, CollectionType, ItemType, Vec, is_coll
from .controlflow import split_type


@op("mesh.MeshExecute")
def _mesh_execute(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """MeshExecute(P, axis)(S1..Sk) — ConcurrentExecute bound to a mesh axis."""
    p: Program = params["P"]
    n = None
    if len(ins) != len(p.inputs):
        raise TypeError("MeshExecute arity mismatch")
    for t, pin in zip(ins, p.inputs):
        if not is_coll(t, SEQ):
            raise TypeError(f"MeshExecute input must be Seq-of-chunks, got {t.render()}")
        tn = t.attr("n")
        n = tn if n is None else n
        if tn != n:
            raise TypeError("MeshExecute inputs disagree on worker count")
        if t.item != pin.type:
            raise TypeError(
                f"MeshExecute input {t.item.render()} != program input {pin.type.render()}"
            )
    return [split_type(r.type, n) for r in p.results]


@op("mesh.AllReduce", barrier=True)
def _allreduce(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """AllReduce(op, axis)(X) → X — reduce across the axis, replicate result."""
    return [ins[0]]


@op("mesh.AllGatherVec", barrier=True)
def _allgather(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """AllGatherVec(axis, n)(Vec⟨T⟩) → Vec⟨T⟩ with n× capacity."""
    (v,) = ins
    if not is_coll(v):
        raise TypeError("AllGatherVec of non-collection")
    cap = v.attr("max_count")
    n = int(params["n"])
    return [Vec(v.item, cap * n if cap else None)]


@op("mesh.ReduceScatter", barrier=True)
def _reducescatter(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ReduceScatter(op, axis, n)(X) → X/n — reduce and shard along the axis."""
    return [ins[0]]


@op("mesh.AllToAll", barrier=True)
def _alltoall(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """AllToAll(axis)(Seq[n]⟨Vec⟨T⟩⟩) → Seq[n]⟨Vec⟨T⟩⟩ — transpose chunks/devices."""
    (s,) = ins
    if not is_coll(s, SEQ):
        raise TypeError("AllToAll of non-split type")
    return [s]


@op("mesh.ExchangeByKey", barrier=True)
def _exchange(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ExchangeByKey(key, axis, n)(Vec⟨T⟩) → Vec⟨T⟩.

    Shuffle rows so equal keys land on the same device: histogram partition +
    all-to-all + concat (paper: MPIHistogram + MPIExchange).  Capacity grows
    by the skew factor (worst case n×; default 2× with runtime validity).
    """
    (v,) = ins
    cap = v.attr("max_count")
    skew = float(params.get("skew", 2.0))
    newcap = int(cap * skew) if cap else None
    return [Vec(v.item, newcap)]


@op("mesh.PPermute", barrier=True)
def _ppermute(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """PPermute(perm, axis)(X) → X — neighbor exchange (ring schedules)."""
    return [ins[0]]


@op("mesh.ShardConstraint")
def _shard_constraint(params: Mapping[str, Any], ins: Sequence[ItemType]) -> Sequence[ItemType]:
    """ShardConstraint(spec)(X) → X — annotate partitioning for the lowering."""
    return [ins[0]]
