"""Program verifier: SSA discipline + typing rules.

Any rewriting must leave programs verifiable — tests call ``verify`` after
every pass.  Semantics must be preserved "as if executed on the abstract
machine"; this checks the static half of that contract.
"""

from __future__ import annotations

from typing import List, Sequence, Set

from . import registry
from .program import Instruction, Program
from .types import ItemType


class VerificationError(Exception):
    pass


def verify(program: Program, *, allow_unknown_ops: bool = True) -> None:
    """Raise ``VerificationError`` on SSA or typing violations.

    Checks, per program (recursing into nested programs):
      * every register is assigned exactly once (SSA);
      * every use is dominated by its definition (linear order);
      * register types at use sites match their definitions;
      * output types match the opcode's typing rule (if registered);
      * results refer to defined registers.
    """
    _verify_one(program, allow_unknown_ops, path=program.name)


def _verify_one(program: Program, allow_unknown: bool, path: str) -> None:
    defined: Set[str] = set()
    types: dict = {}
    for r in program.inputs:
        if r.name in defined:
            raise VerificationError(f"{path}: duplicate input register {r.name}")
        defined.add(r.name)
        types[r.name] = r.type

    for idx, ins in enumerate(program.body):
        where = f"{path}[{idx}] {ins.opcode}"
        # uses
        for r in ins.inputs:
            if r.name not in defined:
                raise VerificationError(f"{where}: use of undefined register %{r.name}")
            if types[r.name] != r.type:
                raise VerificationError(
                    f"{where}: register %{r.name} used at type {r.type.render()} "
                    f"but defined at {types[r.name].render()}"
                )
        # typing rule
        spec = registry.lookup(ins.opcode)
        if spec is None:
            if not allow_unknown:
                raise VerificationError(f"{where}: unknown opcode")
        else:
            try:
                expected = list(spec.signature(dict(ins.params), [r.type for r in ins.inputs]))
            except Exception as e:  # typing rule rejected the inputs
                raise VerificationError(f"{where}: typing rule failed: {e}") from e
            actual = [r.type for r in ins.outputs]
            if len(expected) != len(actual):
                raise VerificationError(
                    f"{where}: arity mismatch, rule gives {len(expected)} outputs, "
                    f"instruction has {len(actual)}"
                )
            for i, (e, a) in enumerate(zip(expected, actual)):
                if e != a:
                    raise VerificationError(
                        f"{where}: output {i} type {a.render()} != rule type {e.render()}"
                    )
        # defs
        for r in ins.outputs:
            if r.name in defined:
                raise VerificationError(f"{where}: register %{r.name} assigned twice (SSA)")
            defined.add(r.name)
            types[r.name] = r.type
        # nested programs
        for pname, p in ins.nested_programs():
            _verify_one(p, allow_unknown, path=f"{path}/{ins.opcode}.{pname}:{p.name}")

    for r in program.results:
        if r.name not in defined:
            raise VerificationError(f"{path}: Return of undefined register %{r.name}")
        if types[r.name] != r.type:
            raise VerificationError(
                f"{path}: Return register %{r.name} at type {r.type.render()} "
                f"but defined at {types[r.name].render()}"
            )


def verify_types_only(types_a: Sequence[ItemType], types_b: Sequence[ItemType]) -> bool:
    return len(types_a) == len(types_b) and all(a == b for a, b in zip(types_a, types_b))
