"""CVM core: the IR language (types, programs, registry, verifier, passes).

Public surface::

    from repro.core import (
        types, expr,               # the grammar + expressions
        Builder, Program, Instruction, Register,
        verify, register_op,
    )

Importing ``repro.core`` loads the standard IR flavors (cf/df/rel/la/vec/
mesh/tz) into the registry.
"""

from . import types, expr  # noqa: F401
from .program import Builder, Instruction, Program, Register, subprogram  # noqa: F401
from .registry import (  # noqa: F401
    OpSpec, ensure_flavors_loaded, infer_output_types, lookup, op, register_op,
    registered_opcodes, require,
)
from .verify import VerificationError, verify  # noqa: F401

ensure_flavors_loaded()
