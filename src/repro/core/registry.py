"""Instruction registry: the open vocabulary of the CVM IR language.

The IR language fixes the *shape* of instructions (SSA, typed registers,
constant/program parameters); this registry holds the *vocabulary* — each
frontend/backend flavor registers its opcodes here together with

  * a signature function (typing rule): ``(params, in_types) -> out_types``
  * semantic flags used by generic rewritings:
      - ``pure``: no side effects (all but data sources/sinks)
      - ``elementwise``: commutes with ``cf.Split`` — the parallelization
        rewrite may push it inside ``ConcurrentExecute`` unchanged
      - ``aggregation``: decomposition for the pre-aggregation rewrite
        (paper Alg. 2): a dict of {pre, combine, finalize} opcode/param info
      - ``source`` / ``sink``: pins instruction to the orchestration layer
      - ``barrier``: may not be reordered across (e.g. collectives)

Unknown opcodes are allowed inside programs (the paper: a rewrite rule that
encounters an unknown instruction "leaves it as is"), but the verifier warns
and the lowering requires an emitter.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

from .types import ItemType

SignatureFn = Callable[[Mapping[str, Any], Sequence[ItemType]], Sequence[ItemType]]


@dataclass
class OpSpec:
    opcode: str
    signature: SignatureFn
    pure: bool = True
    elementwise: bool = False
    source: bool = False
    sink: bool = False
    barrier: bool = False
    aggregation: Optional[Dict[str, Any]] = None
    doc: str = ""


_REGISTRY: Dict[str, OpSpec] = {}


def register_op(
    opcode: str,
    signature: SignatureFn,
    *,
    pure: bool = True,
    elementwise: bool = False,
    source: bool = False,
    sink: bool = False,
    barrier: bool = False,
    aggregation: Optional[Dict[str, Any]] = None,
    doc: str = "",
    overwrite: bool = False,
) -> OpSpec:
    if opcode in _REGISTRY and not overwrite:
        raise ValueError(f"opcode {opcode!r} already registered")
    spec = OpSpec(
        opcode=opcode,
        signature=signature,
        pure=pure,
        elementwise=elementwise,
        source=source,
        sink=sink,
        barrier=barrier,
        aggregation=aggregation,
        doc=doc,
    )
    _REGISTRY[opcode] = spec
    return spec


def op(opcode: str, **flags: Any) -> Callable[[SignatureFn], SignatureFn]:
    """Decorator form: the decorated function is the typing rule."""

    def deco(fn: SignatureFn) -> SignatureFn:
        register_op(opcode, fn, doc=fn.__doc__ or "", **flags)
        return fn

    return deco


def lookup(opcode: str) -> Optional[OpSpec]:
    return _REGISTRY.get(opcode)


def require(opcode: str) -> OpSpec:
    spec = _REGISTRY.get(opcode)
    if spec is None:
        raise KeyError(f"opcode {opcode!r} is not registered in any IR flavor")
    return spec


def registered_opcodes(flavor: Optional[str] = None) -> List[str]:
    if flavor is None:
        return sorted(_REGISTRY)
    return sorted(o for o in _REGISTRY if o.startswith(flavor + "."))


def infer_output_types(
    opcode: str, params: Mapping[str, Any], in_types: Sequence[ItemType]
) -> Sequence[ItemType]:
    spec = require(opcode)
    out = spec.signature(params, in_types)
    return list(out)


def ensure_flavors_loaded() -> None:
    """Import the standard flavor modules (idempotent)."""
    from .ops import controlflow, dataflow, linalg, mesh, relational, tensor, vec  # noqa: F401
