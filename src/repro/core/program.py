"""CVM programs: SSA sequences of collection instructions.

The abstract machine (paper §3.2) has an unlimited number of immutable
registers holding collections and executes linear sequences of instructions::

    Out_1, ..., Out_m ← Instruction(Para_1, ..., Para_k)(In_1, ..., In_n)

Parameters are constant items *and nested programs* (higher-order
instructions).  Programs are always in SSA form; any transformation must
preserve behaviour *as if executed on that machine*.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Iterable, Iterator, List, Mapping, Optional, Sequence, Tuple

from .types import ItemType


# ---------------------------------------------------------------------------
# Registers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Register:
    """An immutable virtual register holding one collection."""

    name: str
    type: ItemType

    def render(self) -> str:
        return f"%{self.name}"

    def __repr__(self) -> str:  # pragma: no cover
        return f"%{self.name}: {self.type.render()}"


class _NameGen:
    def __init__(self, prefix: str = "r") -> None:
        self._c = itertools.count()
        self.prefix = prefix

    def fresh(self, hint: Optional[str] = None) -> str:
        return f"{hint or self.prefix}{next(self._c)}"


# ---------------------------------------------------------------------------
# Instructions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Instruction:
    """One CVM instruction.

    ``opcode`` is namespaced by IR flavor, e.g. ``rel.Select``,
    ``la.MMMult``, ``vec.ScanVec``, ``mesh.AllReduce``, ``df.Map``.
    ``params`` maps parameter names to constant items or nested ``Program``s.
    """

    opcode: str
    inputs: Tuple[Register, ...] = ()
    outputs: Tuple[Register, ...] = ()
    params: Tuple[Tuple[str, Any], ...] = ()

    # -- param helpers ------------------------------------------------------
    def param(self, name: str, default: Any = None) -> Any:
        for k, v in self.params:
            if k == name:
                return v
        return default

    def with_params(self, **updates: Any) -> "Instruction":
        d = dict(self.params)
        d.update(updates)
        return replace(self, params=tuple(d.items()))

    def with_inputs(self, inputs: Sequence[Register]) -> "Instruction":
        return replace(self, inputs=tuple(inputs))

    def with_outputs(self, outputs: Sequence[Register]) -> "Instruction":
        return replace(self, outputs=tuple(outputs))

    def with_opcode(self, opcode: str) -> "Instruction":
        return replace(self, opcode=opcode)

    @property
    def flavor(self) -> str:
        return self.opcode.split(".", 1)[0] if "." in self.opcode else ""

    @property
    def name(self) -> str:
        return self.opcode.split(".", 1)[-1]

    def nested_programs(self) -> Iterator[Tuple[str, "Program"]]:
        for k, v in self.params:
            if isinstance(v, Program):
                yield k, v

    def is_higher_order(self) -> bool:
        return any(True for _ in self.nested_programs())

    def map_nested(self, fn: Callable[["Program"], "Program"]) -> "Instruction":
        new_params = tuple(
            (k, fn(v) if isinstance(v, Program) else v) for k, v in self.params
        )
        return replace(self, params=new_params)

    def render(self) -> str:
        outs = ", ".join(r.render() for r in self.outputs)
        ins = ", ".join(r.render() for r in self.inputs)
        ps = []
        for k, v in self.params:
            if isinstance(v, Program):
                ps.append(f"{k}=@{v.name}")
            else:
                ps.append(f"{k}={v!r}")
        para = ", ".join(ps)
        head = f"{outs} ← " if outs else ""
        return f"{head}{self.opcode}({para})({ins})"


# ---------------------------------------------------------------------------
# Programs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Program:
    """An SSA sequence of instructions with declared inputs and results.

    ``results`` plays the role of the paper's ``Return`` instruction: the
    registers whose values the program yields.
    """

    name: str
    inputs: Tuple[Register, ...]
    body: Tuple[Instruction, ...]
    results: Tuple[Register, ...]

    # -- structural queries --------------------------------------------------
    def defs(self) -> Dict[str, Register]:
        d = {r.name: r for r in self.inputs}
        for ins in self.body:
            for r in ins.outputs:
                d[r.name] = r
        return d

    def producers(self) -> Dict[str, Instruction]:
        p: Dict[str, Instruction] = {}
        for ins in self.body:
            for r in ins.outputs:
                p[r.name] = ins
        return p

    def consumers(self) -> Dict[str, List[Instruction]]:
        c: Dict[str, List[Instruction]] = {}
        for ins in self.body:
            for r in ins.inputs:
                c.setdefault(r.name, []).append(ins)
        for r in self.results:
            c.setdefault(r.name, [])
        return c

    def uses(self, reg: Register) -> int:
        n = sum(1 for ins in self.body for r in ins.inputs if r.name == reg.name)
        n += sum(1 for r in self.results if r.name == reg.name)
        return n

    def result_types(self) -> Tuple[ItemType, ...]:
        return tuple(r.type for r in self.results)

    def input_types(self) -> Tuple[ItemType, ...]:
        return tuple(r.type for r in self.inputs)

    # -- rewriting helpers ---------------------------------------------------
    def with_body(self, body: Sequence[Instruction]) -> "Program":
        return replace(self, body=tuple(body))

    def with_results(self, results: Sequence[Register]) -> "Program":
        return replace(self, results=tuple(results))

    def with_name(self, name: str) -> "Program":
        return replace(self, name=name)

    def map_instructions(self, fn: Callable[[Instruction], Sequence[Instruction]]) -> "Program":
        """Replace each instruction by a sequence (1->n rewriting)."""
        new_body: List[Instruction] = []
        for ins in self.body:
            new_body.extend(fn(ins))
        return self.with_body(new_body)

    def substitute(self, mapping: Mapping[str, Register]) -> "Program":
        """Rename register *uses* (not defs) according to ``mapping``."""

        def sub(r: Register) -> Register:
            return mapping.get(r.name, r)

        body = tuple(
            ins.with_inputs([sub(r) for r in ins.inputs]) for ins in self.body
        )
        return replace(
            self,
            body=body,
            results=tuple(sub(r) for r in self.results),
        )

    def rename_all(self, suffix: str) -> "Program":
        """Alpha-rename every register (inputs, defs, uses) with a suffix.

        Used when inlining/copying programs so SSA names stay unique.
        """

        mapping = {r.name: Register(r.name + suffix, r.type) for r in self.inputs}
        for ins in self.body:
            for r in ins.outputs:
                mapping[r.name] = Register(r.name + suffix, r.type)

        def sub(r: Register) -> Register:
            return mapping.get(r.name, r)

        body = tuple(
            ins.with_inputs([sub(r) for r in ins.inputs]).with_outputs(
                [sub(r) for r in ins.outputs]
            )
            for ins in self.body
        )
        return Program(
            name=self.name,
            inputs=tuple(sub(r) for r in self.inputs),
            body=body,
            results=tuple(sub(r) for r in self.results),
        )

    def walk(self) -> Iterator["Program"]:
        """Yield this program and all nested programs, depth-first."""
        yield self
        for ins in self.body:
            for _, p in ins.nested_programs():
                yield from p.walk()

    def opcodes(self) -> List[str]:
        return [ins.opcode for p in self.walk() for ins in p.body]

    def render(self, indent: int = 0) -> str:
        pad = "  " * indent
        lines = [
            f"{pad}program {self.name}("
            + ", ".join(f"{r.render()}: {r.type.render()}" for r in self.inputs)
            + ")"
        ]
        for ins in self.body:
            lines.append(pad + "  " + ins.render())
            for _, p in ins.nested_programs():
                lines.append(p.render(indent + 2))
        lines.append(pad + "  Return(" + ", ".join(r.render() for r in self.results) + ")")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return self.render()


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


class Builder:
    """Imperative construction of SSA programs with automatic typing.

    Typing rules come from the instruction registry (``core.registry``): the
    builder calls the opcode's signature function to derive output types, so
    frontends never write types by hand.
    """

    def __init__(self, name: str, prefix: str = "r") -> None:
        self.name = name
        self._names = _NameGen(prefix)
        self._inputs: List[Register] = []
        self._body: List[Instruction] = []

    # -- inputs --------------------------------------------------------------
    def input(self, hint: str, type: ItemType) -> Register:
        r = Register(self._names.fresh(hint), type)
        self._inputs.append(r)
        return r

    def fresh(self, type: ItemType, hint: Optional[str] = None) -> Register:
        return Register(self._names.fresh(hint), type)

    # -- emission --------------------------------------------------------------
    def emit(
        self,
        opcode: str,
        inputs: Sequence[Register] = (),
        params: Optional[Mapping[str, Any]] = None,
        out_types: Optional[Sequence[ItemType]] = None,
        out_hints: Optional[Sequence[str]] = None,
    ) -> Tuple[Register, ...]:
        from .registry import infer_output_types  # local import to avoid cycle

        params = dict(params or {})
        if out_types is None:
            out_types = infer_output_types(opcode, params, [r.type for r in inputs])
        hints = list(out_hints or [])
        outs = tuple(
            Register(self._names.fresh(hints[i] if i < len(hints) else None), t)
            for i, t in enumerate(out_types)
        )
        self._body.append(
            Instruction(
                opcode=opcode,
                inputs=tuple(inputs),
                outputs=outs,
                params=tuple(params.items()),
            )
        )
        return outs

    def emit1(self, opcode: str, inputs: Sequence[Register] = (), params: Optional[Mapping[str, Any]] = None,
              out_type: Optional[ItemType] = None, hint: Optional[str] = None) -> Register:
        outs = self.emit(
            opcode, inputs, params,
            out_types=[out_type] if out_type is not None else None,
            out_hints=[hint] if hint else None,
        )
        if len(outs) != 1:
            raise ValueError(f"{opcode} produced {len(outs)} outputs, expected 1")
        return outs[0]

    def append(self, ins: Instruction) -> None:
        self._body.append(ins)

    def finish(self, *results: Register) -> Program:
        return Program(
            name=self.name,
            inputs=tuple(self._inputs),
            body=tuple(self._body),
            results=tuple(results),
        )


def subprogram(name: str, inputs: Sequence[Tuple[str, ItemType]],
               build: Callable[[Builder, Tuple[Register, ...]], Sequence[Register]]) -> Program:
    """Convenience for nested-program parameters of higher-order instructions."""
    b = Builder(name)
    regs = tuple(b.input(n, t) for n, t in inputs)
    results = build(b, regs)
    return b.finish(*results)
