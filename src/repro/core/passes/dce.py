"""Dead code elimination: drop pure instructions whose outputs are unused."""

from __future__ import annotations

from typing import List, Optional, Set

from .. import registry
from ..program import Program
from .rewriter import ProgramRule


class DeadCodeElimination(ProgramRule):
    name = "dce"

    def run(self, program: Program) -> Optional[Program]:
        live: Set[str] = {r.name for r in program.results}
        keep = [False] * len(program.body)
        # backward liveness sweep
        for i in range(len(program.body) - 1, -1, -1):
            ins = program.body[i]
            spec = registry.lookup(ins.opcode)
            pure = spec.pure if spec is not None else False  # unknown ops: keep
            has_live_out = any(r.name in live for r in ins.outputs)
            if has_live_out or not pure or (spec is not None and spec.sink):
                keep[i] = True
                for r in ins.inputs:
                    live.add(r.name)
        if all(keep):
            return None
        return program.with_body([ins for ins, k in zip(program.body, keep) if k])
