"""Mesh-flavor lowering rules (the SPMD backend's pipeline stages).

These are *backend-specific rewritings* (paper §3.6: every frontend/backend
combination gets the rewritings best suited for it).  They used to live
inside the SPMD backend's ``compile``; now they are ordinary passes that the
compilation driver registers as the tail of the ``spmd``/``multipod``
lowering paths (see ``repro.compiler.targets``):

  * ``LowerToMesh`` — ``cf.ConcurrentExecute`` → ``mesh.MeshExecute(axis)``:
    the chunk axis becomes a named mesh axis, so the nested program runs
    under ``jax.shard_map`` as ONE SPMD program for all workers.
  * ``PushCombineIntoMesh`` — a ``CombineChunks(sum)``/``CombinePartials``
    following a MeshExecute is pulled inside the nested program as a
    ``mesh.AllReduce`` — the paper's pre-aggregation becoming a collective
    instead of a gather+reduce.
"""

from __future__ import annotations

from typing import Optional

from ..program import Instruction, Program, Register
from .rewriter import ProgramRule


class LowerToMesh(ProgramRule):
    """cf.ConcurrentExecute → mesh.MeshExecute(axis)."""

    name = "lower-to-mesh"

    def __init__(self, axis: str = "workers") -> None:
        self.axis = axis

    def run(self, program: Program) -> Optional[Program]:
        changed = False
        body = []
        for ins in program.body:
            if ins.opcode == "cf.ConcurrentExecute":
                ins = ins.with_opcode("mesh.MeshExecute").with_params(axis=self.axis)
                changed = True
            body.append(ins)
        return program.with_body(body) if changed else None


class PushCombineIntoMesh(ProgramRule):
    """Pull a CombineChunks(sum)/CombinePartials following a MeshExecute into
    the nested program as a mesh.AllReduce — pre-aggregation as collective."""

    name = "push-combine-into-mesh"

    def run(self, program: Program) -> Optional[Program]:
        producers = program.producers()
        for y in program.body:
            if y.opcode not in ("cf.CombineChunks", "rel.CombinePartials"):
                continue
            if y.opcode == "cf.CombineChunks" and y.param("op") != "sum":
                continue
            src = y.inputs[0]
            me = producers.get(src.name)
            if me is None or me.opcode != "mesh.MeshExecute":
                continue
            if program.uses(src) != 1:
                continue
            idx = list(r.name for r in me.outputs).index(src.name)
            inner: Program = me.param("P")
            axis = me.param("axis")

            from ..ops.controlflow import split_type

            res = inner.results[idx]
            red = Register(res.name + "_ar", res.type)
            if y.opcode == "rel.CombinePartials":
                ar = Instruction("mesh.AllReduce", (res,), (red,),
                                 (("op", "combine_aggs"), ("axis", axis),
                                  ("aggs", y.param("aggs"))))
            else:
                ar = Instruction("mesh.AllReduce", (res,), (red,),
                                 (("op", "sum"), ("axis", axis)))
            new_inner = Program(
                name=inner.name, inputs=inner.inputs,
                body=inner.body + (ar,),
                results=tuple(red if i == idx else r for i, r in enumerate(inner.results)),
            )
            new_me_outs = list(me.outputs)
            new_me_outs[idx] = Register(src.name + "_rep", split_type(red.type, src.type.attr("n")))
            new_me = Instruction("mesh.MeshExecute", me.inputs, tuple(new_me_outs),
                                 (("P", new_inner), ("axis", axis)))
            take = Instruction("cf.TakeChunk", (new_me_outs[idx],), y.outputs, (("i", 0),))
            new_body = []
            for ins in program.body:
                if ins is me:
                    new_body.append(new_me)
                elif ins is y:
                    new_body.append(take)
                else:
                    if any(r.name == src.name for r in ins.inputs):
                        ins = ins.with_inputs([new_me_outs[idx] if r.name == src.name else r
                                               for r in ins.inputs])
                    new_body.append(ins)
            return program.with_body(new_body)
        return None
