"""Mesh-flavor lowering rules (the SPMD backend's pipeline stages).

These are *backend-specific rewritings* (paper §3.6: every frontend/backend
combination gets the rewritings best suited for it).  They used to live
inside the SPMD backend's ``compile``; now they are ordinary passes that the
compilation driver registers as the tail of the ``spmd``/``multipod``
lowering paths (see ``repro.compiler.targets``):

  * ``LowerToMesh`` — ``cf.ConcurrentExecute`` → ``mesh.MeshExecute(axis)``:
    the chunk axis becomes a named mesh axis, so the nested program runs
    under ``jax.shard_map`` as ONE SPMD program for all workers.
  * ``PushCombineIntoMesh`` — a ``CombineChunks(sum)``/``CombinePartials``
    following a MeshExecute is pulled inside the nested program as a
    ``mesh.AllReduce`` — the paper's pre-aggregation becoming a collective
    instead of a gather+reduce.
  * ``PushGroupedCombineIntoMesh`` — the *grouped* recombine
    (``Merge → SortByKey → GroupAggSorted`` after a MeshExecute) is turned
    into ``mesh.ExchangeByKey`` + per-shard sort/aggregate inside the mesh
    program (MPIHistogram + MPIExchange): equal keys land on one device, so
    the final aggregation runs sharded instead of gathered onto one host.
    This is an *alternative* physical lowering, not an unconditional
    improvement — for low group cardinality the gather is cheaper — so the
    compilation driver exposes it as a selectable strategy
    (``grouped-recombine: gather | exchange``) and the cost model picks.
"""

from __future__ import annotations

from typing import Optional

from ..program import Instruction, Program, Register
from ..registry import infer_output_types
from .rewriter import ProgramRule


class LowerToMesh(ProgramRule):
    """cf.ConcurrentExecute → mesh.MeshExecute(axis)."""

    name = "lower-to-mesh"

    def __init__(self, axis: str = "workers") -> None:
        self.axis = axis

    def run(self, program: Program) -> Optional[Program]:
        changed = False
        body = []
        for ins in program.body:
            if ins.opcode == "cf.ConcurrentExecute":
                ins = ins.with_opcode("mesh.MeshExecute").with_params(axis=self.axis)
                changed = True
            body.append(ins)
        return program.with_body(body) if changed else None


class PushCombineIntoMesh(ProgramRule):
    """Pull a CombineChunks(sum)/CombinePartials following a MeshExecute into
    the nested program as a mesh.AllReduce — pre-aggregation as collective."""

    name = "push-combine-into-mesh"

    def run(self, program: Program) -> Optional[Program]:
        producers = program.producers()
        for y in program.body:
            if y.opcode not in ("cf.CombineChunks", "rel.CombinePartials"):
                continue
            if y.opcode == "cf.CombineChunks" and y.param("op") != "sum":
                continue
            src = y.inputs[0]
            me = producers.get(src.name)
            if me is None or me.opcode != "mesh.MeshExecute":
                continue
            if program.uses(src) != 1:
                continue
            idx = list(r.name for r in me.outputs).index(src.name)
            inner: Program = me.param("P")
            axis = me.param("axis")

            from ..ops.controlflow import split_type

            res = inner.results[idx]
            red = Register(res.name + "_ar", res.type)
            if y.opcode == "rel.CombinePartials":
                ar = Instruction("mesh.AllReduce", (res,), (red,),
                                 (("op", "combine_aggs"), ("axis", axis),
                                  ("aggs", y.param("aggs"))))
            else:
                ar = Instruction("mesh.AllReduce", (res,), (red,),
                                 (("op", "sum"), ("axis", axis)))
            new_inner = Program(
                name=inner.name, inputs=inner.inputs,
                body=inner.body + (ar,),
                results=tuple(red if i == idx else r for i, r in enumerate(inner.results)),
            )
            new_me_outs = list(me.outputs)
            new_me_outs[idx] = Register(src.name + "_rep", split_type(red.type, src.type.attr("n")))
            new_me = Instruction("mesh.MeshExecute", me.inputs, tuple(new_me_outs),
                                 (("P", new_inner), ("axis", axis)))
            take = Instruction("cf.TakeChunk", (new_me_outs[idx],), y.outputs, (("i", 0),))
            new_body = []
            for ins in program.body:
                if ins is me:
                    new_body.append(new_me)
                elif ins is y:
                    new_body.append(take)
                else:
                    if any(r.name == src.name for r in ins.inputs):
                        ins = ins.with_inputs([new_me_outs[idx] if r.name == src.name else r
                                               for r in ins.inputs])
                    new_body.append(ins)
            return program.with_body(new_body)
        return None


class PushGroupedCombineIntoMesh(ProgramRule):
    """A grouped recombine after a MeshExecute — ``Merge → SortByKey →
    GroupAggSorted`` or the sort-free ``Merge → GroupAggDirect`` — becomes
    ExchangeByKey + per-shard aggregation inside the mesh program.

    Correctness relies only on colocation: partitioning by the first group
    key sends every row of a group to the same device, so the per-shard
    aggregation produces each group exactly once and the outer Merge is a
    plain concatenation of disjoint group sets (compacted back to the
    original ``max_groups`` capacity).  ``skew=n`` reserves worst-case slots
    in the exchange so no rows are ever dropped.
    """

    name = "push-grouped-combine-into-mesh"

    def run(self, program: Program) -> Optional[Program]:
        producers = program.producers()
        for g in program.body:
            if g.opcode not in ("vec.GroupAggSorted", "vec.GroupAggDirect"):
                continue
            sort = None
            if g.opcode == "vec.GroupAggSorted":
                sort = producers.get(g.inputs[0].name)
                if (sort is None or sort.opcode != "vec.SortByKey"
                        or program.uses(g.inputs[0]) != 1):
                    continue
                if tuple(sort.param("keys")) != tuple(g.param("keys")):
                    continue
                merge = producers.get(sort.inputs[0].name)
                merge_out = sort.inputs[0]
            else:
                # the direct (dense-bucket) tier consumes the Merge directly:
                # there is no sort to elide, only the gather to replace
                merge = producers.get(g.inputs[0].name)
                merge_out = g.inputs[0]
            if (merge is None or merge.opcode != "cf.Merge"
                    or program.uses(merge_out) != 1):
                continue
            src = merge.inputs[0]
            me = producers.get(src.name)
            if me is None or me.opcode != "mesh.MeshExecute":
                continue
            if program.uses(src) != 1:
                continue

            idx = [r.name for r in me.outputs].index(src.name)
            inner: Program = me.param("P")
            axis = me.param("axis")
            n = int(src.type.attr("n"))
            keys = tuple(g.param("keys"))
            max_groups = int(g.param("max_groups"))

            # --- extend the nested program: exchange + shard-local re-agg --
            res = inner.results[idx]
            ex_params = {"key": keys[0], "axis": axis, "n": n, "skew": float(n)}
            (ex_t,) = infer_output_types("mesh.ExchangeByKey", ex_params,
                                         [res.type])
            ex = Register(res.name + "_ex", ex_t)
            if g.opcode == "vec.GroupAggSorted":
                sort_params = {"keys": keys}
                (s_t,) = infer_output_types("vec.SortByKey", sort_params, [ex_t])
                srt = Register(res.name + "_st", s_t)
                agg_params = dict(g.params)
                (a_t,) = infer_output_types("vec.GroupAggSorted", agg_params, [s_t])
                agg = Register(res.name + "_ag", a_t)
                tail = (
                    Instruction("mesh.ExchangeByKey", (res,), (ex,),
                                tuple(ex_params.items())),
                    Instruction("vec.SortByKey", (ex,), (srt,),
                                tuple(sort_params.items())),
                    Instruction("vec.GroupAggSorted", (srt,), (agg,),
                                tuple(agg_params.items())),
                )
            else:
                agg_params = dict(g.params)
                (a_t,) = infer_output_types("vec.GroupAggDirect", agg_params, [ex_t])
                agg = Register(res.name + "_ag", a_t)
                tail = (
                    Instruction("mesh.ExchangeByKey", (res,), (ex,),
                                tuple(ex_params.items())),
                    Instruction("vec.GroupAggDirect", (ex,), (agg,),
                                tuple(agg_params.items())),
                )
            new_inner = Program(
                name=inner.name, inputs=inner.inputs,
                body=inner.body + tail,
                results=tuple(agg if i == idx else r
                              for i, r in enumerate(inner.results)),
            )

            # --- rebuild the outer instructions ---------------------------
            me_params = dict(me.params)
            me_params["P"] = new_inner
            me_out_types = infer_output_types("mesh.MeshExecute", me_params,
                                              [r.type for r in me.inputs])
            new_me_outs = tuple(
                Register(src.name + "_gx", t) if i == idx else r
                for i, (r, t) in enumerate(zip(me.outputs, me_out_types)))
            new_me = Instruction("mesh.MeshExecute", me.inputs, new_me_outs,
                                 tuple(me_params.items()))
            (m_t,) = infer_output_types("cf.Merge", {}, [new_me_outs[idx].type])
            gathered = Register(src.name + "_gm", m_t)
            new_merge = Instruction("cf.Merge", (new_me_outs[idx],), (gathered,))
            compact = Instruction("vec.Compact", (gathered,), g.outputs,
                                  (("max_count", max_groups),))

            new_body = []
            for ins in program.body:
                if ins is me:
                    new_body.append(new_me)
                elif ins is merge:
                    new_body.append(new_merge)
                elif ins is sort:
                    continue
                elif ins is g:
                    new_body.append(compact)
                else:
                    if any(r.name == src.name for r in ins.inputs):
                        ins = ins.with_inputs(
                            [new_me_outs[idx] if r.name == src.name else r
                             for r in ins.inputs])
                    new_body.append(ins)
            results = tuple(new_me_outs[idx] if r.name == src.name else r
                            for r in program.results)
            return program.with_body(new_body).with_results(results)
        return None
