"""Generic parallelization rewrite (paper §3.6, Algorithms 1 → 2).

Three rules, applied to fixpoint:

* **Seed** — replace the use of a source collection ``r`` with
  ``s ← Split(n)(r); e ← ConcurrentExecute(identity)(s); m ← Merge(e)``
  (a logical no-op) and redirect r's consumers to ``m``.
* **AbsorbElementwise** — an instruction whose first input is a single-use
  ``Merge`` of a CE output moves *inside* the nested program; its other
  (loop-invariant) inputs are ``Broadcast`` into the CE.
* **AbsorbAggregation** — a decomposable aggregation is *copied* inside as a
  pre-aggregation; the outer instruction is replaced by the matching
  combiner (``rel.CombinePartials`` for scalar aggs, Merge+GroupByAggr with
  combine-fns for grouped aggs, ``cf.CombineChunks`` for segmented/LA aggs).

Instructions the rules don't understand are left as is (paper: "If an
unknown instruction had been encountered, then the rule would leave it as
is") — they simply stay outside the ConcurrentExecute.
"""

from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from .. import registry
from ..expr import AggSpec, col
from ..program import Instruction, Program, Register
from ..registry import infer_output_types
from ..types import BAG, SEQ, SET, CollectionType, is_coll
from .rewriter import ProgramRule

_FRESH = itertools.count()


def _fresh(taken: Set[str], hint: str) -> str:
    while True:
        name = f"{hint}{next(_FRESH)}"
        if name not in taken:
            taken.add(name)
            return name


def _all_names(p: Program) -> Set[str]:
    names = {r.name for r in p.inputs}
    for ins in p.body:
        names.update(r.name for r in ins.outputs)
    return names


class Parallelize(ProgramRule):
    """Parallelize a program over ``n`` workers.

    ``targets``: optional set of register names to seed; defaults to every
    program input / source-instruction output of an abstract collection type
    that has at least one absorbable consumer.
    """

    name = "parallelize"
    recurse = False

    def __init__(self, n: int, targets: Optional[Set[str]] = None) -> None:
        self.n = n
        self.targets = targets
        self._pending_broadcasts: List[Tuple[Register, Register]] = []

    # ------------------------------------------------------------------ run
    def run(self, program: Program) -> Optional[Program]:
        out = self._seed(program)
        if out is not None:
            return out
        out = self._absorb(program)
        if out is not None:
            return out
        return None

    # ----------------------------------------------------------------- seed
    def _seedable(self, program: Program) -> List[Tuple[int, Register]]:
        """(insert_position, register) pairs eligible for the seed rule."""
        consumers = program.consumers()
        producers = program.producers()
        positions = {id(ins): i for i, ins in enumerate(program.body)}
        found = []

        def absorbable_consumer(reg: Register) -> bool:
            for ins in consumers.get(reg.name, []):
                spec = registry.lookup(ins.opcode)
                if spec is None:
                    continue
                if (spec.elementwise or spec.aggregation) and ins.inputs and ins.inputs[0].name == reg.name:
                    return True
            return False

        def splittable(reg: Register) -> bool:
            t = reg.type
            if not is_coll(t):
                return False
            explicitly_targeted = self.targets is not None and reg.name in self.targets
            if (t.kind not in (BAG, SET, SEQ)
                    and t.kind.name not in ("Vec", "Tensor")
                    and not explicitly_targeted):
                return False
            if t.kind is SEQ and t.attr("n") is not None:
                return False  # already split
            # static sizes must divide
            for key in ("max_count",):
                v = t.attr(key)
                if v is not None and v % self.n != 0:
                    return False
            shape = t.attr("shape")
            if shape is not None and (not shape or shape[0] % self.n != 0):
                return False
            return True

        cands: List[Tuple[int, Register]] = []
        for r in program.inputs:
            cands.append((0, r))
        for i, ins in enumerate(program.body):
            spec = registry.lookup(ins.opcode)
            if spec is not None and spec.source:
                for r in ins.outputs:
                    cands.append((i + 1, r))

        for pos, r in cands:
            if self.targets is not None and r.name not in self.targets:
                continue
            if not splittable(r):
                continue
            if any(c.opcode == "cf.Split" for c in consumers.get(r.name, [])):
                continue  # already seeded
            if self.targets is None and not absorbable_consumer(r):
                continue
            found.append((pos, r))
        return found

    def _seed(self, program: Program) -> Optional[Program]:
        seeds = self._seedable(program)
        if not seeds:
            return None
        pos, r = seeds[0]
        taken = _all_names(program)

        from ..ops.controlflow import chunk_type, split_type, unchunk_type

        chunk = chunk_type(r.type, self.n)
        inner_in = Register("x0", chunk)
        identity = Program(name=f"par_{r.name}", inputs=(inner_in,), body=(), results=(inner_in,))

        s_reg = Register(_fresh(taken, "split"), split_type(chunk, self.n))
        e_reg = Register(_fresh(taken, "ce"), split_type(chunk, self.n))
        m_reg = Register(_fresh(taken, "merged"), r.type)

        split_ins = Instruction("cf.Split", (r,), (s_reg,), (("n", self.n),))
        ce_ins = Instruction("cf.ConcurrentExecute", (s_reg,), (e_reg,), (("P", identity),))
        merge_ins = Instruction("cf.Merge", (e_reg,), (m_reg,))

        body = list(program.body)
        new_body = body[:pos] + [split_ins, ce_ins, merge_ins] + body[pos:]

        # redirect consumers of r (except the new split) to m
        redirected = []
        for ins in new_body:
            if ins is split_ins:
                redirected.append(ins)
                continue
            if any(i.name == r.name for i in ins.inputs):
                ins = ins.with_inputs([m_reg if i.name == r.name else i for i in ins.inputs])
            redirected.append(ins)
        results = tuple(m_reg if x.name == r.name else x for x in program.results)
        return program.with_body(redirected).with_results(results)

    # --------------------------------------------------------------- absorb
    def _absorb(self, program: Program) -> Optional[Program]:
        producers = program.producers()
        positions: Dict[str, int] = {}
        for i, ins in enumerate(program.body):
            for r in ins.outputs:
                positions[r.name] = i

        def uses(reg: Register) -> int:
            return program.uses(reg)

        for yi, y in enumerate(program.body):
            spec = registry.lookup(y.opcode)
            if spec is None or not (spec.elementwise or spec.aggregation):
                continue
            if y.param("recombine"):
                # the combiner this rewrite itself emitted: absorbing it again
                # would ping-pong forever (pre-aggregate → recombine → ...)
                continue
            if not y.inputs:
                continue
            # first input must be a single-use Merge of a CE output
            a0 = y.inputs[0]
            merge0 = producers.get(a0.name)
            if merge0 is None or merge0.opcode != "cf.Merge" or uses(a0) != 1:
                continue
            if any(r.name == a0.name for r in program.results):
                continue
            e0 = merge0.inputs[0]
            ce = producers.get(e0.name)
            if ce is None or ce.opcode != "cf.ConcurrentExecute":
                continue
            ce_pos = positions[e0.name]

            # classify remaining inputs: merges of the SAME ce, or broadcasts
            merge_inputs: Dict[str, int] = {}  # y-input name -> ce result index
            bcast_inputs: List[Register] = []
            ok = True
            ce_out_names = [r.name for r in ce.outputs]
            merge_inputs[a0.name] = ce_out_names.index(e0.name)
            for a in y.inputs[1:]:
                prod = producers.get(a.name)
                if (
                    prod is not None
                    and prod.opcode == "cf.Merge"
                    and uses(a) == 1
                    and prod.inputs[0].name in ce_out_names
                    and not any(r.name == a.name for r in program.results)
                ):
                    merge_inputs[a.name] = ce_out_names.index(prod.inputs[0].name)
                elif positions.get(a.name, -1) < ce_pos:
                    bcast_inputs.append(a)  # defined before the CE (or an input)
                else:
                    ok = False
                    break
            if not ok:
                continue

            return self._do_absorb(program, y, ce, merge_inputs, bcast_inputs, spec)
        return None

    def _do_absorb(
        self,
        program: Program,
        y: Instruction,
        ce: Instruction,
        merge_inputs: Dict[str, int],
        bcast_inputs: List[Register],
        spec: registry.OpSpec,
    ) -> Program:
        from ..ops.controlflow import split_type

        taken = _all_names(program)
        inner: Program = ce.param("P")
        inner_taken = _all_names(inner)

        # --- extend the nested program ------------------------------------
        inner_inputs = list(inner.inputs)
        new_ce_inputs = list(ce.inputs)
        arg_regs: List[Register] = []
        for a in y.inputs:
            if a.name in merge_inputs:
                arg_regs.append(inner.results[merge_inputs[a.name]])
            else:
                ir = Register(_fresh(inner_taken, "b"), a.type)
                inner_inputs.append(ir)
                arg_regs.append(ir)
                # broadcast outer register into the CE
                br = Register(_fresh(taken, "bc"), split_type(a.type, self.n, bcast=True))
                new_ce_inputs.append(br)
                self._pending_broadcasts.append((a, br))

        inner_params = dict(y.params)
        inner_out_types = infer_output_types(y.opcode, inner_params, [r.type for r in arg_regs])
        inner_outs = tuple(Register(_fresh(inner_taken, "t"), t) for t in inner_out_types)
        inner_ins = Instruction(y.opcode, tuple(arg_regs), inner_outs, tuple(inner_params.items()))

        consumed = set(merge_inputs.values())
        kept_indices = [i for i in range(len(inner.results)) if i not in consumed]
        new_inner_results = tuple(inner.results[i] for i in kept_indices) + inner_outs
        new_inner = Program(
            name=inner.name,
            inputs=tuple(inner_inputs),
            body=inner.body + (inner_ins,),
            results=new_inner_results,
        )

        # --- rebuild the CE instruction ------------------------------------
        new_ce_outs = tuple(
            Register(_fresh(taken, "ce"), split_type(r.type, self.n))
            for r in new_inner.results
        )
        new_ce = Instruction(
            "cf.ConcurrentExecute",
            tuple(new_ce_inputs),
            new_ce_outs,
            (("P", new_inner),),
        )

        # map kept old ce outputs -> new ce outputs
        remap: Dict[str, Register] = {}
        for new_i, old_i in enumerate(kept_indices):
            remap[ce.outputs[old_i].name] = new_ce_outs[new_i]
        op_outs = new_ce_outs[len(kept_indices):]

        # --- outer replacement for y ---------------------------------------
        outer: List[Instruction] = []
        agg = spec.aggregation
        if agg is None:
            # elementwise: y becomes Merge(s) of the new outputs
            for yr, er in zip(y.outputs, op_outs):
                outer.append(Instruction("cf.Merge", (er,), (yr,)))
        elif agg["kind"] == "scalar":
            aggs: Tuple[AggSpec, ...] = tuple(y.param("aggs"))
            combine = tuple(AggSpec(a.combine_fn, col(a.name), a.name) for a in aggs)
            outer.append(
                Instruction("rel.CombinePartials", (op_outs[0],), (y.outputs[0],),
                            (("aggs", combine),))
            )
        elif agg["kind"] == "grouped":
            aggs = tuple(y.param("aggs"))
            keys = tuple(y.param("keys"))
            combine = tuple(AggSpec(a.combine_fn, col(a.name), a.name) for a in aggs)
            m = Register(_fresh(taken, "gm"), infer_output_types("cf.Merge", {}, [op_outs[0].type])[0])
            outer.append(Instruction("cf.Merge", (op_outs[0],), (m,)))
            recombine_params: Tuple[Tuple[str, Any], ...] = (
                ("keys", keys), ("aggs", combine), ("recombine", True))
            if y.param("max_groups"):
                recombine_params += (("max_groups", y.param("max_groups")),)
            outer.append(
                Instruction("rel.GroupByAggr", (m,), (y.outputs[0],),
                            recombine_params)
            )
        elif agg["kind"] == "segmented":
            for yr, er in zip(y.outputs, op_outs):
                outer.append(
                    Instruction("cf.CombineChunks", (er,), (yr,), (("op", "sum"),))
                )
        else:  # pragma: no cover - future kinds
            raise NotImplementedError(f"aggregation kind {agg['kind']}")

        # --- stitch the body -------------------------------------------------
        consumed_merge_names = set(merge_inputs.keys())
        new_body: List[Instruction] = []
        for ins in program.body:
            if ins is ce:
                for a, br in self._pending_broadcasts:
                    new_body.append(Instruction("cf.Broadcast", (a,), (br,), (("n", self.n),)))
                new_body.append(new_ce)
                continue
            if ins.opcode == "cf.Merge" and ins.outputs and ins.outputs[0].name in consumed_merge_names:
                continue  # absorbed merge disappears
            if ins is y:
                new_body.extend(outer)
                continue
            if any(r.name in remap for r in ins.inputs):
                ins = ins.with_inputs([remap.get(r.name, r) for r in ins.inputs])
            new_body.append(ins)
        self._pending_broadcasts = []
        results = tuple(remap.get(r.name, r) for r in program.results)
        return program.with_body(new_body).with_results(results)

    def apply(self, program: Program, max_iters: int = 200) -> Program:
        self._pending_broadcasts = []
        return super().apply(program, max_iters)
