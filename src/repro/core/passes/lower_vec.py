"""Lowering rewrite: abstract relational flavor → physical vec flavor.

This pass *changes the IR flavor* of a program (paper §3.1: "during the
rewriting, the program may change the IR flavor several times").  Because
physical types carry static capacities, the program is reconstructed
through a Builder so every register is re-typed by the typing rules.

Catalog decisions made here (the "physical optimizer"):
  * table scans get static capacities from the catalog;
  * GroupByAggr → SortByKey + GroupAggSorted(max_groups), or — under
    ``groupby="direct"``, when propagated catalog statistics bound the
    composite key domain — the sort-FREE ``vec.GroupAggDirect`` (dense
    bucket segment reduction, O(n)); the compilation driver exposes the
    two tiers as the ``groupby: sorted | direct`` strategy Choice and the
    cost model picks (NDV/domain decides, like gather-vs-exchange);
  * Join → SortByKey(build side) + MergeJoinSorted (sort-based PK-FK join —
    the TPU-native rewrite of BuildHTable/ProbeHTable, DESIGN.md §2), or —
    under ``join="hash"``, when the statistics bound the joint key domain —
    the sort-FREE ``vec.HashJoinDirect`` (dense direct-table probe, O(n));
    the driver exposes the tiers as the ``join: sorted | hash`` Choice;
    multi-column join keys get catalog-derived ``key_domains`` so the
    composite packing is collision-checked instead of 16-bit truncated;
  * higher-order instructions are reconstructed recursively with re-derived
    chunk types.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from ..program import Builder, Instruction, Program, Register
from ..types import ItemType

#: dense-bucket plans beyond this domain size are never emitted — the
#: bucket table itself would dominate (the cost model would reject them
#: anyway; this is the hard memory guard)
MAX_DIRECT_BUCKETS = 1 << 20

#: composite-key packing budget of the sorted merge join (mirrors
#: ``repro.relational.runtime._PACK_LIMIT`` without importing jax here):
#: under ``encode="dict"`` composites over this raw budget are packed as
#: dictionary *ranks* instead, lifting the 32-bit ceiling
PACK_LIMIT = 1 << 31


@dataclass
class Catalog:
    """Physical metadata for lowering.

    ``stats`` optionally carries a :class:`repro.compiler.stats.Statistics`
    catalog (cardinality / NDV / bytes-per-row estimates); the compilation
    driver's cost model reads it to choose between alternative physical
    lowerings, and it is part of the plan-cache key.
    """

    capacities: Dict[str, int] = field(default_factory=dict)
    default_max_groups: int = 1024
    join_selectivity: float = 1.0  # output-capacity factor for joins
    stats: Optional[Any] = None   # repro.compiler.stats.Statistics

    def capacity(self, table: str) -> int:
        if table not in self.capacities:
            raise KeyError(f"catalog has no capacity for table {table!r}")
        return self.capacities[table]


class LowerRelToVec:
    """Not a fixpoint rule: a single whole-program reconstruction.

    ``groupby`` selects the physical grouped-aggregation tier: ``"sorted"``
    (SortByKey + GroupAggSorted, always valid) or ``"direct"``
    (vec.GroupAggDirect dense buckets — used per instruction whenever the
    propagated statistics bound the key domain, falling back to sorted
    otherwise).

    ``join`` selects the physical join tier the same way: ``"sorted"``
    (SortByKey(build) + MergeJoinSorted, always valid) or ``"hash"``
    (vec.HashJoinDirect dense direct table — per instruction, when the
    statistics bound the joint key domain; unbounded-but-small domains get
    the dynamic-bounds variant with an in-trace fallback to sorted).

    ``encode`` extends both direct tiers to sparse and string keys:
    ``"raw"`` plans dense buckets only over raw catalog domain bounds
    (today's behavior), ``"dict"`` additionally re-encodes key columns to
    dense dictionary ranks ``[0, card)`` via ``vec.DictEncode`` whenever
    the raw domain is missing (string codes) or wider than the bucket
    budget, decoding only the surviving group/join key columns after the
    operator (decode-late).  A dictionary whose values are already
    contiguous needs no instructions at all — its bounds are used as the
    domain directly.  Under the sorted join tier, dictionary ranks also
    lift the 32-bit composite packing ceiling (``PACK_LIMIT``) by packing
    ranks instead of raw values.
    """

    name = "lower-rel-to-vec"

    def __init__(self, catalog: Catalog, groupby: str = "sorted",
                 join: str = "sorted", encode: str = "raw") -> None:
        if groupby not in ("sorted", "direct"):
            raise ValueError(f"unknown groupby tier {groupby!r}")
        if join not in ("sorted", "hash"):
            raise ValueError(f"unknown join tier {join!r}")
        if encode not in ("raw", "dict"):
            raise ValueError(f"unknown encode tier {encode!r}")
        self.catalog = catalog
        self.groupby = groupby
        self.join = join
        self.encode = encode
        self._env: Any = None  # StatsEnv over the SOURCE program tree

    def apply(self, program: Program, input_types: Optional[Sequence[ItemType]] = None) -> Program:
        if self.catalog.stats is not None:
            # propagate catalog statistics over the source tree once: the
            # per-register domain bounds are what make dense-bucket plans
            # (GroupAggDirect, packed join keys) derivable mid-program
            from ...compiler.stats import propagate
            self._env = propagate(program, self.catalog.stats)
        return self._lower(program, list(input_types or []) or None)

    # ------------------------------------------------------------------
    def _reg_domains(self, program: Program, reg: Register,
                     columns: Sequence[str]) -> Optional[Tuple[Tuple[int, int], ...]]:
        """Static (lo, hi) per column of a source-program register, if the
        propagated statistics bound every one of them."""
        if self._env is None:
            return None
        rs = self._env.get(program, reg)
        out = []
        for c in columns:
            d = rs.domain_of(c)
            if d is None:
                return None
            out.append((int(d[0]), int(d[1])))
        return tuple(out)

    # ------------------------------------------------------------------
    # dictionary-encoding planning
    # ------------------------------------------------------------------
    @staticmethod
    def _pick_size(pick) -> int:
        kind, val = pick
        return (int(val[1]) - int(val[0]) + 1) if kind == "raw" else val.card

    @staticmethod
    def _plan_from(cols, picks):
        """(specs, key_domains, num_buckets) from per-column picks.

        specs[i] is ``(col, Dictionary)`` when an encode instruction is
        needed, ``(col, None)`` when raw bounds (or a dense dictionary,
        whose ranks are just an offset) already give a dense domain."""
        specs, domains, nb = [], [], 1
        for c, (kind, val) in zip(cols, picks):
            if kind == "raw":
                domains.append((int(val[0]), int(val[1])))
                specs.append((c, None))
            elif val.dense:
                domains.append((int(val.lo), int(val.hi)))
                specs.append((c, None))
            else:
                domains.append((0, val.card - 1))
                specs.append((c, val))
            nb *= LowerRelToVec._pick_size((kind, val))
        return specs, tuple(domains), nb

    def _key_plan(self, cols, raws, dcs, budget, what="key"):
        """Choose per-column raw-bounds vs dictionary-rank domains.

        Raw bounds are preferred (no instructions); under ``encode="dict"``
        the smallest effective domain per column is tried when raw bounds
        are missing or the raw bucket product exceeds ``budget``.  Returns
        ``((specs, key_domains, num_buckets), None)`` on success, else
        ``(None, reason)`` — the reason states *why* encoding did not
        apply, so the downgrade is diagnosable from the warning alone.
        """
        nb_raw = None
        if all(c in raws for c in cols):
            picks = [("raw", raws[c]) for c in cols]
            nb_raw = 1
            for p in picks:
                nb_raw *= self._pick_size(p)
            if 0 < nb_raw <= budget:
                return self._plan_from(cols, picks), None
        if self.encode == "dict":
            picks, missing = [], None
            for c in cols:
                cands = []
                if c in raws:
                    cands.append(("raw", raws[c]))
                if c in dcs:
                    cands.append(("dict", dcs[c]))
                if not cands:
                    missing = c
                    break
                picks.append(min(cands, key=self._pick_size))
            if missing is None:
                nb = 1
                for p in picks:
                    nb *= self._pick_size(p)
                if 0 < nb <= budget:
                    return self._plan_from(cols, picks), None
                return None, (
                    f"{what} domain too large even as dictionary ranks "
                    f"({nb:,} buckets > {budget:,}) — dictionary over budget")
            return None, (f"unbounded {what} domain (no domain bounds or "
                          f"dictionary for {missing!r})")
        # encode == "raw": say whether "dict" would have helped
        if nb_raw is not None:
            hint = (" — dictionary available; strategy forced encode=raw"
                    if any(c in dcs for c in cols) else "")
            return None, (f"{what} domain too large ({nb_raw:,} buckets > "
                          f"{budget:,}){hint}")
        c = next(c for c in cols if c not in raws)
        if c in dcs:
            return None, (f"unbounded {what} domain (no raw bounds for "
                          f"{c!r}; dictionary available; strategy forced "
                          "encode=raw)")
        return None, (f"unbounded {what} domain (no domain bounds or "
                      f"dictionary for {c!r})")

    def _direct_key_plan(self, program: Program, reg: Register,
                         cols: Sequence[str], budget: int = MAX_DIRECT_BUCKETS,
                         what: str = "key"):
        if self._env is None:
            return None, f"unbounded {what} domain (no catalog statistics)"
        rs = self._env.get(program, reg)
        raws = {c: (int(d[0]), int(d[1]))
                for c in cols for d in (rs.domain_of(c),) if d is not None}
        dcs = {c: dc for c in cols
               for dc in (rs.dict_of(c),) if dc is not None and dc.card > 0}
        return self._key_plan(tuple(cols), raws, dcs, budget, what)

    def _join_key_plan(self, program: Program, ins: Instruction,
                       left_on: Sequence[str], right_on: Sequence[str],
                       budget: int):
        """Joint per-position plan over both join sides: raw bounds are the
        (min lo, max hi) envelope, dictionaries are the sorted union — the
        SAME static table on both sides, so equal values get equal ranks
        and probe keys missing from the build side simply find no match."""
        if self._env is None:
            return None, "unbounded join key domain (no catalog statistics)"
        ls = self._env.get(program, ins.inputs[0])
        rs = self._env.get(program, ins.inputs[1])
        labels = tuple(f"{lc}={rc}" for lc, rc in zip(left_on, right_on))
        raws, dcs = {}, {}
        for lab, lc, rc in zip(labels, left_on, right_on):
            ld, rd = ls.domain_of(lc), rs.domain_of(rc)
            if ld is not None and rd is not None:
                raws[lab] = (min(int(ld[0]), int(rd[0])),
                             max(int(ld[1]), int(rd[1])))
            dl, dr = ls.dict_of(lc), rs.dict_of(rc)
            if dl is not None and dr is not None:
                merged = dl.merge(dr)
                if merged.card > 0:
                    dcs[lab] = merged
        plan, reason = self._key_plan(labels, raws, dcs, budget,
                                      what="join key")
        if plan is None:
            return None, reason
        specs, domains, nb = plan
        enc_l = [(lc, d) for lc, (_, d) in zip(left_on, specs)]
        enc_r = [(rc, d) for rc, (_, d) in zip(right_on, specs)]
        return (enc_l, enc_r, domains, nb), None

    def _emit_encode(self, b: Builder, inp: Register, enc) -> Register:
        """vec.DictEncode for the (col, Dictionary) pairs that need one.

        Mode per column: a span-sized O(1) remap gather when the value
        range is small, log(card) searchsorted otherwise; the tables are
        static instruction params (they come from the catalog, not the
        data)."""
        import numpy as np
        cols, modes, tables, lows, cards = [], [], [], [], []
        for c, dc in [e for e in enc if e[1] is not None]:
            vals = np.asarray(dc.values)
            if vals.dtype.kind not in "iu":
                raise TypeError(
                    f"catalog dictionary for {c!r} holds non-integer values")
            fits32 = int(vals[0]) >= -(1 << 31) and int(vals[-1]) < (1 << 31)
            vals = vals.astype(np.int32 if fits32 else np.int64)
            span = int(dc.hi) - int(dc.lo) + 1
            if span <= MAX_DIRECT_BUCKETS:
                table = np.full(span, dc.card, np.int32)
                table[np.asarray(dc.values) - int(dc.lo)] = np.arange(
                    dc.card, dtype=np.int32)
                modes.append("remap")
                tables.append(table)
            else:
                modes.append("searchsorted")
                tables.append(vals)
            cols.append(c)
            lows.append(int(dc.lo))
            cards.append(dc.card)
        return b.emit1("vec.DictEncode", [inp], {
            "cols": tuple(cols), "modes": tuple(modes),
            "tables": tuple(tables), "lows": tuple(lows),
            "cards": tuple(cards)})

    @staticmethod
    def _emit_decode(b: Builder, out: Register, enc, src_schema) -> Register:
        """vec.DictDecode for surviving encoded key columns (decode-late:
        runs on the compacted operator output, never the full input)."""
        import numpy as np
        cols, tables, atoms = [], [], []
        for c, dc in [e for e in enc if e[1] is not None]:
            vals = np.asarray(dc.values)
            fits32 = int(vals[0]) >= -(1 << 31) and int(vals[-1]) < (1 << 31)
            cols.append(c)
            tables.append(vals.astype(np.int32 if fits32 else np.int64))
            atoms.append(src_schema.field(c))
        return b.emit1("vec.DictDecode", [out], {
            "cols": tuple(cols), "tables": tuple(tables),
            "atoms": tuple(atoms)})

    # ------------------------------------------------------------------
    def _remap_pred(self, e, schema):
        """Rewrite string-literal comparisons into global-code space.

        Physical string columns hold i32 global-dictionary rank codes, and
        rank order is lexicographic order, so every comparison maps to a
        code comparison: equality to the literal's exact rank (constant
        False/True when the literal is out of dictionary), ranges through
        the literal's insertion point.  Interp runs the un-lowered program
        and compares the raw strings directly — both paths agree.
        """
        from ...core.expr import _CMP, BinOp, Col, Const, UnOp
        from ...core.types import BOOL, I32

        stats = self.catalog.stats
        gd = getattr(stats, "global_dict", None) if stats is not None else None
        flip = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le",
                "eq": "eq", "ne": "ne"}

        def is_str_col(x):
            return (isinstance(x, Col)
                    and getattr(schema.field(x.name), "domain", None) == "str")

        def remap(cmp_op, colx, lit):
            if gd is None:
                raise ValueError(
                    f"string literal {lit!r} in a predicate over physical "
                    "i32 codes needs the global string dictionary — compile "
                    "with catalog statistics (Context builds them "
                    "automatically for string tables)")
            if cmp_op in ("eq", "ne"):
                r = gd.rank_of(lit)
                if r is None:
                    return Const(cmp_op == "ne", BOOL)
                return BinOp(cmp_op, colx, Const(int(r), I32))
            if cmp_op in ("lt", "le"):
                bound = gd.insertion(lit, "left" if cmp_op == "lt" else "right")
                return BinOp("lt", colx, Const(int(bound), I32))
            bound = gd.insertion(lit, "right" if cmp_op == "gt" else "left")
            return BinOp("ge", colx, Const(int(bound), I32))

        def walk(x):
            if isinstance(x, BinOp):
                if x.op in _CMP:
                    l, r = x.lhs, x.rhs
                    if (is_str_col(l) and isinstance(r, Const)
                            and isinstance(r.value, str)):
                        return remap(x.op, l, r.value)
                    if (isinstance(l, Const) and isinstance(l.value, str)
                            and is_str_col(r)):
                        return remap(flip[x.op], r, l.value)
                return BinOp(x.op, walk(x.lhs), walk(x.rhs))
            if isinstance(x, UnOp):
                return UnOp(x.op, walk(x.arg))
            return x

        return walk(e)

    # ------------------------------------------------------------------
    def _check_pkfk(self, program: Program, ins: Instruction,
                    right_on: Sequence[str]) -> None:
        """Surface the physical joins' silent PK-FK assumption.

        Every vec join tier (sorted merge and dense direct table alike)
        produces at most ONE match per probe row — correct only when the
        build side's keys are unique.  When the propagated NDV says the
        build side has duplicate keys, or there are no statistics to check
        against, emit a structured warning instead of silently dropping
        matches (mirrors ``lower_vec.direct_unavailable``).
        """
        from ...obs.trace import warn_event
        keys = ",".join(right_on)
        if self._env is None:
            warn_event("lower_vec.join_pkfk_unverified", keys=keys,
                       reason="no catalog statistics to verify build-side "
                              "key uniqueness")
            return
        rs = self._env.get(program, ins.inputs[1])
        distinct = 1.0
        for c in right_on:
            ndv = rs.ndv_of(c)
            if ndv is None:
                warn_event("lower_vec.join_pkfk_unverified", keys=keys,
                           reason=f"no NDV estimate for build key {c!r}")
                return
            distinct *= float(ndv)
        distinct = min(distinct, rs.rows)
        if distinct + 0.5 < rs.rows:
            warn_event(
                "lower_vec.join_pkfk_unverified", keys=keys,
                rows=int(rs.rows), distinct=int(distinct),
                reason=f"build side has ~{rs.rows:,.0f} rows but only "
                       f"~{distinct:,.0f} distinct keys — duplicate matches "
                       "will be dropped (PK-FK join keeps one per probe row)",
            )

    # ------------------------------------------------------------------
    def _lower(self, program: Program, new_input_types: Optional[List[ItemType]]) -> Program:
        b = Builder(program.name, prefix="v")
        regmap: Dict[str, Register] = {}
        for i, r in enumerate(program.inputs):
            t = new_input_types[i] if new_input_types else r.type
            regmap[r.name] = b.input(r.name, t)

        for ins in program.body:
            new_ins = [regmap[r.name] for r in ins.inputs]
            outs = self._lower_instruction(b, ins, new_ins, program)
            if len(outs) != len(ins.outputs):
                raise AssertionError(f"lowering {ins.opcode}: arity changed")
            for old, new in zip(ins.outputs, outs):
                regmap[old.name] = new

        return b.finish(*[regmap[r.name] for r in program.results])

    # ------------------------------------------------------------------
    def _lower_instruction(self, b: Builder, ins: Instruction,
                           inputs: List[Register], src_program: Program,
                           ) -> Sequence[Register]:
        params = dict(ins.params)
        op = ins.opcode

        if op == "rel.Scan":
            return b.emit("vec.ScanVec", [], {
                "table": params["table"],
                "schema": params["schema"],
                "max_count": self.catalog.capacity(params["table"]),
            })
        if op == "rel.Select":
            pred = self._remap_pred(params["pred"],
                                    ins.inputs[0].type.schema)
            return b.emit("vec.MaskSelect", inputs, {"pred": pred})
        if op == "rel.Proj":
            return b.emit("vec.ProjVec", inputs, {"names": tuple(params["names"])})
        if op == "rel.ExProj":
            schema = ins.inputs[0].type.schema
            exprs = tuple((n, self._remap_pred(e, schema))
                          for n, e in params["exprs"])
            if inputs[0].type.kind.name == "Single":
                return b.emit("vec.FinalizeSingle", inputs, {"exprs": exprs})
            return b.emit("vec.ExProjVec", inputs, {"exprs": exprs})
        if op == "rel.Aggr":
            return b.emit("vec.AggrVec", inputs, {"aggs": tuple(params["aggs"])})
        if op == "rel.GroupByAggr":
            keys = tuple(params["keys"])
            mg = int(params.get("max_groups") or self.catalog.default_max_groups)
            aggs = tuple(params["aggs"])
            if self.groupby == "direct":
                plan, reason = self._direct_key_plan(
                    src_program, ins.inputs[0], keys)
                if plan is not None:
                    specs, domains, n_buckets = plan
                    enc = [e for e in specs if e[1] is not None]
                    inp = inputs[0]
                    if enc:
                        inp = self._emit_encode(b, inp, enc)
                    out = b.emit1("vec.GroupAggDirect", [inp], {
                        "keys": keys, "aggs": aggs, "max_groups": mg,
                        "key_domains": domains, "num_buckets": n_buckets,
                    })
                    if enc:
                        out = self._emit_decode(
                            b, out, enc, ins.inputs[0].type.schema)
                    return [out]
                # unbounded / oversized key domain: the sorted tier is the
                # always-valid fallback — but the caller asked for direct, so
                # the downgrade is surfaced (with why encoding did not apply)
                # instead of happening silently
                from ...obs.trace import warn_event
                warn_event(
                    "lower_vec.direct_unavailable",
                    keys=",".join(keys),
                    max_buckets=MAX_DIRECT_BUCKETS,
                    encode=self.encode,
                    reason=reason,
                )
            s = b.emit1("vec.SortByKey", inputs, {"keys": keys})
            return b.emit("vec.GroupAggSorted", [s], {
                "keys": keys, "aggs": aggs, "max_groups": mg,
            })
        if op == "rel.Join":
            left, right = inputs
            left_on = tuple(params["left_on"])
            right_on = tuple(params["right_on"])
            left_cap = left.type.attr("max_count")
            right_cap = right.type.attr("max_count")
            out_cap = int(left_cap * self.catalog.join_selectivity)
            self._check_pkfk(src_program, ins, right_on)
            join_params: Dict[str, Any] = {
                "left_on": left_on, "right_on": right_on, "max_count": out_cap,
            }
            # joint per-column bounds over both sides (packing must agree)
            ld = self._reg_domains(src_program, ins.inputs[0], left_on)
            rd = self._reg_domains(src_program, ins.inputs[1], right_on)
            joint = None
            if ld is not None and rd is not None:
                joint = tuple((min(a[0], c[0]), max(a[1], c[1]))
                              for a, c in zip(ld, rd))
            if self.join == "hash":
                jplan, jreason = self._join_key_plan(
                    src_program, ins, left_on, right_on, MAX_DIRECT_BUCKETS)
                if jplan is not None:
                    enc_l, enc_r, domains, n_buckets = jplan
                    need_l = [e for e in enc_l if e[1] is not None]
                    need_r = [e for e in enc_r if e[1] is not None]
                    probe = (self._emit_encode(b, left, need_l)
                             if need_l else left)
                    build = (self._emit_encode(b, right, need_r)
                             if need_r else right)
                    out = b.emit1("vec.HashJoinDirect", [probe, build], {
                        **join_params, "key_domains": domains,
                    })
                    if need_l:
                        # only the probe-side key columns survive the join
                        # schema — decode them back (decode-late)
                        out = self._emit_decode(
                            b, out, need_l, ins.inputs[0].type.schema)
                    return [out]
                if joint is None:
                    # unbounded raw domain and no static dictionary plan:
                    # dynamic-bounds variant — the bucket budget is static,
                    # the fit check and the fallback to the sorted merge
                    # happen inside the trace per instruction
                    budget = min(MAX_DIRECT_BUCKETS, max(4 * int(right_cap), 1024))
                    return b.emit("vec.HashJoinDirect", [left, right], {
                        **join_params, "num_buckets": budget,
                    })
                # bounded but oversized (even as dictionary ranks, or with
                # encoding forced off): surface the downgrade to sorted with
                # the reason (mirrors lower_vec.direct_unavailable)
                from ...obs.trace import warn_event
                warn_event(
                    "lower_vec.hash_unavailable",
                    keys=",".join(left_on),
                    max_buckets=MAX_DIRECT_BUCKETS,
                    encode=self.encode,
                    reason=jreason,
                )
            if len(left_on) > 1:
                raw_fits = joint is not None
                if raw_fits:
                    nb = 1
                    for lo, hi in joint:
                        nb *= hi - lo + 1
                    raw_fits = 0 < nb <= PACK_LIMIT
                if raw_fits:
                    # catalog bounds let the composite key pack without
                    # 16-bit truncation (joint bounds over both sides)
                    join_params["key_domains"] = joint
                elif self.encode == "dict":
                    # raw product over the 32-bit packing ceiling (or
                    # unbounded): pack dictionary *ranks* instead — the rank
                    # product is the card product, which may fit where raw
                    # spans cannot
                    jplan, _ = self._join_key_plan(
                        src_program, ins, left_on, right_on, PACK_LIMIT)
                    if jplan is not None:
                        enc_l, enc_r, domains, _nb = jplan
                        need_l = [e for e in enc_l if e[1] is not None]
                        need_r = [e for e in enc_r if e[1] is not None]
                        if need_l:
                            left = self._emit_encode(b, left, need_l)
                        if need_r:
                            right = self._emit_encode(b, right, need_r)
                        join_params["key_domains"] = domains
                        rs = b.emit1("vec.SortByKey", [right],
                                     {"keys": right_on})
                        out = b.emit1("vec.MergeJoinSorted", [left, rs],
                                      join_params)
                        if need_l:
                            out = self._emit_decode(
                                b, out, need_l, ins.inputs[0].type.schema)
                        return [out]
            rs = b.emit1("vec.SortByKey", [right], {"keys": right_on})
            return b.emit("vec.MergeJoinSorted", [left, rs], join_params)
        if op == "rel.OrderBy":
            keys = tuple(params["keys"])
            asc = tuple(params.get("ascending") or (True,) * len(keys))
            return b.emit("vec.SortByKey", inputs, {"keys": keys, "ascending": asc})
        if op == "rel.Limit":
            return b.emit("vec.LimitVec", inputs, {"k": int(params["k"])})
        if op == "rel.CombinePartials":
            return b.emit(op, inputs, params)

        # higher-order instructions: reconstruct nested programs with the
        # chunk types of the (already lowered) new inputs
        if op in ("cf.ConcurrentExecute", "mesh.MeshExecute"):
            p: Program = params["P"]
            chunk_types = [r.type.item for r in inputs]
            params["P"] = self._lower(p, chunk_types)
            return b.emit(op, inputs, params)
        if op in ("cf.Loop", "cf.While"):
            p = params["P"]
            params["P"] = self._lower(p, [r.type for r in inputs])
            return b.emit(op, inputs, params)
        if op == "cf.Cond":
            then_types = [r.type for r in inputs[1:]]
            params["Pthen"] = self._lower(params["Pthen"], then_types)
            params["Pelse"] = self._lower(params["Pelse"], then_types)
            return b.emit(op, inputs, params)
        if op == "cf.Call":
            params["P"] = self._lower(params["P"], [r.type for r in inputs])
            return b.emit(op, inputs, params)
        if op == "df.Map":
            p = params["P"]
            params["P"] = self._lower(p, [inputs[0].type.item])
            return b.emit(op, inputs, params)

        # default: re-emit unchanged (cf.Split/Merge/Broadcast/CombineChunks,
        # la.*, unknown flavors) — typing rules recompute the physical types
        from .. import registry
        if registry.lookup(op) is None:
            return b.emit(op, inputs, params, out_types=[o.type for o in ins.outputs])
        return b.emit(op, inputs, params)
