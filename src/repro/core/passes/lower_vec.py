"""Lowering rewrite: abstract relational flavor → physical vec flavor.

This pass *changes the IR flavor* of a program (paper §3.1: "during the
rewriting, the program may change the IR flavor several times").  Because
physical types carry static capacities, the program is reconstructed
through a Builder so every register is re-typed by the typing rules.

Catalog decisions made here (the "physical optimizer"):
  * table scans get static capacities from the catalog;
  * GroupByAggr → SortByKey + GroupAggSorted(max_groups), or — under
    ``groupby="direct"``, when propagated catalog statistics bound the
    composite key domain — the sort-FREE ``vec.GroupAggDirect`` (dense
    bucket segment reduction, O(n)); the compilation driver exposes the
    two tiers as the ``groupby: sorted | direct`` strategy Choice and the
    cost model picks (NDV/domain decides, like gather-vs-exchange);
  * Join → SortByKey(build side) + MergeJoinSorted (sort-based PK-FK join —
    the TPU-native rewrite of BuildHTable/ProbeHTable, DESIGN.md §2), or —
    under ``join="hash"``, when the statistics bound the joint key domain —
    the sort-FREE ``vec.HashJoinDirect`` (dense direct-table probe, O(n));
    the driver exposes the tiers as the ``join: sorted | hash`` Choice;
    multi-column join keys get catalog-derived ``key_domains`` so the
    composite packing is collision-checked instead of 16-bit truncated;
  * higher-order instructions are reconstructed recursively with re-derived
    chunk types.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from ..program import Builder, Instruction, Program, Register
from ..types import ItemType

#: dense-bucket plans beyond this domain size are never emitted — the
#: bucket table itself would dominate (the cost model would reject them
#: anyway; this is the hard memory guard)
MAX_DIRECT_BUCKETS = 1 << 20


@dataclass
class Catalog:
    """Physical metadata for lowering.

    ``stats`` optionally carries a :class:`repro.compiler.stats.Statistics`
    catalog (cardinality / NDV / bytes-per-row estimates); the compilation
    driver's cost model reads it to choose between alternative physical
    lowerings, and it is part of the plan-cache key.
    """

    capacities: Dict[str, int] = field(default_factory=dict)
    default_max_groups: int = 1024
    join_selectivity: float = 1.0  # output-capacity factor for joins
    stats: Optional[Any] = None   # repro.compiler.stats.Statistics

    def capacity(self, table: str) -> int:
        if table not in self.capacities:
            raise KeyError(f"catalog has no capacity for table {table!r}")
        return self.capacities[table]


class LowerRelToVec:
    """Not a fixpoint rule: a single whole-program reconstruction.

    ``groupby`` selects the physical grouped-aggregation tier: ``"sorted"``
    (SortByKey + GroupAggSorted, always valid) or ``"direct"``
    (vec.GroupAggDirect dense buckets — used per instruction whenever the
    propagated statistics bound the key domain, falling back to sorted
    otherwise).

    ``join`` selects the physical join tier the same way: ``"sorted"``
    (SortByKey(build) + MergeJoinSorted, always valid) or ``"hash"``
    (vec.HashJoinDirect dense direct table — per instruction, when the
    statistics bound the joint key domain; unbounded-but-small domains get
    the dynamic-bounds variant with an in-trace fallback to sorted).
    """

    name = "lower-rel-to-vec"

    def __init__(self, catalog: Catalog, groupby: str = "sorted",
                 join: str = "sorted") -> None:
        if groupby not in ("sorted", "direct"):
            raise ValueError(f"unknown groupby tier {groupby!r}")
        if join not in ("sorted", "hash"):
            raise ValueError(f"unknown join tier {join!r}")
        self.catalog = catalog
        self.groupby = groupby
        self.join = join
        self._env: Any = None  # StatsEnv over the SOURCE program tree

    def apply(self, program: Program, input_types: Optional[Sequence[ItemType]] = None) -> Program:
        if self.catalog.stats is not None:
            # propagate catalog statistics over the source tree once: the
            # per-register domain bounds are what make dense-bucket plans
            # (GroupAggDirect, packed join keys) derivable mid-program
            from ...compiler.stats import propagate
            self._env = propagate(program, self.catalog.stats)
        return self._lower(program, list(input_types or []) or None)

    # ------------------------------------------------------------------
    def _reg_domains(self, program: Program, reg: Register,
                     columns: Sequence[str]) -> Optional[Tuple[Tuple[int, int], ...]]:
        """Static (lo, hi) per column of a source-program register, if the
        propagated statistics bound every one of them."""
        if self._env is None:
            return None
        rs = self._env.get(program, reg)
        out = []
        for c in columns:
            d = rs.domain_of(c)
            if d is None:
                return None
            out.append((int(d[0]), int(d[1])))
        return tuple(out)

    # ------------------------------------------------------------------
    def _check_pkfk(self, program: Program, ins: Instruction,
                    right_on: Sequence[str]) -> None:
        """Surface the physical joins' silent PK-FK assumption.

        Every vec join tier (sorted merge and dense direct table alike)
        produces at most ONE match per probe row — correct only when the
        build side's keys are unique.  When the propagated NDV says the
        build side has duplicate keys, or there are no statistics to check
        against, emit a structured warning instead of silently dropping
        matches (mirrors ``lower_vec.direct_unavailable``).
        """
        from ...obs.trace import warn_event
        keys = ",".join(right_on)
        if self._env is None:
            warn_event("lower_vec.join_pkfk_unverified", keys=keys,
                       reason="no catalog statistics to verify build-side "
                              "key uniqueness")
            return
        rs = self._env.get(program, ins.inputs[1])
        distinct = 1.0
        for c in right_on:
            ndv = rs.ndv_of(c)
            if ndv is None:
                warn_event("lower_vec.join_pkfk_unverified", keys=keys,
                           reason=f"no NDV estimate for build key {c!r}")
                return
            distinct *= float(ndv)
        distinct = min(distinct, rs.rows)
        if distinct + 0.5 < rs.rows:
            warn_event(
                "lower_vec.join_pkfk_unverified", keys=keys,
                rows=int(rs.rows), distinct=int(distinct),
                reason=f"build side has ~{rs.rows:,.0f} rows but only "
                       f"~{distinct:,.0f} distinct keys — duplicate matches "
                       "will be dropped (PK-FK join keeps one per probe row)",
            )

    # ------------------------------------------------------------------
    def _lower(self, program: Program, new_input_types: Optional[List[ItemType]]) -> Program:
        b = Builder(program.name, prefix="v")
        regmap: Dict[str, Register] = {}
        for i, r in enumerate(program.inputs):
            t = new_input_types[i] if new_input_types else r.type
            regmap[r.name] = b.input(r.name, t)

        for ins in program.body:
            new_ins = [regmap[r.name] for r in ins.inputs]
            outs = self._lower_instruction(b, ins, new_ins, program)
            if len(outs) != len(ins.outputs):
                raise AssertionError(f"lowering {ins.opcode}: arity changed")
            for old, new in zip(ins.outputs, outs):
                regmap[old.name] = new

        return b.finish(*[regmap[r.name] for r in program.results])

    # ------------------------------------------------------------------
    def _lower_instruction(self, b: Builder, ins: Instruction,
                           inputs: List[Register], src_program: Program,
                           ) -> Sequence[Register]:
        params = dict(ins.params)
        op = ins.opcode

        if op == "rel.Scan":
            return b.emit("vec.ScanVec", [], {
                "table": params["table"],
                "schema": params["schema"],
                "max_count": self.catalog.capacity(params["table"]),
            })
        if op == "rel.Select":
            return b.emit("vec.MaskSelect", inputs, {"pred": params["pred"]})
        if op == "rel.Proj":
            return b.emit("vec.ProjVec", inputs, {"names": tuple(params["names"])})
        if op == "rel.ExProj":
            if inputs[0].type.kind.name == "Single":
                return b.emit("vec.FinalizeSingle", inputs, {"exprs": tuple(params["exprs"])})
            return b.emit("vec.ExProjVec", inputs, {"exprs": tuple(params["exprs"])})
        if op == "rel.Aggr":
            return b.emit("vec.AggrVec", inputs, {"aggs": tuple(params["aggs"])})
        if op == "rel.GroupByAggr":
            keys = tuple(params["keys"])
            mg = int(params.get("max_groups") or self.catalog.default_max_groups)
            aggs = tuple(params["aggs"])
            if self.groupby == "direct":
                domains = self._reg_domains(src_program, ins.inputs[0], keys)
                n_buckets = None
                if domains is not None:
                    n_buckets = 1
                    for lo, hi in domains:
                        n_buckets *= hi - lo + 1
                    if 0 < n_buckets <= MAX_DIRECT_BUCKETS:
                        return b.emit("vec.GroupAggDirect", inputs, {
                            "keys": keys, "aggs": aggs, "max_groups": mg,
                            "key_domains": domains, "num_buckets": n_buckets,
                        })
                # unbounded / oversized key domain: the sorted tier is the
                # always-valid fallback — but the caller asked for direct, so
                # the downgrade is surfaced instead of happening silently
                from ...obs.trace import warn_event
                warn_event(
                    "lower_vec.direct_unavailable",
                    keys=",".join(keys),
                    num_buckets=n_buckets if n_buckets is not None else -1,
                    max_buckets=MAX_DIRECT_BUCKETS,
                    reason=("unbounded key domain" if domains is None
                            else f"key domain too large ({n_buckets:,} buckets"
                                 f" > {MAX_DIRECT_BUCKETS:,})"),
                )
            s = b.emit1("vec.SortByKey", inputs, {"keys": keys})
            return b.emit("vec.GroupAggSorted", [s], {
                "keys": keys, "aggs": aggs, "max_groups": mg,
            })
        if op == "rel.Join":
            left, right = inputs
            left_on = tuple(params["left_on"])
            right_on = tuple(params["right_on"])
            left_cap = left.type.attr("max_count")
            right_cap = right.type.attr("max_count")
            out_cap = int(left_cap * self.catalog.join_selectivity)
            self._check_pkfk(src_program, ins, right_on)
            join_params: Dict[str, Any] = {
                "left_on": left_on, "right_on": right_on, "max_count": out_cap,
            }
            # joint per-column bounds over both sides (packing must agree)
            ld = self._reg_domains(src_program, ins.inputs[0], left_on)
            rd = self._reg_domains(src_program, ins.inputs[1], right_on)
            joint = None
            if ld is not None and rd is not None:
                joint = tuple((min(a[0], c[0]), max(a[1], c[1]))
                              for a, c in zip(ld, rd))
            if self.join == "hash":
                if joint is not None:
                    n_buckets = 1
                    for lo, hi in joint:
                        n_buckets *= hi - lo + 1
                    if 0 < n_buckets <= MAX_DIRECT_BUCKETS:
                        return b.emit("vec.HashJoinDirect", [left, right], {
                            **join_params, "key_domains": joint,
                        })
                    # bounded but oversized: the direct table would dominate —
                    # surface the downgrade to sorted (mirrors
                    # lower_vec.direct_unavailable for group-by)
                    from ...obs.trace import warn_event
                    warn_event(
                        "lower_vec.hash_unavailable",
                        keys=",".join(left_on),
                        num_buckets=n_buckets,
                        max_buckets=MAX_DIRECT_BUCKETS,
                        reason=f"join key domain too large ({n_buckets:,} "
                               f"buckets > {MAX_DIRECT_BUCKETS:,})",
                    )
                else:
                    # unbounded domain: dynamic-bounds variant — the bucket
                    # budget is static, the fit check and the fallback to the
                    # sorted merge happen inside the trace per instruction
                    budget = min(MAX_DIRECT_BUCKETS, max(4 * int(right_cap), 1024))
                    return b.emit("vec.HashJoinDirect", [left, right], {
                        **join_params, "num_buckets": budget,
                    })
            if len(left_on) > 1 and joint is not None:
                # catalog bounds let the composite key pack without 16-bit
                # truncation (joint bounds over both sides)
                join_params["key_domains"] = joint
            rs = b.emit1("vec.SortByKey", [right], {"keys": right_on})
            return b.emit("vec.MergeJoinSorted", [left, rs], join_params)
        if op == "rel.OrderBy":
            keys = tuple(params["keys"])
            asc = tuple(params.get("ascending") or (True,) * len(keys))
            return b.emit("vec.SortByKey", inputs, {"keys": keys, "ascending": asc})
        if op == "rel.Limit":
            return b.emit("vec.LimitVec", inputs, {"k": int(params["k"])})
        if op == "rel.CombinePartials":
            return b.emit(op, inputs, params)

        # higher-order instructions: reconstruct nested programs with the
        # chunk types of the (already lowered) new inputs
        if op in ("cf.ConcurrentExecute", "mesh.MeshExecute"):
            p: Program = params["P"]
            chunk_types = [r.type.item for r in inputs]
            params["P"] = self._lower(p, chunk_types)
            return b.emit(op, inputs, params)
        if op in ("cf.Loop", "cf.While"):
            p = params["P"]
            params["P"] = self._lower(p, [r.type for r in inputs])
            return b.emit(op, inputs, params)
        if op == "cf.Cond":
            then_types = [r.type for r in inputs[1:]]
            params["Pthen"] = self._lower(params["Pthen"], then_types)
            params["Pelse"] = self._lower(params["Pelse"], then_types)
            return b.emit(op, inputs, params)
        if op == "cf.Call":
            params["P"] = self._lower(params["P"], [r.type for r in inputs])
            return b.emit(op, inputs, params)
        if op == "df.Map":
            p = params["P"]
            params["P"] = self._lower(p, [inputs[0].type.item])
            return b.emit(op, inputs, params)

        # default: re-emit unchanged (cf.Split/Merge/Broadcast/CombineChunks,
        # la.*, unknown flavors) — typing rules recompute the physical types
        from .. import registry
        if registry.lookup(op) is None:
            return b.emit(op, inputs, params, out_types=[o.type for o in ins.outputs])
        return b.emit(op, inputs, params)
