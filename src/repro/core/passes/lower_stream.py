"""Streaming lowering: split a lowered plan into static / per-batch / merge
/ finalize segments for micro-batched incremental execution.

The streaming target compiles a relational program exactly like the local
target (same canonicalize + groupby/join/encode/fuse Choice machinery, but
with the stream table's capacity rebound to the micro-batch capacity), then
this module splits the final vec-flavor program at its terminal
aggregation:

* **static segment** — every instruction whose value does NOT depend on the
  stream scan (dimension-table scans, their selects/projections, the
  build-side ``SortByKey`` of a sorted join, build-side ``DictEncode``).
  It runs ONCE per consumer session; its results — including the
  ``HashJoinDirect``/``MergeJoinSorted`` build tables — are carried across
  micro-batches instead of being recomputed per batch.
* **batch segment** — the stream-dependent pipeline up to and including the
  terminal aggregation.  Run per micro-batch, it produces a *partial*
  aggregate (every AggSpec is self-decomposable), reusing the ordinary
  physical operators — ``GroupAggDirect`` dense buckets included.
* **merge program** — one ``vec.MergeGroupedState``/``vec.MergeScalarState``
  instruction folding the batch partial into the running state: the
  checkpointable accumulator of the stream.
* **finalize segment** — everything after the aggregation (decode-late
  ``DictDecode``, ``FinalizeSingle`` avg arithmetic, order-by/limit),
  re-run on demand over the current state to answer the query.

Exactly-once recovery builds on this split: the state is a pure fold over
the micro-batch sequence, so ``state_after(seq)`` is deterministic and a
restored snapshot plus a replay of the uncommitted suffix reproduces the
batch oracle bit-for-bit (see docs/streaming.md).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from ..program import Builder, Instruction, Program, Register
from ..verify import verify

__all__ = ["StreamPlan", "lower_stream", "GROUPED_AGG_OPS", "SCALAR_AGG_OPS"]


#: terminal aggregation opcodes whose output is a bounded grouped state
GROUPED_AGG_OPS = ("vec.GroupAggSorted", "vec.GroupAggDirect",
                   "vec.FusedJoinGroupAgg")
#: terminal aggregation opcodes whose output is a Single scalar state
SCALAR_AGG_OPS = ("vec.AggrVec", "vec.FusedSelectAgg")


@dataclass(frozen=True)
class StreamPlan:
    """The four-way split of one lowered program (see module docstring)."""

    source: Program                       # the full lowered program
    stream_table: str
    state_kind: str                       # "grouped" | "scalar"
    agg: Instruction                      # the terminal aggregation
    static_program: Optional[Program]     # () → boundary values; run once
    #: static results consumed by the batch segment (program inputs, in
    #: ``static_program.results`` order)
    batch_boundary: Tuple[Register, ...]
    batch_program: Program                # per micro-batch → partial state
    merge_program: Program                # (state, delta) → state
    #: static results consumed by the finalize segment
    finalize_boundary: Tuple[Register, ...]
    finalize_program: Optional[Program]   # (state, *boundary) → query results

    def render(self) -> str:
        parts = [f"stream plan over table {self.stream_table!r} "
                 f"({self.state_kind} state via {self.agg.opcode})"]
        if self.static_program is not None:
            parts.append(self.static_program.render())
        parts.append(self.batch_program.render())
        parts.append(self.merge_program.render())
        if self.finalize_program is not None:
            parts.append(self.finalize_program.render())
        return "\n".join(parts)


def _stream_scans(program: Program, stream_table: str) -> List[Instruction]:
    return [ins for ins in program.body
            if ins.opcode == "vec.ScanVec"
            and ins.param("table") == stream_table]


def _merge_params(agg: Instruction) -> Dict[str, object]:
    """Parameters of the merge op, lifted off the terminal aggregation."""
    if agg.opcode in SCALAR_AGG_OPS:
        return {"aggs": tuple(agg.param("aggs"))}
    params: Dict[str, object] = {
        "keys": tuple(agg.param("keys")),
        "aggs": tuple(agg.param("aggs")),
        "max_groups": int(agg.param("max_groups")),
    }
    # the direct tiers carry their dense-bucket geometry into the merge so
    # the carried accumulator stays sort-free
    if agg.opcode in ("vec.GroupAggDirect", "vec.FusedJoinGroupAgg"):
        params["key_domains"] = tuple(agg.param("key_domains"))
        params["num_buckets"] = int(agg.param("num_buckets"))
    return params


def lower_stream(program: Program, stream_table: str) -> StreamPlan:
    """Split a lowered vec-flavor program for incremental execution.

    Raises ``ValueError`` with a named reason when the program shape is not
    streamable: no stream scan, no terminal aggregation over the stream, a
    second stream-dependent aggregation, or a post-aggregation instruction
    that consumes raw (pre-aggregation) stream rows.
    """
    scans = _stream_scans(program, stream_table)
    if not scans:
        known = sorted({ins.param("table") for ins in program.body
                        if ins.opcode == "vec.ScanVec"})
        raise ValueError(
            f"stream table {stream_table!r} is not scanned by "
            f"{program.name!r}; scanned tables: {known}")

    # -- dependence: which registers transitively read the stream scan ------
    stream_dep: Set[str] = set()
    for ins in program.body:
        if ins in scans or any(r.name in stream_dep for r in ins.inputs):
            stream_dep.update(r.name for r in ins.outputs)

    # -- the terminal aggregation ------------------------------------------
    agg_ops = GROUPED_AGG_OPS + SCALAR_AGG_OPS
    aggs = [ins for ins in program.body
            if ins.opcode in agg_ops
            and any(r.name in stream_dep for r in list(ins.inputs)
                    + list(ins.outputs))]
    if not aggs:
        raise ValueError(
            f"{program.name!r} has no aggregation over stream table "
            f"{stream_table!r}; unbounded state cannot stream "
            f"(add a group_by/agg, or run a batch target)")
    if len(aggs) > 1:
        raise ValueError(
            f"{program.name!r} has {len(aggs)} aggregations over the "
            f"stream; streaming supports exactly one terminal aggregation "
            f"({[i.opcode for i in aggs]})")
    agg = aggs[0]
    agg_idx = program.body.index(agg)
    agg_out = agg.outputs[0]
    state_kind = "scalar" if agg.opcode in SCALAR_AGG_OPS else "grouped"

    # -- partition the body -------------------------------------------------
    batch_body: List[Instruction] = []
    static_body: List[Instruction] = []
    suffix_body: List[Instruction] = []
    suffix_defined: Set[str] = {agg_out.name}
    for idx, ins in enumerate(program.body):
        dep = any(r.name in stream_dep for r in ins.outputs)
        if not dep:
            static_body.append(ins)
        elif idx <= agg_idx:
            batch_body.append(ins)
        else:
            for r in ins.inputs:
                if r.name in stream_dep and r.name not in suffix_defined:
                    raise ValueError(
                        f"{program.name!r}: {ins.opcode} after the "
                        f"aggregation consumes pre-aggregation stream "
                        f"register %{r.name}; only the aggregated state "
                        f"may flow past the aggregation")
            suffix_defined.update(r.name for r in ins.outputs)
            suffix_body.append(ins)

    for r in program.results:
        if r.name in stream_dep and r.name not in suffix_defined:
            raise ValueError(
                f"{program.name!r}: result %{r.name} is raw stream data; a "
                f"streaming program must return aggregated state")

    # -- boundary registers: static values the other segments consume ------
    static_defs = {r.name: r for ins in static_body for r in ins.outputs}

    def boundary(body: List[Instruction],
                 extra: Tuple[Register, ...] = ()) -> List[Register]:
        seen: Dict[str, Register] = {}
        for ins in body:
            for r in ins.inputs:
                if r.name in static_defs and r.name not in seen:
                    seen[r.name] = r
        for r in extra:
            if r.name in static_defs and r.name not in seen:
                seen[r.name] = r
        return list(seen.values())

    batch_boundary = boundary(batch_body)
    finalize_boundary = boundary(suffix_body, program.results)
    needed = list(batch_boundary)
    needed += [r for r in finalize_boundary
               if r.name not in {b.name for b in batch_boundary}]

    static_program: Optional[Program] = None
    if needed:
        # backward closure: only static instructions feeding a boundary reg
        live = {r.name for r in needed}
        keep: List[Instruction] = []
        for ins in reversed(static_body):
            if any(r.name in live for r in ins.outputs):
                keep.append(ins)
                live.update(r.name for r in ins.inputs)
        keep.reverse()
        static_program = Program(
            name=f"{program.name}__static",
            inputs=(), body=tuple(keep), results=tuple(needed))

    batch_program = Program(
        name=f"{program.name}__batch",
        inputs=tuple(batch_boundary),
        body=tuple(batch_body),
        results=(agg_out,))

    # -- merge: one instruction, built through the typed Builder ------------
    b = Builder(f"{program.name}__merge", prefix="m")
    s_in = b.input("state", agg_out.type)
    d_in = b.input("delta", agg_out.type)
    merge_op = ("vec.MergeScalarState" if state_kind == "scalar"
                else "vec.MergeGroupedState")
    merged = b.emit1(merge_op, [s_in, d_in], params=_merge_params(agg))
    merge_program = b.finish(merged)

    finalize_program: Optional[Program] = None
    if suffix_body or any(r.name != agg_out.name for r in program.results):
        finalize_program = Program(
            name=f"{program.name}__finalize",
            inputs=(agg_out,) + tuple(finalize_boundary),
            body=tuple(suffix_body),
            results=program.results)

    for p in filter(None, (static_program, batch_program, merge_program,
                           finalize_program)):
        verify(p, allow_unknown_ops=True)

    return StreamPlan(
        source=program,
        stream_table=stream_table,
        state_kind=state_kind,
        agg=agg,
        static_program=static_program,
        batch_boundary=tuple(batch_boundary),
        batch_program=batch_program,
        merge_program=merge_program,
        finalize_boundary=tuple(finalize_boundary),
        finalize_program=finalize_program,
    )
