"""Fusion rewrites: combine producer-consumer chains into fused operators.

This is the paper's pipeline extraction in miniature: tree-shaped data paths
collapse into single instructions that the backend JIT-compiles as one unit
(here: a Pallas kernel or one XLA fusion).

* ``FuseSelectAgg`` — ``MaskSelect → [ExProjVec] → AggrVec`` becomes
  ``vec.FusedSelectAgg`` (the single-pass shape JITQ compiles TPC-H Q6 into).
* ``FuseSelectGroupAgg`` — ``MaskSelect → [ExProjVec] → GroupAggDirect``
  folds the predicate (and projected agg expressions) into the dense-bucket
  grouped aggregation, the TPC-H Q1 single-pass shape; under
  ``use_kernels`` the whole pipeline is one ``grouped_select_agg`` Pallas
  kernel invocation.
* ``FuseJoinGroupAgg`` — ``[MaskSelect →] HashJoinDirect → GroupAggDirect``
  becomes ``vec.FusedJoinGroupAgg``: the whole select→join→group pipeline
  (the TPC-H Q3/Q12 shape) runs as one pass and the join result is never
  materialized; under ``use_kernels`` it is one ``grouped_join_agg`` Pallas
  kernel invocation.
* ``FuseKMeansStep`` — ``CDist2 → ArgMinRow → SegSum + SegCount`` becomes
  ``la.KMeansStep`` (the "run-based aggregation" plan analysis the paper
  credits for matching hand-written C++ k-means).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from ..expr import AggSpec, Col, Const, Expr, col, substitute
from ..program import Instruction, Program
from ..types import BOOL
from .rewriter import ProgramRule

TRUE = Const(True, BOOL)


class FuseSelectAgg(ProgramRule):
    name = "fuse-select-agg"

    def run(self, program: Program) -> Optional[Program]:
        producers = program.producers()

        for y in program.body:
            if y.opcode != "vec.AggrVec":
                continue
            aggs = tuple(y.param("aggs"))
            src = y.inputs[0]
            chain: List[Instruction] = []
            exprs_map: Dict[str, Expr] = {}
            pred: Expr = TRUE

            cur = producers.get(src.name)
            # optional ExProj directly below the Aggr
            if cur is not None and cur.opcode == "vec.ExProjVec" and program.uses(cur.outputs[0]) == 1:
                exprs_map = {n: e for n, e in cur.param("exprs")}
                chain.append(cur)
                cur = producers.get(cur.inputs[0].name)
            # optional MaskSelect below that
            if cur is not None and cur.opcode == "vec.MaskSelect" and program.uses(cur.outputs[0]) == 1:
                pred = cur.param("pred")
                chain.append(cur)
                cur = producers.get(cur.inputs[0].name)

            if not chain:
                continue
            base = chain[-1].inputs[0]
            fused_aggs = tuple(
                AggSpec(a.fn, substitute(a.expr, exprs_map), a.name) for a in aggs
            )
            fused = Instruction(
                "vec.FusedSelectAgg",
                (base,),
                y.outputs,
                (("pred", pred), ("aggs", fused_aggs)),
            )
            dead = {id(c) for c in chain} | {id(y)}
            new_body = [fused if ins is y else ins for ins in program.body if id(ins) not in dead or ins is y]
            return program.with_body(new_body)
        return None


class FuseSelectGroupAgg(ProgramRule):
    """Fold MaskSelect → [ExProjVec] → GroupAggDirect into one instruction.

    MaskSelect only narrows the validity mask, so its predicate moves
    verbatim into GroupAggDirect's fused ``pred``; an intervening ExProjVec
    is absorbed by substituting its expressions into the agg specs, but only
    when every group key passes through as an identity column (a rename
    would change the output schema).  The sorted tier cannot fuse this way —
    the sort between select and aggregate forces materialization — which is
    part of why the direct tier wins on selective low-NDV queries.
    """

    name = "fuse-select-groupagg"

    def run(self, program: Program) -> Optional[Program]:
        producers = program.producers()

        for y in program.body:
            if y.opcode != "vec.GroupAggDirect":
                continue
            keys = tuple(y.param("keys"))
            aggs = tuple(y.param("aggs"))
            chain: List[Instruction] = []
            exprs_map: Dict[str, Expr] = {}
            pred: Optional[Expr] = y.param("pred")

            cur = producers.get(y.inputs[0].name)
            if (cur is not None and cur.opcode == "vec.ExProjVec"
                    and program.uses(cur.outputs[0]) == 1):
                exprs = {n: e for n, e in cur.param("exprs")}
                if all(isinstance(exprs.get(k), Col) and exprs[k].name == k
                       for k in keys):
                    exprs_map = exprs
                    if pred is not None:  # re-express over the base schema
                        pred = substitute(pred, exprs_map)
                    chain.append(cur)
                    cur = producers.get(cur.inputs[0].name)
            if (cur is not None and cur.opcode == "vec.MaskSelect"
                    and program.uses(cur.outputs[0]) == 1):
                sel = cur.param("pred")  # already over the base schema
                pred = sel if pred is None else (pred & sel)
                chain.append(cur)

            if not chain:
                continue
            base = chain[-1].inputs[0]
            fused_aggs = tuple(
                AggSpec(a.fn, substitute(a.expr, exprs_map), a.name)
                for a in aggs) if exprs_map else aggs
            params = dict(y.params)
            params["aggs"] = fused_aggs
            params["pred"] = pred
            fused = Instruction("vec.GroupAggDirect", (base,), y.outputs,
                                tuple(params.items()))
            dead = {id(c) for c in chain}
            new_body = [fused if ins is y else ins
                        for ins in program.body if id(ins) not in dead]
            return program.with_body(new_body)
        return None


class FuseJoinGroupAgg(ProgramRule):
    """Fold [MaskSelect →] HashJoinDirect → GroupAggDirect into one op.

    The whole-pipeline select→join→group shape (TPC-H Q3/Q12): the join
    result is never materialized — predicate, direct-table probe and dense
    grouped reduction become a single ``vec.FusedJoinGroupAgg`` instruction
    (one ``grouped_join_agg`` Pallas kernel under ``use_kernels``).

    Only the statically-bounded join variant fuses (``key_domains`` present;
    the dynamic-bounds variant carries an in-trace sorted fallback that the
    fused op cannot replicate).  A predicate already fused into the
    GroupAggDirect (by FuseSelectGroupAgg, which runs first) is absorbed
    when it only reads probe-side columns — left-column filters commute
    with a PK-FK inner join.  A MaskSelect feeding the join's probe side
    folds in the same way.  Column-name collisions between the sides would
    need the ``_r`` rename; the rule bails instead.
    """

    name = "fuse-join-groupagg"

    def run(self, program: Program) -> Optional[Program]:
        producers = program.producers()

        for y in program.body:
            if y.opcode != "vec.GroupAggDirect":
                continue
            join = producers.get(y.inputs[0].name)
            if (join is None or join.opcode != "vec.HashJoinDirect"
                    or program.uses(join.outputs[0]) != 1):
                continue
            if join.param("key_domains") is None:
                continue  # dynamic-bounds variant: in-trace fallback, no fuse
            left, right = join.inputs
            lnames = set(left.type.schema.names)
            right_on = tuple(join.param("right_on"))
            rnames = [n for n in right.type.schema.names if n not in right_on]
            if any(n in lnames for n in rnames):
                continue
            pred: Optional[Expr] = y.param("pred")
            if pred is not None and not set(pred.fields()) <= lnames:
                continue  # predicate reads a build-side column: can't hoist

            chain: List[Instruction] = []
            cur = producers.get(left.name)
            if (cur is not None and cur.opcode == "vec.MaskSelect"
                    and program.uses(cur.outputs[0]) == 1):
                sel = cur.param("pred")
                pred = sel if pred is None else (pred & sel)
                chain.append(cur)
                left = cur.inputs[0]

            jkd = tuple(join.param("key_domains"))
            njb = 1
            for lo, hi in jkd:
                njb *= int(hi) - int(lo) + 1
            fused = Instruction(
                "vec.FusedJoinGroupAgg",
                (left, right),
                y.outputs,
                (("pred", pred),
                 ("left_on", tuple(join.param("left_on"))),
                 ("right_on", right_on),
                 ("join_key_domains", jkd),
                 ("join_num_buckets", njb),
                 ("keys", tuple(y.param("keys"))),
                 ("aggs", tuple(y.param("aggs"))),
                 ("max_groups", int(y.param("max_groups"))),
                 ("key_domains", tuple(y.param("key_domains"))),
                 ("num_buckets", int(y.param("num_buckets")))),
            )
            dead = {id(c) for c in chain} | {id(join)}
            new_body = [fused if ins is y else ins
                        for ins in program.body if id(ins) not in dead]
            return program.with_body(new_body)
        return None


class FuseKMeansStep(ProgramRule):
    name = "fuse-kmeans-step"

    def run(self, program: Program) -> Optional[Program]:
        producers = program.producers()

        segsum = segcount = None
        for ins in program.body:
            if ins.opcode == "la.SegSum":
                segsum = ins
            if ins.opcode == "la.SegCount":
                segcount = ins
        if segsum is None or segcount is None:
            return None

        lab = segsum.inputs[1]
        if segcount.inputs[0].name != lab.name:
            return None
        argmin = producers.get(lab.name)
        if argmin is None or argmin.opcode != "la.ArgMinRow":
            return None
        if program.uses(argmin.outputs[0]) != 2:
            return None
        cdist = producers.get(argmin.inputs[0].name)
        if cdist is None or cdist.opcode != "la.CDist2":
            return None
        if program.uses(cdist.outputs[0]) != 1:
            return None
        x, c = cdist.inputs
        if segsum.inputs[0].name != x.name:
            return None

        fused = Instruction(
            "la.KMeansStep",
            (x, c),
            (segsum.outputs[0], segcount.outputs[0]),
        )
        dead = {id(cdist), id(argmin), id(segcount)}
        new_body = []
        for ins in program.body:
            if id(ins) in dead:
                continue
            if ins is segsum:
                new_body.append(fused)
                continue
            new_body.append(ins)
        return program.with_body(new_body)
