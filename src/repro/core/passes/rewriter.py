"""The rewriter framework: rules, passes, fixpoints.

Two rule granularities:

* ``InstructionRule`` — local 1→N rewrites (lowering one instruction into a
  sequence of another flavor's instructions).  The rule must bind the same
  output registers (possibly re-typed via an explicit adapter).
* ``ProgramRule`` — whole-program restructurings (parallelization, fusion,
  pipeline extraction) that need to look at producer/consumer structure.

``PassManager`` runs passes in order, each to a fixpoint (bounded), recursing
into nested programs, verifying after each pass when ``check=True``.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from ..program import Instruction, Program
from ..verify import verify


class FixpointWarning(UserWarning):
    """A pass hit its iteration bound while still reporting changes."""


class Pass:
    """Base class: transform a program (or return None for no change)."""

    name: str = "pass"
    recurse: bool = True  # also apply inside nested programs?

    def run(self, program: Program) -> Optional[Program]:
        raise NotImplementedError

    # -- driver ------------------------------------------------------------
    def apply(self, program: Program, max_iters: int = 50) -> Program:
        cur = program
        if self.recurse:
            cur = self._recurse_nested(cur, max_iters)
        for _ in range(max_iters):
            nxt = self.run(cur)
            if nxt is None:
                return cur
            cur = nxt
            if self.recurse:
                cur = self._recurse_nested(cur, max_iters)
        # the loop exhausted its budget: a silent half-rewritten program is a
        # debugging trap, so probe once more and complain if still changing
        if self.run(cur) is not None:
            warnings.warn(
                f"pass {self.name!r} hit max_iters={max_iters} while still "
                "reporting changes; returning the partially rewritten program",
                FixpointWarning,
                stacklevel=2,
            )
        return cur

    def _recurse_nested(self, program: Program, max_iters: int) -> Program:
        def fix(ins: Instruction) -> Sequence[Instruction]:
            if ins.is_higher_order():
                return [ins.map_nested(lambda p: self.apply(p, max_iters))]
            return [ins]

        return program.map_instructions(fix)


class InstructionRule(Pass):
    """Rewrite single instructions; unknown instructions are left as is."""

    def rewrite(self, ins: Instruction, program: Program) -> Optional[Sequence[Instruction]]:
        raise NotImplementedError

    def run(self, program: Program) -> Optional[Program]:
        changed = False
        new_body: List[Instruction] = []
        for ins in program.body:
            repl = self.rewrite(ins, program)
            if repl is None:
                new_body.append(ins)
            else:
                changed = True
                new_body.extend(repl)
        if not changed:
            return None
        return program.with_body(new_body)


class ProgramRule(Pass):
    pass


@dataclass
class PassManager:
    passes: List[Pass]
    check: bool = True
    allow_unknown_ops: bool = True
    trace: Optional[Callable[[str, Program], None]] = None

    def run(self, program: Program) -> Program:
        cur = program
        if self.check:
            verify(cur, allow_unknown_ops=self.allow_unknown_ops)
        for p in self.passes:
            cur = p.apply(cur)
            if self.check:
                try:
                    verify(cur, allow_unknown_ops=self.allow_unknown_ops)
                except Exception as e:
                    raise AssertionError(
                        f"pass {p.name!r} broke the program:\n{cur.render()}"
                    ) from e
            if self.trace is not None:
                self.trace(p.name, cur)
        return cur


def pipeline(*passes: Pass, check: bool = True) -> PassManager:
    return PassManager(list(passes), check=check)
