"""Common subexpression elimination over pure instructions.

Two instructions are congruent if opcode, canonicalized inputs and params
match (params include nested programs — compared structurally, which frozen
dataclasses give us for free).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .. import registry
from ..program import Instruction, Program, Register
from .rewriter import ProgramRule


def _key(ins: Instruction) -> Tuple:
    return (ins.opcode, tuple(r.name for r in ins.inputs), ins.params)


class CommonSubexpressionElimination(ProgramRule):
    name = "cse"

    def run(self, program: Program) -> Optional[Program]:
        seen: Dict[Tuple, Instruction] = {}
        replace: Dict[str, Register] = {}
        new_body: List[Instruction] = []
        changed = False

        for ins in program.body:
            # apply pending substitutions to inputs first
            if any(r.name in replace for r in ins.inputs):
                ins = ins.with_inputs([replace.get(r.name, r) for r in ins.inputs])
                changed = True
            spec = registry.lookup(ins.opcode)
            cse_ok = spec is not None and spec.pure and not spec.source and not spec.barrier
            if not cse_ok:
                new_body.append(ins)
                continue
            k = _key(ins)
            prev = seen.get(k)
            if prev is None:
                seen[k] = ins
                new_body.append(ins)
            else:
                changed = True
                for old, new in zip(ins.outputs, prev.outputs):
                    replace[old.name] = new

        if not changed:
            return None
        results = tuple(replace.get(r.name, r) for r in program.results)
        return program.with_body(new_body).with_results(results)
