"""Rewriting passes over CVM programs.

The rewriting mechanism is "highly flexible and configurable, such that
every frontend/backend combination can do the rewritings that are best
suited for that combination" (paper §3.6).  Passes must work in the
presence of collection types and instructions of *any* flavor: rules that
don't understand an instruction leave it as is.
"""

from .rewriter import (  # noqa: F401
    FixpointWarning, InstructionRule, Pass, PassManager, ProgramRule,
)
from .dce import DeadCodeElimination  # noqa: F401
from .cse import CommonSubexpressionElimination  # noqa: F401
from .parallelize import Parallelize  # noqa: F401
from .fusion import (FuseJoinGroupAgg, FuseKMeansStep, FuseSelectAgg,  # noqa: F401
                     FuseSelectGroupAgg)
from .mesh_lower import (  # noqa: F401
    LowerToMesh, PushCombineIntoMesh, PushGroupedCombineIntoMesh,
)
