"""CVM item/collection type grammar.

The paper (§3.2) defines::

    item := atom | tuple of items | collection of items

where an *atom* is an indivisible value of a domain, a *tuple* is a mapping
from names to items, and a *collection* is any (abstract or physical) data
type holding a finite homogeneous multiset of items.

This module implements that grammar as immutable, hashable Python objects.
Collection *kinds* are open-ended (the IR language fixes *how* collection
types look, not *which* exist): new kinds register themselves via
``CollectionKind``.  Abstract kinds (Set/Bag/Seq/KDSeq) model frontend
domains; physical kinds (Vec/Single/ArrayN/HTab) model backend layouts;
``Tensor`` is the custom collection type used by the LM/tensor flavor
(a kDSeq with static shape + dtype, which is what XLA needs).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Mapping, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Atoms
# ---------------------------------------------------------------------------

#: atom domains understood by the JAX lowering.  ``date`` is an i32 epoch-day
#: and ``str`` a dictionary-encoded i32 (documented TPU adaptation).
ATOM_DOMAINS: Dict[str, str] = {
    "bool": "bool_",
    "i8": "int8",
    "i16": "int16",
    "i32": "int32",
    "i64": "int64",
    "u32": "uint32",
    "f16": "float16",
    "bf16": "bfloat16",
    "f32": "float32",
    "f64": "float64",
    "date": "int32",
    "str": "int32",
    "id": "int32",
    "num": "float32",
}


class ItemType:
    """Base class of all item types."""

    def is_atom(self) -> bool:
        return isinstance(self, Atom)

    def is_tuple(self) -> bool:
        return isinstance(self, TupleType)

    def is_collection(self) -> bool:
        return isinstance(self, CollectionType)

    # rendered by subclasses
    def __repr__(self) -> str:  # pragma: no cover - trivial
        return self.render()

    def render(self) -> str:
        raise NotImplementedError


@dataclass(frozen=True)
class Atom(ItemType):
    """An indivisible value of a particular domain."""

    domain: str

    def __post_init__(self) -> None:
        if self.domain not in ATOM_DOMAINS:
            raise TypeError(f"unknown atom domain {self.domain!r}")

    @property
    def np_dtype(self) -> str:
        return ATOM_DOMAINS[self.domain]

    def render(self) -> str:
        return self.domain


# common atoms
BOOL = Atom("bool")
I32 = Atom("i32")
I64 = Atom("i64")
F32 = Atom("f32")
F64 = Atom("f64")
BF16 = Atom("bf16")
DATE = Atom("date")
STR = Atom("str")
ID = Atom("id")
NUM = Atom("num")


# ---------------------------------------------------------------------------
# Tuples
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TupleType(ItemType):
    """A mapping from field names to item types.

    Field order is significant for *physical* layouts (the paper: "the
    lexicographical order of the field names defines the physical order");
    we keep declaration order and expose ``lex_fields`` for layouts.
    """

    fields: Tuple[Tuple[str, ItemType], ...]

    def __post_init__(self) -> None:
        names = [n for n, _ in self.fields]
        if len(set(names)) != len(names):
            raise TypeError(f"duplicate field names in tuple type: {names}")
        for _, t in self.fields:
            if not isinstance(t, ItemType):
                raise TypeError(f"tuple field must be ItemType, got {t!r}")

    @staticmethod
    def of(**fields: ItemType) -> "TupleType":
        return TupleType(tuple(fields.items()))

    @staticmethod
    def make(items: Mapping[str, ItemType] | Iterable[Tuple[str, ItemType]]) -> "TupleType":
        if isinstance(items, Mapping):
            return TupleType(tuple(items.items()))
        return TupleType(tuple(items))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.fields)

    @property
    def lex_fields(self) -> Tuple[Tuple[str, ItemType], ...]:
        return tuple(sorted(self.fields, key=lambda kv: kv[0]))

    def field(self, name: str) -> ItemType:
        for n, t in self.fields:
            if n == name:
                return t
        raise KeyError(name)

    def has_field(self, name: str) -> bool:
        return any(n == name for n, _ in self.fields)

    def project(self, names: Sequence[str]) -> "TupleType":
        return TupleType(tuple((n, self.field(n)) for n in names))

    def render(self) -> str:
        inner = ", ".join(f"{n}: {t.render()}" for n, t in self.fields)
        return f"⟨{inner}⟩"  # ⟨...⟩


# ---------------------------------------------------------------------------
# Collections
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CollectionKind:
    """A *kind* of collection (Set, Bag, Vec, ...).

    ``abstract`` kinds carry domain semantics only; physical kinds promise a
    memory layout to the lowering.  ``ordered`` distinguishes Seq-like kinds.
    Kinds form an open registry — frontends/backends add their own, which is
    the essence of the CVM IR *language* (the framework fixes the grammar,
    not the vocabulary).
    """

    name: str
    abstract: bool = True
    ordered: bool = False

    _registry: Dict[str, "CollectionKind"] = dataclasses.field(
        default=None, repr=False, compare=False
    )  # type: ignore[assignment]

    def __post_init__(self) -> None:
        KIND_REGISTRY[self.name] = self


KIND_REGISTRY: Dict[str, CollectionKind] = {}

SET = CollectionKind("Set", abstract=True, ordered=False)
BAG = CollectionKind("Bag", abstract=True, ordered=False)
SEQ = CollectionKind("Seq", abstract=True, ordered=True)
KDSEQ = CollectionKind("KDSeq", abstract=True, ordered=True)
VEC = CollectionKind("Vec", abstract=False, ordered=True)
SINGLE = CollectionKind("Single", abstract=False, ordered=True)
ARRAYN = CollectionKind("ArrayN", abstract=False, ordered=True)
HTAB = CollectionKind("HTab", abstract=False, ordered=False)
TENSOR = CollectionKind("Tensor", abstract=False, ordered=True)
STREAM = CollectionKind("Stream", abstract=True, ordered=True)  # unbounded data source


@dataclass(frozen=True)
class CollectionType(ItemType):
    """A finite homogeneous multiset of ``item`` with layout/semantic ``kind``.

    ``attrs`` carry kind-specific compile-time parameters:
      * KDSeq/Tensor: ``shape`` (tuple of ints, -1 for unknown dims)
      * ArrayN: ``n`` (compile-time length)
      * Tensor: ``dtype`` is in ``item`` (an Atom); optional ``spec``
        (sharding hint tuple, entries: mesh-axis name, tuple thereof, or None)
      * Vec: optional ``max_count`` (static padded capacity)
    """

    kind: CollectionKind
    item: ItemType
    attrs: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self) -> None:
        if not isinstance(self.item, ItemType):
            raise TypeError(f"collection item must be ItemType, got {self.item!r}")
        # canonicalize attr order so structural equality is insensitive to
        # the order in which attrs were attached
        object.__setattr__(self, "attrs", tuple(sorted(self.attrs, key=lambda kv: kv[0])))

    # -- attr helpers -----------------------------------------------------
    def attr(self, name: str, default: Any = None) -> Any:
        for k, v in self.attrs:
            if k == name:
                return v
        return default

    def with_attr(self, name: str, value: Any) -> "CollectionType":
        rest = tuple((k, v) for k, v in self.attrs if k != name)
        return CollectionType(self.kind, self.item, rest + ((name, value),))

    def with_item(self, item: ItemType) -> "CollectionType":
        return CollectionType(self.kind, item, self.attrs)

    def with_kind(self, kind: CollectionKind) -> "CollectionType":
        return CollectionType(kind, self.item, self.attrs)

    # -- convenience ------------------------------------------------------
    @property
    def schema(self) -> TupleType:
        if not isinstance(self.item, TupleType):
            raise TypeError(f"collection of {self.item.render()} has no schema")
        return self.item

    def render(self) -> str:
        extra = ""
        if self.attrs:
            extra = "[" + ", ".join(f"{k}={v}" for k, v in self.attrs) + "]"
        return f"{self.kind.name}{extra}⟨{self.item.render()}⟩"


# -- constructors ----------------------------------------------------------


def Set_(item: ItemType) -> CollectionType:
    return CollectionType(SET, item)


def Bag(item: ItemType) -> CollectionType:
    return CollectionType(BAG, item)


def Seq(item: ItemType) -> CollectionType:
    return CollectionType(SEQ, item)


def KDSeq(item: ItemType, shape: Tuple[int, ...]) -> CollectionType:
    return CollectionType(KDSEQ, item, (("shape", tuple(shape)),))


def Vec(item: ItemType, max_count: Optional[int] = None) -> CollectionType:
    attrs: Tuple[Tuple[str, Any], ...] = ()
    if max_count is not None:
        attrs = (("max_count", int(max_count)),)
    return CollectionType(VEC, item, attrs)


def Single(item: ItemType) -> CollectionType:
    return CollectionType(SINGLE, item)


def ArrayN(item: ItemType, n: int) -> CollectionType:
    return CollectionType(ARRAYN, item, (("n", int(n)),))


def HTab(item: ItemType) -> CollectionType:
    return CollectionType(HTAB, item)


def Tensor(dtype: Atom, shape: Sequence[int], spec: Optional[Tuple[Any, ...]] = None) -> CollectionType:
    attrs: Tuple[Tuple[str, Any], ...] = (("shape", tuple(int(s) for s in shape)),)
    if spec is not None:
        attrs += (("spec", tuple(spec)),)
    return CollectionType(TENSOR, dtype, attrs)


def Stream(item: ItemType) -> CollectionType:
    return CollectionType(STREAM, item)


# ---------------------------------------------------------------------------
# Structural helpers / matching
# ---------------------------------------------------------------------------


def atom_nbytes(a: Atom) -> int:
    """Storage bytes of one atom value (from its numpy dtype)."""
    import numpy as np

    return int(np.dtype(ATOM_DOMAINS[a.domain]).itemsize)


def item_nbytes(t: ItemType, default: int = 8) -> int:
    """Estimated bytes per item: the statistics/cost hooks of the type grammar.

    Atoms answer exactly; tuples sum their fields; collections answer per
    *element* of the collection (a row of a relation, a scalar of a tensor).
    Unknown/opaque items fall back to ``default``.
    """
    if isinstance(t, Atom):
        return atom_nbytes(t)
    if isinstance(t, TupleType):
        if not t.fields:
            return default
        return sum(item_nbytes(ft, default) for _, ft in t.fields)
    if isinstance(t, CollectionType):
        return item_nbytes(t.item, default)
    return default


def is_coll(t: ItemType, kind: Optional[CollectionKind] = None) -> bool:
    return isinstance(t, CollectionType) and (kind is None or t.kind is kind)


def is_tensor(t: ItemType) -> bool:
    return is_coll(t, TENSOR)


def tensor_shape(t: ItemType) -> Tuple[int, ...]:
    assert isinstance(t, CollectionType) and t.kind is TENSOR, t
    return t.attr("shape")


def tensor_dtype(t: ItemType) -> Atom:
    assert isinstance(t, CollectionType) and t.kind is TENSOR
    assert isinstance(t.item, Atom)
    return t.item


def common_kind(a: CollectionKind, b: CollectionKind) -> CollectionKind:
    """Join of two abstract kinds: Seq⊔Seq=Seq, Set⊔Set=Set, else Bag.

    Mirrors the paper's typing rules where e.g. Proj on a Seq yields a Seq,
    on a Set a Set, otherwise a Bag.
    """
    if a is b:
        return a
    return BAG


def schema_of(t: ItemType) -> TupleType:
    if not isinstance(t, CollectionType):
        raise TypeError(f"expected a collection type, got {t.render()}")
    return t.schema


def relation(kind: CollectionKind = BAG, **fields: ItemType) -> CollectionType:
    """Shorthand: a relation is a collection of tuples of atoms."""
    return CollectionType(kind, TupleType.of(**fields))


def substitute_item(t: ItemType, new_item: ItemType) -> ItemType:
    if isinstance(t, CollectionType):
        return t.with_item(new_item)
    raise TypeError("can only substitute item of a collection type")


def type_eq(a: ItemType, b: ItemType) -> bool:
    return a == b


def assert_type_eq(a: ItemType, b: ItemType, where: str = "") -> None:
    if a != b:
        raise TypeError(f"type mismatch{(' in ' + where) if where else ''}: "
                        f"{a.render()} vs {b.render()}")
