"""Deterministic environments for jax-spawning subprocesses.

Tests and benchmarks launch workers with a minimal env so XLA flags (device
counts must be set before jax initializes) and stray user configuration
can't leak in.  Centralised here because every spawn needs the same
footgun-guard: containers that ship libtpu but have no TPU attached hang
for minutes in TPU init unless the platform is pinned.
"""

from __future__ import annotations

import os
from pathlib import Path
from typing import Iterable


def subprocess_env(repo_root: Path, *, extra_pythonpath: Iterable[str] = (),
                   **overrides: str) -> dict:
    """Minimal env for a jax subprocess: repo sources + pinned platform."""
    pythonpath = ":".join([str(Path(repo_root) / "src"),
                           *map(str, extra_pythonpath)])
    env = {
        "PYTHONPATH": pythonpath,
        "PATH": "/usr/bin:/bin",
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
    }
    env.update(overrides)
    return env
