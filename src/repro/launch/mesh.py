"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Production target: TPU v5e pods, 256 chips/pod
(16×16), optionally 2 pods (the "pod" axis is the DCN/elastic axis — the
Lambada analogue in DESIGN.md §2).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/benchmarks (host-device counts permitting)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
