"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Production target: TPU v5e pods, 256 chips/pod
(16×16), optionally 2 pods (the "pod" axis is the DCN/elastic axis — the
Lambada analogue in DESIGN.md §2).
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int):
    # jax.sharding.AxisType only exists on newer jax; older releases default
    # to Auto axes, which is exactly what we would request
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/benchmarks (host-device counts permitting)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))
