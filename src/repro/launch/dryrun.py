import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent + roofline.

For every (architecture × input shape × mesh) cell this lowers the step
function (train_step / prefill_step / serve_step) with full production
shardings, compiles it, and records memory_analysis / cost_analysis /
collective bytes parsed from the compiled HLO.

**Loop correction.** XLA cost analysis counts a ``while`` body once, but the
production configs scan over layers / microbatches / kv-blocks.  The
roofline therefore comes from *probes*: the same cell re-lowered with
``scan_unroll=True`` and n_layers ∈ {1, 2} (plus attn_every / enc-layer
variants for the hybrid and enc-dec families).  Cost is exactly affine in
layer count, so two (or three) probes identify slope+intercept and
extrapolate to the full depth.  RWKV's O(S) time scan cannot be unrolled;
its wkv FLOPs are added analytically (documented).

Artifacts: ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --skip-existing
"""

import argparse
import json
import re
import sys
import time
import traceback
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np

from ..configs import ARCH_IDS, SHAPES, cell_applicable, get_config, input_specs
from ..models.api import build_model, make_prefill_step, make_serve_step, make_train_step
from ..models import sharding as shd
from ..train.optimizer import AdamW
from .mesh import make_production_mesh

import os as _os
ARTIFACTS = Path(_os.environ.get(
    "REPRO_DRYRUN_DIR",
    str(Path(__file__).resolve().parents[3] / "artifacts" / "dryrun")))
_DONATE = _os.environ.get("REPRO_NO_DONATE") != "1"

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_COLLECTIVE_RE = re.compile(
    r"(\w+)\[([0-9,]*)\][^=]*\s(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)"
)

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}


def parse_collective_bytes(hlo_text: str):
    """Sum output sizes of collective ops in the compiled HLO, per kind."""
    per_kind = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        per_kind[kind] = per_kind.get(kind, 0) + n * _DTYPE_BYTES[dtype]
    return per_kind


def _named(mesh, tree):
    return shd.named(mesh, tree)


def _dp_size(multi_pod: bool) -> int:
    return 32 if multi_pod else 16


def lower_cfg_cell(cfg, shape_name: str, *, multi_pod: bool = False,
                   zero1: bool = True, microbatch=None, donate: bool = None):
    """Shard + lower one (cfg × shape × mesh); returns (lowered, meta).

    ``donate`` aliases params/opt (train) and the KV cache (decode) between
    input and output — removes the double buffer (§Perf iteration 1).
    """
    if donate is None:
        donate = _DONATE
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    kind, specs = input_specs(cfg, shape_name)

    key_spec = jax.ShapeDtypeStruct((2,), np.dtype("uint32"))
    params_shapes = jax.eval_shape(model.init, key_spec)
    pspecs = shd.tree_param_specs(params_shapes, mesh)

    with mesh:
        if kind == "train":
            m = microbatch
            if m is None:
                m = max(1, SHAPES[shape_name].global_batch // _dp_size(multi_pod))
            gc = None
            if m > 1 and _os.environ.get("REPRO_NO_ZERO2") != "1":
                gspecs = shd.tree_grad_specs(params_shapes, pspecs, mesh)
                gnamed = _named(mesh, gspecs)
                gc = lambda tree: jax.lax.with_sharding_constraint(tree, gnamed)
            step, opt = make_train_step(model, AdamW(), microbatch=m,
                                        grad_constraint=gc)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            ospecs = shd.tree_opt_specs(opt_shapes, pspecs, mesh, zero1=zero1)
            bspecs = shd.batch_specs(
                {k: (v.shape, v.dtype) for k, v in specs.items()}, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                              _named(mesh, bspecs)),
                donate_argnums=(0, 1) if donate else (),
            ).lower(params_shapes, opt_shapes, specs)
        elif kind == "prefill":
            cap = SHAPES[shape_name].seq_len
            step = make_prefill_step(model, cap)
            bspecs = shd.batch_specs(
                {k: (v.shape, v.dtype) for k, v in specs.items()}, mesh)
            # shard the emitted KV cache (it is the big output)
            out_shapes = jax.eval_shape(step, params_shapes, specs)
            out_specs = shd.cache_specs(out_shapes, mesh, cfg)
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
                out_shardings=_named(mesh, out_specs),
            ).lower(params_shapes, specs)
        else:  # decode
            step = make_serve_step(model)
            sspecs = shd.cache_specs(specs["state"], mesh, cfg)
            tspecs = shd.batch_specs(
                {"tokens": (specs["tokens"].shape, specs["tokens"].dtype)}, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, pspecs), _named(mesh, sspecs),
                              _named(mesh, tspecs)["tokens"]),
                donate_argnums=(1,) if donate else (),
            ).lower(params_shapes, specs["state"], specs["tokens"])

    n_chips = 512 if multi_pod else 256
    meta = {"arch": cfg.arch, "shape": shape_name, "kind": kind,
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips,
            "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params()}
    return lowered, meta


def _measure(cfg, shape_name, multi_pod, microbatch=None):
    """(flops, bytes, collective bytes) per device of one compiled config."""
    lowered, _ = lower_cfg_cell(cfg, shape_name, multi_pod=multi_pod,
                                microbatch=microbatch)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = parse_collective_bytes(hlo)
    return np.array([float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)),
                     float(sum(coll.values()))]), coll


# ---------------------------------------------------------------------------
# probes: loop-corrected roofline vectors
# ---------------------------------------------------------------------------


def _probe_cfg(cfg, **over):
    return replace(cfg, scan_unroll=True, loss_chunk=10 ** 9, **over)


def corrected_vector(cfg, shape_name: str, multi_pod: bool):
    """Loop-corrected (flops, bytes, coll_bytes) per device for the cell."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "rwkv"):
        p1, _ = _measure(_probe_cfg(cfg, n_layers=1), shape_name, multi_pod, microbatch=1)
        p2, _ = _measure(_probe_cfg(cfg, n_layers=2), shape_name, multi_pod, microbatch=1)
        vec = p1 + (p2 - p1) * (cfg.n_layers - 1)
        if fam == "rwkv":
            vec = vec + _rwkv_wkv_correction(cfg, shape_name, multi_pod)
        return vec
    if fam == "hybrid":
        p1, _ = _measure(_probe_cfg(cfg, n_layers=1, attn_every=1), shape_name,
                         multi_pod, microbatch=1)
        p2, _ = _measure(_probe_cfg(cfg, n_layers=2, attn_every=1), shape_name,
                         multi_pod, microbatch=1)
        p3, _ = _measure(_probe_cfg(cfg, n_layers=2, attn_every=2), shape_name,
                         multi_pod, microbatch=1)
        attn = p2 - p3
        mamba = p3 - p1
        base = p1 - attn - mamba
        n_attn = -(-cfg.n_layers // cfg.attn_every)
        return base + n_attn * attn + cfg.n_layers * mamba
    if fam == "encdec":
        p1, _ = _measure(_probe_cfg(cfg, n_layers=1, n_enc_layers=1), shape_name,
                         multi_pod, microbatch=1)
        p2, _ = _measure(_probe_cfg(cfg, n_layers=1, n_enc_layers=2), shape_name,
                         multi_pod, microbatch=1)
        p3, _ = _measure(_probe_cfg(cfg, n_layers=2, n_enc_layers=1), shape_name,
                         multi_pod, microbatch=1)
        enc = p2 - p1
        dec = p3 - p1
        base = p1 - enc - dec
        return base + cfg.n_enc_layers * enc + cfg.n_layers * dec
    raise ValueError(fam)


def _rwkv_wkv_correction(cfg, shape_name, multi_pod):
    """Analytic FLOPs of the O(S) wkv time scan (cannot be unrolled).

    Per token per layer per head: r·S read (2 P²) + k⊗v outer (P²) + decay
    mult (P²) + state add (P²) ≈ 5 P² FLOPs; ×4 for fwd+remat+bwd in train.
    Counted per device (tokens are batch-sharded over the dp axes).
    """
    sh = SHAPES[shape_name]
    dp = _dp_size(multi_pod)
    h = cfg.d_model // 64
    p = 64
    if sh.kind == "train":
        tokens_dev = sh.seq_len * max(1, sh.global_batch // dp)
        factor = 4.0
    elif sh.kind == "prefill":
        tokens_dev = sh.seq_len * max(1, sh.global_batch // dp)
        factor = 1.0
    else:
        return np.zeros(3)  # decode scan has length 1 — already counted
    flops = factor * 5.0 * tokens_dev * cfg.n_layers * h * p * p
    return np.array([flops, 0.0, 0.0])


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def roofline_terms(vec, meta, seq, batch, chips):
    flops_dev, bytes_dev, coll_dev = [float(x) for x in vec]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max([("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)], key=lambda kv: kv[1])[0]
    n = meta["n_active_params"]
    if meta["kind"] == "train":
        model_flops = 6.0 * n * seq * batch
    elif meta["kind"] == "prefill":
        model_flops = 2.0 * n * seq * batch
    else:
        model_flops = 2.0 * n * batch
    model_flops_dev = model_flops / chips
    return {
        "flops_per_device": flops_dev,
        "bytes_per_device_accessed": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops_dev,
        "useful_fraction": (model_flops_dev / flops_dev) if flops_dev else 0.0,
        "roofline_fraction": (model_flops_dev / PEAK_FLOPS) /
                             max(t_compute, t_memory, t_coll)
                             if max(t_compute, t_memory, t_coll) > 0 else 0.0,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             save: bool = True, verbose: bool = True, probes: bool = True):
    cfg = get_config(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, reason = cell_applicable(cfg, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": reason}
        if save:
            _save(rec)
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: SKIP ({reason})")
        return rec

    t0 = time.time()
    lowered, meta = lower_cfg_cell(cfg, shape_name, multi_pod=multi_pod)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll_raw = parse_collective_bytes(hlo)

    sh = SHAPES[shape_name]
    rec = dict(meta)
    rec["raw"] = {  # uncorrected (loop bodies counted once) — sanity only
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_by_kind": coll_raw,
    }
    if probes:
        vec = corrected_vector(cfg, shape_name, multi_pod)
        rec.update(roofline_terms(vec, meta, sh.seq_len, sh.global_batch,
                                  meta["chips"]))
    rec["bytes_per_device"] = {
        "arguments": mem.argument_size_in_bytes,
        "outputs": mem.output_size_in_bytes,
        "temps": mem.temp_size_in_bytes,
        "aliased": mem.alias_size_in_bytes,
    }
    rec["device_mem_gib"] = round(
        (mem.argument_size_in_bytes + mem.temp_size_in_bytes
         - mem.alias_size_in_bytes) / 2 ** 30, 3)
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    if save:
        _save(rec)
    if verbose:
        dom = rec.get("dominant", "?")
        rf = rec.get("roofline_fraction", 0)
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
              f"dev_mem={rec['device_mem_gib']}GiB dominant={dom} "
              f"roofline={rf:.3f} (lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {mem}")
        if probes:
            print(f"  cost_analysis (corrected, per-device): "
                  f"flops={rec['flops_per_device']:.4g} "
                  f"bytes={rec['bytes_per_device_accessed']:.4g} "
                  f"coll={rec['collective_bytes_per_device']:.4g}")
    return rec


def _save(rec):
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    (ARTIFACTS / name).write_text(json.dumps(rec, indent=2, default=str))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="sharding/memory proof only (fast)")
    args = ap.parse_args(argv)

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            out = ARTIFACTS / f"{a}__{s}__{mesh_name}.json"
            if args.skip_existing and out.exists():
                print(f"[dryrun] {a} × {s} × {mesh_name}: cached")
                continue
            try:
                # probes (roofline) on the single-pod mesh only, per spec
                run_cell(a, s, multi_pod=mp, probes=(not args.no_probes) and not mp)
            except Exception as e:
                failures.append((a, s, mesh_name, repr(e)))
                print(f"[dryrun] {a} × {s} × {mesh_name}: FAIL {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nall requested dry-run cells OK")


if __name__ == "__main__":
    main()
