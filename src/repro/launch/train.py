"""Training driver: config → CVM-planned distribution → fault-tolerant loop.

The step program is planned through CVM (see ``frontends/tensor.py``): the
parallelization rewrite decides the mesh axes and pre-aggregation, the SPMD
backend binds them to GSPMD shardings, and this driver owns the run loop:
deterministic data, checkpoint cadence, restore-on-failure, straggler log.

On the CPU container use reduced configs::

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 50 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, get_reduced
from ..data.pipeline import TokenPipeline
from ..distributed.checkpoint import CheckpointManager
from ..distributed.fault import StepRunner
from ..models.api import build_model, make_train_step
from ..train.optimizer import AdamW


def make_batch_fn(cfg, pipeline: TokenPipeline):
    """Adapt the token pipeline to each family's batch dict."""

    def at(step: int):
        b = pipeline.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "vlm":
            bsz, s = batch["tokens"].shape
            rng = np.random.default_rng((1234, step))
            batch["embeds"] = jnp.asarray(
                rng.normal(size=(bsz, s, cfg.d_model)).astype(np.float32))
            batch["positions3"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (3, bsz, s))
            del batch["tokens"]
        elif cfg.family == "encdec":
            bsz, s = batch["tokens"].shape
            rng = np.random.default_rng((4321, step))
            batch["frames"] = jnp.asarray(
                rng.normal(size=(bsz, s, cfg.d_model)).astype(np.float32))
        return batch

    return at


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    print(f"[train] {cfg.arch}: {cfg.n_params()/1e6:.1f}M params "
          f"({cfg.n_active_params()/1e6:.1f}M active)")

    params = model.init(jax.random.PRNGKey(0))
    step_fn, opt = make_train_step(model, AdamW(lr=args.lr),
                                   microbatch=args.microbatch)
    opt_state = opt.init(params)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        (params, opt_state), extra = ckpt.restore((params, opt_state))
        start_step = int(extra.get("step", 0))
        print(f"[train] resumed from step {start_step}")

    pipeline = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch)
    batch_at = make_batch_fn(cfg, pipeline)

    runner = StepRunner(
        step_fn=lambda p, o, b: jstep(p, o, b),
        ckpt=ckpt, ckpt_every=args.ckpt_every)

    def batches():
        s = start_step
        while True:
            yield s, batch_at(s)
            s += 1

    t0 = time.time()
    params, opt_state = runner.run((params, opt_state), batches(),
                                   start_step=start_step, num_steps=args.steps)
    dt = time.time() - t0
    losses = [h.loss for h in runner.history if h.loss is not None]
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({1000*dt/max(1,args.steps):.0f} ms/step); "
          f"loss {losses[0]:.3f} → {losses[-1]:.3f}; "
          f"stragglers={runner.stragglers}")
    return losses


if __name__ == "__main__":
    main()
