"""Serving driver: batched decode with a functional KV cache + load shedding.

Continuous-batching-style loop: a request pool keeps the decode batch full;
finished sequences (EOS or length budget) are swapped out and their slots
re-prefilled.  Admission control sits in front of the decode loop:

  * requests enter a **bounded queue** (``--queue-cap``) — arrivals beyond
    the cap are shed immediately (``serve.shed.queue_full``) instead of
    growing an unbounded backlog;
  * each request carries an optional **deadline** (``--deadline-s``); a
    request whose deadline has already passed when its wave forms is shed
    (``serve.shed.deadline``) rather than burning decode steps on an answer
    nobody is waiting for;
  * a wave that keeps failing after bounded retries sheds its requests
    (``serve.shed.error``) and the loop moves on — a poison batch cannot
    wedge the server.

The loop itself (:func:`serve_loop`) is model-free: it drives any
``run_wave(requests) -> {rid: output}`` callable, which is what the chaos
tests exercise with injected slow/failing steps (``serve.step``).

On the CPU container use reduced configs::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 16 --batch 4 --gen 16
"""

from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Iterable, List, Optional

import numpy as np

from ..obs.trace import Tracer, get_tracer, set_tracer
from ..robust.inject import maybe_inject
from ..robust.retry import Deadline, RetryPolicy, call_with_retry

#: bounded retries for a failing decode wave before its requests are shed
WAVE_RETRY = RetryPolicy(max_retries=2, backoff_s=0.01)


@dataclass(frozen=True)
class Request:
    """One generation request: a prompt and an optional deadline."""

    rid: int
    prompt: Any
    deadline: Optional[Deadline] = None


@dataclass
class ShedStats:
    """Why requests were dropped instead of served."""

    queue_full: int = 0
    deadline: int = 0
    error: int = 0

    @property
    def total(self) -> int:
        return self.queue_full + self.deadline + self.error


class AdmissionQueue:
    """Bounded FIFO with deadline-aware dequeue.

    ``offer`` rejects (sheds) when the queue is at capacity; ``take`` skips
    (sheds) requests whose deadline already passed.  Both bump the
    ``serve.shed`` counter plus a per-reason counter, so the ``--trace``
    metrics dump shows not just *that* load was shed but *why*.
    """

    def __init__(self, cap: Optional[int] = None) -> None:
        self.cap = cap
        self.shed = ShedStats()
        self._q: deque = deque()

    def __len__(self) -> int:
        return len(self._q)

    def offer(self, req: Request) -> bool:
        if self.cap is not None and len(self._q) >= self.cap:
            self.shed.queue_full += 1
            tracer = get_tracer()
            tracer.counter("serve.shed")
            tracer.counter("serve.shed.queue_full")
            return False
        self._q.append(req)
        return True

    def take(self, n: int) -> List[Request]:
        out: List[Request] = []
        while self._q and len(out) < n:
            req = self._q.popleft()
            if req.deadline is not None and req.deadline.expired():
                self._shed_deadline(req)
                continue
            out.append(req)
        return out

    def shed_expired(self, wave: List[Request]) -> List[Request]:
        """Drop already-expired requests from a formed wave (post-delay)."""
        keep: List[Request] = []
        for req in wave:
            if req.deadline is not None and req.deadline.expired():
                self._shed_deadline(req)
            else:
                keep.append(req)
        return keep

    def _shed_deadline(self, req: Request) -> None:
        self.shed.deadline += 1
        tracer = get_tracer()
        tracer.counter("serve.shed")
        tracer.counter("serve.shed.deadline")
        tracer.event("serve.shed.deadline", rid=req.rid)


def serve_loop(requests: Iterable[Request],
               run_wave: Callable[[List[Request]], Dict[int, Any]],
               *,
               batch: int,
               queue_cap: Optional[int] = None,
               deadline_s: Optional[float] = None,
               retry: RetryPolicy = WAVE_RETRY,
               ) -> Dict[int, Any]:
    """Admission-controlled wave loop; returns ``{rid: output}`` for the
    requests that were actually served (shed requests are absent).

    Termination is structural: every admitted request is either served,
    shed on deadline, or shed after bounded wave retries — the loop cannot
    spin on a request it will never finish.
    """
    tracer = get_tracer()
    queue = AdmissionQueue(queue_cap)
    outputs: Dict[int, Any] = {}
    for req in requests:
        if deadline_s is not None and req.deadline is None:
            req = replace(req, deadline=Deadline.after(deadline_s))
        queue.offer(req)

    while len(queue):
        wave = queue.take(batch)
        if not wave:
            continue  # everything taken was past deadline; re-check queue
        wave_t0 = time.perf_counter()
        with tracer.span("serve.wave", cat="serve", requests=len(wave),
                         batch=batch) as wave_span:
            # fault-injection point: "raise" fails the wave (retried, then
            # shed), "delay" slows it so queued deadlines expire
            def attempt() -> Dict[int, Any]:
                maybe_inject("serve.step", batch=len(wave))
                # mutate in place: a request shed on one attempt must not be
                # re-shed (re-counted) by a retry
                wave[:] = queue.shed_expired(wave)
                return run_wave(wave) if wave else {}

            try:
                got = call_with_retry(attempt, retry, name="serve.step")
            except Exception as e:
                queue.shed.error += len(wave)
                tracer.counter("serve.shed", len(wave))
                tracer.counter("serve.shed.error", len(wave))
                tracer.event("serve.wave_failed", requests=len(wave),
                             error=f"{type(e).__name__}: {e}")
                continue
            outputs.update(got)
            wave_dt = time.perf_counter() - wave_t0
            wave_span.set(served=len(got), wall_s=wave_dt)
        # every request in the wave shares its wall time (batched decode)
        for _ in got:
            tracer.observe("serve.request_latency_s", wave_dt)
        tracer.counter("serve.requests", len(got))
    return outputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    from ..configs import ARCH_IDS

    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-cap", type=int, default=64)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline; requests still queued past "
                         "it are shed instead of decoded")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bound the admission queue; arrivals beyond the "
                         "cap are shed immediately")
    ap.add_argument("--trace", nargs="?", const="trace__serve.json",
                    default=None, metavar="PATH",
                    help="enable tracing and write a Chrome trace "
                         "(chrome://tracing / Perfetto) to PATH")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config, get_reduced
    from ..models.api import build_model, make_serve_step

    previous_tracer = None
    if args.trace:
        previous_tracer = set_tracer(Tracer(enabled=True))
    tracer = get_tracer()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    if model.decode is None:
        raise SystemExit(f"{cfg.arch} has no decode path")
    params = model.init(jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(model), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len))
    requests = [Request(rid=i, prompt=prompts[i]) for i in range(args.requests)]

    def run_wave(wave: List[Request]) -> Dict[int, Any]:
        take = len(wave)
        bsz = args.batch
        # waves survive shedding, so request ids need not be contiguous
        toks = np.zeros((bsz, args.prompt_len), np.int32)
        toks[:take] = np.stack([r.prompt for r in wave]).astype(np.int32)

        if cfg.family == "encdec":
            frames = jnp.asarray(
                rng.normal(size=(bsz, args.prompt_len, cfg.d_model)),
                jnp.float32)
            state = model.prefill(params, {"frames": frames}, args.cache_cap)
            tok = jnp.zeros((bsz, 1), jnp.int32)
        elif cfg.family in ("dense", "moe") and model.prefill is not None:
            logits, state = model.prefill(
                params, {"tokens": jnp.asarray(toks)}, args.cache_cap)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        else:
            state = model.init_state(bsz, args.cache_cap)
            tok = jnp.zeros((bsz, 1), jnp.int32)

        gen = np.zeros((bsz, args.gen), np.int32)
        for i in range(args.gen):
            tok, logits, state = serve(params, state, tok)
            gen[:, i] = np.asarray(tok[:, 0])
        tracer.counter("serve.tokens", take * args.gen)
        return {r.rid: gen[j] for j, r in enumerate(wave)}

    t0 = time.time()
    outputs = serve_loop(requests, run_wave, batch=args.batch,
                         queue_cap=args.queue_cap,
                         deadline_s=args.deadline_s)
    dt = time.time() - t0
    total_tokens = len(outputs) * args.gen
    shed = args.requests - len(outputs)
    print(f"[serve] {len(outputs)}/{args.requests} requests × {args.gen} "
          f"tokens in {dt:.1f}s → {total_tokens/max(dt, 1e-9):.1f} tok/s "
          f"(batch={args.batch}, shed={shed})")
    if args.trace:
        from ..obs.export import write_chrome_trace

        lat = tracer.histogram_summary("serve.request_latency_s") or {}
        if lat:
            print(f"[serve] request latency p50={lat['p50']:.3f}s "
                  f"p99={lat['p99']:.3f}s over {int(lat['count'])} requests")
        write_chrome_trace(args.trace, tracer)
        print(f"[serve] chrome trace → {args.trace}")
        set_tracer(previous_tracer)
    return outputs


if __name__ == "__main__":
    main()
