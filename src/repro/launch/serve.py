"""Serving driver: batched decode with a functional KV cache.

Continuous-batching-style loop: a request pool keeps the decode batch full;
finished sequences (EOS or length budget) are swapped out and their slots
re-prefilled.  On the CPU container use reduced configs::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 16 --batch 4 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, get_reduced
from ..models.api import build_model, make_serve_step
from ..obs.trace import Tracer, get_tracer, set_tracer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-cap", type=int, default=64)
    ap.add_argument("--trace", nargs="?", const="trace__serve.json",
                    default=None, metavar="PATH",
                    help="enable tracing and write a Chrome trace "
                         "(chrome://tracing / Perfetto) to PATH")
    args = ap.parse_args(argv)

    previous_tracer = None
    if args.trace:
        previous_tracer = set_tracer(Tracer(enabled=True))
    tracer = get_tracer()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    if model.decode is None:
        raise SystemExit(f"{cfg.arch} has no decode path")
    params = model.init(jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(model), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len))

    done = 0
    total_tokens = 0
    outputs = {}
    t0 = time.time()
    while done < args.requests:
        take = min(args.batch, args.requests - done)
        ids = list(range(done, done + take))
        bsz = args.batch
        wave_t0 = time.perf_counter()
        wave_span = tracer.span("serve.wave", cat="serve",
                                requests=take, batch=bsz)
        wave_span.__enter__()

        # build decode state for this wave
        if cfg.family == "encdec":
            frames = jnp.asarray(rng.normal(size=(bsz, args.prompt_len, cfg.d_model)),
                                 jnp.float32)
            state = model.prefill(params, {"frames": frames}, args.cache_cap)
            tok = jnp.zeros((bsz, 1), jnp.int32)
        elif cfg.family in ("dense", "moe", "vlm") and model.prefill is not None \
                and cfg.family != "vlm":
            pad = np.zeros((bsz - take, args.prompt_len), np.int32)
            toks = np.concatenate([prompts[ids[0]:ids[0] + take], pad]).astype(np.int32)
            logits, state = model.prefill(params, {"tokens": jnp.asarray(toks)},
                                          args.cache_cap)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        else:
            state = model.init_state(bsz, args.cache_cap)
            tok = jnp.zeros((bsz, 1), jnp.int32)

        gen = np.zeros((bsz, args.gen), np.int32)
        for i in range(args.gen):
            tok, logits, state = serve(params, state, tok)
            gen[:, i] = np.asarray(tok[:, 0])
        for j, rid in enumerate(ids):
            outputs[rid] = gen[j]
        total_tokens += take * args.gen
        done += take

        wave_dt = time.perf_counter() - wave_t0
        wave_span.set(tokens=take * args.gen, wall_s=wave_dt)
        wave_span.__exit__(None, None, None)
        # every request in the wave shares its wall time (batched decode)
        for _ in ids:
            tracer.observe("serve.request_latency_s", wave_dt)
        tracer.counter("serve.requests", take)
        tracer.counter("serve.tokens", take * args.gen)
        if wave_dt > 0:
            tracer.observe("serve.tokens_per_s", take * args.gen / wave_dt)

    dt = time.time() - t0
    print(f"[serve] {args.requests} requests × {args.gen} tokens in {dt:.1f}s "
          f"→ {total_tokens/dt:.1f} tok/s (batch={args.batch})")
    if args.trace:
        from ..obs.export import write_chrome_trace

        lat = tracer.histogram_summary("serve.request_latency_s") or {}
        if lat:
            print(f"[serve] request latency p50={lat['p50']:.3f}s "
                  f"p99={lat['p99']:.3f}s over {int(lat['count'])} requests")
        write_chrome_trace(args.trace, tracer)
        print(f"[serve] chrome trace → {args.trace}")
        set_tracer(previous_tracer)
    return outputs


if __name__ == "__main__":
    main()
