"""Serving driver: batched decode with a functional KV cache + load shedding.

Continuous-batching-style loop: a request pool keeps the decode batch full;
finished sequences (EOS or length budget) are swapped out and their slots
re-prefilled.  Admission control sits in front of the decode loop:

  * requests enter a **bounded queue** (``--queue-cap``) — arrivals beyond
    the cap are shed immediately (``serve.shed.queue_full``) instead of
    growing an unbounded backlog;
  * each request carries an optional **deadline** (``--deadline-s``); a
    request whose deadline has already passed when its wave forms is shed
    (``serve.shed.deadline``) rather than burning decode steps on an answer
    nobody is waiting for;
  * a wave that keeps failing after bounded retries sheds its requests
    (``serve.shed.error``) and the loop moves on — a poison batch cannot
    wedge the server.

The loop itself (:func:`serve_loop`) is model-free: it drives any
``run_wave(requests) -> {rid: output}`` callable, which is what the chaos
tests exercise with injected slow/failing steps (``serve.step``).

On the CPU container use reduced configs::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 16 --batch 4 --gen 16
"""

from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Iterable, List, Optional

import numpy as np

from ..obs.trace import Tracer, get_tracer, set_tracer
from ..robust.inject import maybe_inject
from ..robust.retry import Deadline, RetryPolicy, call_with_retry

#: bounded retries for a failing decode wave before its requests are shed
WAVE_RETRY = RetryPolicy(max_retries=2, backoff_s=0.01)


@dataclass(frozen=True)
class Request:
    """One generation request: a prompt and an optional deadline."""

    rid: int
    prompt: Any
    deadline: Optional[Deadline] = None
    #: stamped by ``AdmissionQueue.offer`` — queue wait is part of the
    #: request's latency, so ``serve.request_latency_s`` measures from here,
    #: not from when the wave formed
    offered_at: Optional[float] = None


@dataclass
class ShedStats:
    """Why requests were dropped instead of served."""

    queue_full: int = 0
    deadline: int = 0
    error: int = 0

    @property
    def total(self) -> int:
        return self.queue_full + self.deadline + self.error


class AdmissionQueue:
    """Bounded FIFO with deadline-aware dequeue.

    ``offer`` rejects (sheds) when the queue is at capacity; ``take`` skips
    (sheds) requests whose deadline already passed.  Both bump the
    ``serve.shed`` counter plus a per-reason counter, so the ``--trace``
    metrics dump shows not just *that* load was shed but *why*.
    """

    def __init__(self, cap: Optional[int] = None) -> None:
        self.cap = cap
        self.shed = ShedStats()
        self._q: deque = deque()

    def __len__(self) -> int:
        return len(self._q)

    def offer(self, req: Request) -> bool:
        if self.cap is not None and len(self._q) >= self.cap:
            self.shed.queue_full += 1
            tracer = get_tracer()
            tracer.counter("serve.shed")
            tracer.counter("serve.shed.queue_full")
            return False
        if req.offered_at is None:
            req = replace(req, offered_at=time.perf_counter())
        self._q.append(req)
        return True

    def take(self, n: int) -> List[Request]:
        out: List[Request] = []
        while self._q and len(out) < n:
            req = self._q.popleft()
            if req.deadline is not None and req.deadline.expired():
                self._shed_deadline(req)
                continue
            out.append(req)
        return out

    def shed_expired(self, wave: List[Request]) -> List[Request]:
        """Drop already-expired requests from a formed wave (post-delay)."""
        keep: List[Request] = []
        for req in wave:
            if req.deadline is not None and req.deadline.expired():
                self._shed_deadline(req)
            else:
                keep.append(req)
        return keep

    def _shed_deadline(self, req: Request) -> None:
        self.shed.deadline += 1
        tracer = get_tracer()
        tracer.counter("serve.shed")
        tracer.counter("serve.shed.deadline")
        tracer.event("serve.shed.deadline", rid=req.rid)


def serve_loop(requests: Iterable[Request],
               run_wave: Callable[[List[Request]], Dict[int, Any]],
               *,
               batch: int,
               queue_cap: Optional[int] = None,
               deadline_s: Optional[float] = None,
               retry: RetryPolicy = WAVE_RETRY,
               ) -> Dict[int, Any]:
    """Admission-controlled wave loop; returns ``{rid: output}`` for the
    requests that were actually served (shed requests are absent).

    Termination is structural: every admitted request is either served,
    shed on deadline, or shed after bounded wave retries — the loop cannot
    spin on a request it will never finish.
    """
    tracer = get_tracer()
    queue = AdmissionQueue(queue_cap)
    outputs: Dict[int, Any] = {}
    for req in requests:
        if deadline_s is not None and req.deadline is None:
            req = replace(req, deadline=Deadline.after(deadline_s))
        queue.offer(req)

    while len(queue):
        wave = queue.take(batch)
        if not wave:
            continue  # everything taken was past deadline; re-check queue
        wave_t0 = time.perf_counter()
        with tracer.span("serve.wave", cat="serve", requests=len(wave),
                         batch=batch) as wave_span:
            # fault-injection point: "raise" fails the wave (retried, then
            # shed), "delay" slows it so queued deadlines expire
            def attempt() -> Dict[int, Any]:
                maybe_inject("serve.step", batch=len(wave))
                # mutate in place: a request shed on one attempt must not be
                # re-shed (re-counted) by a retry
                wave[:] = queue.shed_expired(wave)
                return run_wave(wave) if wave else {}

            try:
                got = call_with_retry(attempt, retry, name="serve.step")
            except Exception as e:
                queue.shed.error += len(wave)
                tracer.counter("serve.shed", len(wave))
                tracer.counter("serve.shed.error", len(wave))
                tracer.event("serve.wave_failed", requests=len(wave),
                             error=f"{type(e).__name__}: {e}")
                continue
            outputs.update(got)
            wave_dt = time.perf_counter() - wave_t0
            wave_span.set(served=len(got), wall_s=wave_dt)
        # per-request latency = queue wait + shared wave wall time — the
        # offer() stamp makes the p99 under load honest, not just wave time
        done = time.perf_counter()
        for r in wave:
            if r.rid in got:
                tracer.observe("serve.request_latency_s",
                               done - (r.offered_at if r.offered_at is not None
                                       else wave_t0))
        tracer.counter("serve.requests", len(got))
    return outputs


# ---------------------------------------------------------------------------
# streaming: checkpointed incremental consumption of micro-batches
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MicroBatch:
    """One sequenced micro-batch of stream rows.

    ``seq`` is the monotone sequence number the exactly-once protocol keys
    on; ``rows`` are column arrays (≤ the plan's batch capacity, physical
    dtypes); ``watermark`` is the batch's event-time high watermark (any
    monotone-ish clock), consulted by ``stream_loop``'s lag shedding.
    """

    seq: int
    rows: Any                      # Mapping[str, np.ndarray]
    watermark: Optional[float] = None

    @property
    def n_rows(self) -> int:
        cols = dict(self.rows)
        return len(next(iter(cols.values()))) if cols else 0


def microbatches(rows: Any, batch_rows: int, *, watermark_col: Optional[str] = None,
                 start_seq: int = 0) -> List[MicroBatch]:
    """Chop full columns into sequenced micro-batches (tests + benchmarks)."""
    cols = {k: np.asarray(v) for k, v in dict(rows).items()}
    n = len(next(iter(cols.values()))) if cols else 0
    out: List[MicroBatch] = []
    for i, lo in enumerate(range(0, n, batch_rows)):
        chunk = {k: v[lo:lo + batch_rows] for k, v in cols.items()}
        wm = (float(np.max(chunk[watermark_col]))
              if watermark_col and len(chunk[watermark_col]) else None)
        out.append(MicroBatch(seq=start_seq + i, rows=chunk, watermark=wm))
    return out


@dataclass
class StreamStats:
    """What the consumer did — and what it refused to do twice."""

    batches: int = 0          # micro-batches folded into the state
    rows: int = 0             # stream rows folded
    deduped: int = 0          # re-delivered batches skipped by seq number
    snapshots: int = 0
    restores: int = 0
    replayed: int = 0         # batches re-fed after a restore
    failures: int = 0         # process() attempts that raised
    shed_watermark: int = 0   # batches dropped by lag shedding
    paused: int = 0           # intake pauses from backpressure


class StreamConsumer:
    """Drives a stream-target executable over sequenced micro-batches with
    checkpointed exactly-once recovery.

    The carried state is a pure fold: ``state_after(k)`` depends only on
    the set of folded sequence numbers ≤ k.  Exactly-once therefore needs
    (1) **atomic commit** — ``process`` assigns ``self.state`` and
    ``self.committed_seq`` only after the (functional) step succeeds, so a
    mid-batch crash never leaves a half-folded batch; (2) **durable
    snapshots** — every ``snapshot_every`` folded batches the state tree
    goes through :class:`~repro.distributed.checkpoint.CheckpointManager`
    (atomic tmp→rename) with the committed sequence number and watermark in
    the manifest's ``extra``; (3) **dedup on replay** — ``process`` is a
    counted no-op for ``seq ≤ committed_seq``, so re-delivering the suffix
    after :meth:`restore` can never double-count a batch.

    The three ``stream.*`` fault-injection points bracket exactly these
    transitions, which is what the chaos suite kills.
    """

    def __init__(self, compiled: Any, sources: Any, *,
                 checkpoint: Any = None, snapshot_every: int = 8,
                 strict_restore: bool = False) -> None:
        # accept a driver CompileResult or a bare StreamExecutable
        ex = getattr(compiled, "executable", compiled)
        if not hasattr(ex, "init_state"):
            raise TypeError(
                f"StreamConsumer needs a stream-target executable "
                f"(compile(..., target='stream')), got {type(ex).__name__}")
        self.exec = ex
        self.exec.bind(dict(sources))
        self.ckpt = checkpoint
        self.snapshot_every = int(snapshot_every)
        self.strict_restore = strict_restore
        self.stats = StreamStats()
        self.state = self.exec.init_state()
        #: highest sequence number folded into the in-memory state
        self.committed_seq = -1
        #: highest sequence number covered by a durable snapshot
        self.snapshot_seq = -1
        self.watermark: Optional[float] = None

    def inflight(self) -> int:
        """Batches folded but not yet durable — the in-flight window."""
        return self.committed_seq - self.snapshot_seq

    def process(self, batch: MicroBatch) -> bool:
        """Fold one micro-batch; returns False for a deduped redelivery."""
        tracer = get_tracer()
        if batch.seq <= self.committed_seq:
            self.stats.deduped += 1
            tracer.counter("stream.deduped")
            return False
        t0 = time.perf_counter()
        with tracer.span("stream.batch", cat="stream", seq=batch.seq,
                         rows=batch.n_rows):
            # the mid-batch kill: fires before the fold commits, so the
            # batch stays uncommitted and must be re-delivered
            maybe_inject("stream.batch", seq=batch.seq)
            state = self.exec.step(self.state, batch.rows)
            # -- commit point: all-or-nothing from here down ---------------
            self.state = state
            self.committed_seq = batch.seq
            if batch.watermark is not None:
                self.watermark = (batch.watermark if self.watermark is None
                                  else max(self.watermark, batch.watermark))
        self.stats.batches += 1
        self.stats.rows += batch.n_rows
        tracer.counter("stream.batches")
        tracer.counter("stream.rows", batch.n_rows)
        tracer.observe("stream.batch_s", time.perf_counter() - t0)
        tracer.observe("stream.lag_batches", float(self.inflight()))
        if self.inflight() >= self.snapshot_every:
            self.snapshot()
        return True

    def snapshot(self) -> Optional[int]:
        """Publish the state atomically; returns the covered seq (or None)."""
        if self.committed_seq < 0 or self.committed_seq == self.snapshot_seq:
            return None
        tracer = get_tracer()
        t0 = time.perf_counter()
        with tracer.span("stream.snapshot", cat="stream",
                         seq=self.committed_seq):
            # the mid-snapshot kill: fires before the save, and the
            # CheckpointManager's tmp→rename publish means a kill *during*
            # the save leaves the previous snapshot intact either way
            maybe_inject("stream.snapshot", seq=self.committed_seq)
            if self.ckpt is not None:
                self.ckpt.save(self.committed_seq,
                               self.exec.state_to_tree(self.state),
                               extra={"seq": self.committed_seq,
                                      "watermark": self.watermark,
                                      "program": self.exec.program.name})
        self.snapshot_seq = self.committed_seq
        self.stats.snapshots += 1
        tracer.counter("stream.snapshots")
        tracer.observe("stream.snapshot_s", time.perf_counter() - t0)
        return self.snapshot_seq

    def restore(self) -> int:
        """Roll back to the last durable snapshot (or the initial state).

        Returns the restored sequence number; the caller owns re-delivering
        every batch with a higher seq (``process`` dedups the rest).
        """
        tracer = get_tracer()
        with tracer.span("stream.restore", cat="stream"):
            maybe_inject("stream.restore", seq=self.snapshot_seq)
            if self.ckpt is not None and self.ckpt.latest_step() is not None:
                tree, extra = self.ckpt.restore(
                    self.exec.state_to_tree(self.exec.init_state()),
                    strict=self.strict_restore)
                self.state = self.exec.state_from_tree(tree)
                self.committed_seq = int(extra.get("seq", -1))
                wm = extra.get("watermark")
                self.watermark = None if wm is None else float(wm)
            else:
                self.state = self.exec.init_state()
                self.committed_seq = -1
                self.watermark = None
        self.snapshot_seq = self.committed_seq
        self.stats.restores += 1
        tracer.counter("stream.restores")
        return self.committed_seq

    def results(self) -> List[Any]:
        """Finalize the current state (decode, avg arithmetic, order/limit)."""
        return self.exec.finalize(self.state)


def stream_loop(batches: Iterable[MicroBatch], consumer: StreamConsumer, *,
                queue_cap: Optional[int] = None,
                inflight_cap: Optional[int] = None,
                max_lag_s: Optional[float] = None,
                max_recoveries: int = 3) -> List[Any]:
    """`serve_loop` grown into a continuously-running stream consumer.

    Per arriving micro-batch: admission through the same bounded
    :class:`AdmissionQueue`, **backpressure** (when the consumer's
    un-snapshotted window reaches ``inflight_cap``, intake pauses and a
    snapshot drains the window — bounded lag by construction), **watermark
    shedding** (a batch whose event-time watermark lags the consumer's by
    more than ``max_lag_s`` is shed, counted, and never folded), and
    **crash recovery** (a failed fold restores the last snapshot and
    replays the retained uncommitted suffix; dedup-by-seq makes the replay
    idempotent).  Recovery is bounded by ``max_recoveries``; exhaustion
    re-raises — a permanently poisoned stream must not spin forever.

    Returns ``consumer.results()`` — the finalized query answer over every
    batch folded exactly once.
    """
    tracer = get_tracer()
    queue = AdmissionQueue(queue_cap)
    #: delivered but not yet snapshot-durable — the replay suffix.  In a
    #: real deployment this is the upstream log's unacknowledged tail; the
    #: loop retains it so recovery needs nothing beyond the last snapshot.
    pending: Dict[int, MicroBatch] = {}
    recoveries = 0
    source = iter(batches)
    intake_open = True

    def recover(error: BaseException) -> None:
        nonlocal recoveries
        t0 = time.perf_counter()
        while True:
            consumer.stats.failures += 1
            tracer.counter("stream.failures")
            if recoveries >= max_recoveries:
                raise error
            recoveries += 1
            try:
                restored = consumer.restore()
                for seq in sorted(pending):
                    if consumer.process(pending[seq]):
                        consumer.stats.replayed += 1
                        tracer.counter("stream.replayed")
            except Exception as e:
                # a recovery that itself fails (stream.restore injection, or
                # the armed fault firing again mid-replay) — go around,
                # bounded by max_recoveries
                error = e
                continue
            tracer.event("stream.recovered", restored_seq=restored,
                         replayed=len([s for s in pending if s > restored]))
            tracer.observe("stream.recovery_s", time.perf_counter() - t0)
            return

    while True:
        if intake_open:
            if (inflight_cap is not None
                    and consumer.inflight() >= inflight_cap):
                # backpressure: pause intake, drain the window durably
                consumer.stats.paused += 1
                tracer.counter("stream.backpressure.paused")
                try:
                    consumer.snapshot()
                except Exception as e:
                    recover(e)
                continue
            try:
                nb = next(source)
            except StopIteration:
                intake_open = False
            else:
                queue.offer(Request(rid=nb.seq, prompt=nb))
        wave = queue.take(1)
        if not wave:
            if not intake_open:
                break
            continue
        mb: MicroBatch = wave[0].prompt
        if (max_lag_s is not None and mb.watermark is not None
                and consumer.watermark is not None
                and mb.watermark < consumer.watermark - max_lag_s):
            consumer.stats.shed_watermark += 1
            tracer.counter("stream.shed.watermark")
            tracer.event("stream.shed.watermark", seq=mb.seq,
                         watermark=mb.watermark, high=consumer.watermark)
            continue
        pending[mb.seq] = mb
        try:
            consumer.process(mb)
        except Exception as e:
            recover(e)
        if wave[0].offered_at is not None:
            # intake-to-fold latency, the streaming sibling of the serve
            # loop's queue-wait-inclusive request latency
            tracer.observe("stream.queue_wait_s",
                           time.perf_counter() - wave[0].offered_at)
        for seq in [s for s in pending if s <= consumer.snapshot_seq]:
            del pending[seq]
    try:
        consumer.snapshot()   # final barrier: everything folded is durable
    except Exception as e:
        recover(e)
        consumer.snapshot()
    return consumer.results()


def main(argv=None):
    ap = argparse.ArgumentParser()
    from ..configs import ARCH_IDS

    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-cap", type=int, default=64)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline; requests still queued past "
                         "it are shed instead of decoded")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bound the admission queue; arrivals beyond the "
                         "cap are shed immediately")
    ap.add_argument("--trace", nargs="?", const="trace__serve.json",
                    default=None, metavar="PATH",
                    help="enable tracing and write a Chrome trace "
                         "(chrome://tracing / Perfetto) to PATH")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config, get_reduced
    from ..models.api import build_model, make_serve_step

    previous_tracer = None
    if args.trace:
        previous_tracer = set_tracer(Tracer(enabled=True))
    tracer = get_tracer()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    if model.decode is None:
        raise SystemExit(f"{cfg.arch} has no decode path")
    params = model.init(jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(model), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len))
    requests = [Request(rid=i, prompt=prompts[i]) for i in range(args.requests)]

    def run_wave(wave: List[Request]) -> Dict[int, Any]:
        take = len(wave)
        bsz = args.batch
        # waves survive shedding, so request ids need not be contiguous
        toks = np.zeros((bsz, args.prompt_len), np.int32)
        toks[:take] = np.stack([r.prompt for r in wave]).astype(np.int32)

        if cfg.family == "encdec":
            frames = jnp.asarray(
                rng.normal(size=(bsz, args.prompt_len, cfg.d_model)),
                jnp.float32)
            state = model.prefill(params, {"frames": frames}, args.cache_cap)
            tok = jnp.zeros((bsz, 1), jnp.int32)
        elif cfg.family in ("dense", "moe") and model.prefill is not None:
            logits, state = model.prefill(
                params, {"tokens": jnp.asarray(toks)}, args.cache_cap)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        else:
            state = model.init_state(bsz, args.cache_cap)
            tok = jnp.zeros((bsz, 1), jnp.int32)

        gen = np.zeros((bsz, args.gen), np.int32)
        for i in range(args.gen):
            tok, logits, state = serve(params, state, tok)
            gen[:, i] = np.asarray(tok[:, 0])
        tracer.counter("serve.tokens", take * args.gen)
        return {r.rid: gen[j] for j, r in enumerate(wave)}

    t0 = time.time()
    outputs = serve_loop(requests, run_wave, batch=args.batch,
                         queue_cap=args.queue_cap,
                         deadline_s=args.deadline_s)
    dt = time.time() - t0
    total_tokens = len(outputs) * args.gen
    shed = args.requests - len(outputs)
    print(f"[serve] {len(outputs)}/{args.requests} requests × {args.gen} "
          f"tokens in {dt:.1f}s → {total_tokens/max(dt, 1e-9):.1f} tok/s "
          f"(batch={args.batch}, shed={shed})")
    if args.trace:
        from ..obs.export import write_chrome_trace

        lat = tracer.histogram_summary("serve.request_latency_s") or {}
        if lat:
            print(f"[serve] request latency p50={lat['p50']:.3f}s "
                  f"p99={lat['p99']:.3f}s over {int(lat['count'])} requests")
        write_chrome_trace(args.trace, tracer)
        print(f"[serve] chrome trace → {args.trace}")
        set_tracer(previous_tracer)
    return outputs


if __name__ == "__main__":
    main()
