"""Content-addressed on-disk store for compiled-plan metadata.

Executables (jitted callables) cannot be serialized, but everything needed
to *re-plan cheaply* can: the structural fingerprint, the chosen lowering
strategy, the pass records, and the cost estimates.  Spilling that metadata
keyed by the full plan-cache key means a restarted process (serve restarts,
elastic re-planning) skips the costed candidate search and re-lowers
straight down the previously chosen path, and the cost calibration keeps
learning across processes instead of starting cold.

Layout (``<root>/``):
  * ``<keyhash>.json``  — one plan record per (target, epoch, fingerprint,
    options) key, hashed content-address
  * ``<keyhash>.corrupt`` — a quarantined record that failed to parse; it is
    renamed aside on first detection so later runs see a clean miss instead
    of re-parsing and re-warning on the same bytes
  * ``calibration.json`` — the shared :class:`CostCalibration` state

Plan records may carry a ``poison`` list: strategies whose compiled plans
*failed* (verification, backend compile, or execution — see
``repro.robust.fallback``).  :meth:`PlanStore.mark_poison` appends to it and
the driver skips poisoned strategies on replay, so a crashing plan is never
reloaded from cache and re-crashed.

Store I/O is failure-tolerant by design: reads retry transient ``OSError``\\ s
(``repro.robust.retry``), a failed read degrades to a cache miss, and a
failed write is warned about and dropped — persistence is an optimization,
never a correctness dependency.  Writes are atomic (tmp + rename) so
concurrent processes can share a store directory.  The default location
honours ``REPRO_PLAN_STORE`` so serving stacks can turn persistence on
without code changes.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, Iterable, Optional, Set, Tuple, Union

from ..obs.trace import get_tracer, warn_event
from ..robust.inject import InjectedFault, maybe_inject
from ..robust.retry import RetryPolicy, call_with_retry
from .cost import CostCalibration

__all__ = ["PlanStore", "default_store"]

#: transient-I/O policy for store reads/writes: short, bounded, OSError-only
_IO_RETRY = RetryPolicy(max_retries=2, backoff_s=0.01, retry_on=(OSError,))


def _mangle_json(text: str, rule: Any) -> str:
    """Deterministic corruptor for ``store.load``: make the parse fail the
    way a torn write does (truncated bytes), exercising quarantine."""
    return text[: max(len(text) // 2, 1)].rstrip("}")


class PlanStore:
    """Directory-backed, content-addressed plan-metadata store."""

    def __init__(self, root: Union[str, Path]) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # -- paths -------------------------------------------------------------
    def _plan_path(self, key_hash: str) -> Path:
        return self.root / f"{key_hash}.json"

    def _quarantine_path(self, key_hash: str) -> Path:
        return self.root / f"{key_hash}.corrupt"

    @property
    def _calib_path(self) -> Path:
        return self.root / "calibration.json"

    # -- plan records --------------------------------------------------------
    def save_plan(self, key_hash: str, record: Dict[str, Any]) -> None:
        """Persist one plan record; existing poison marks are preserved.

        A failed write is warned about (``plan_store.save_failed``) and
        dropped — the store is an optimization, not a correctness
        dependency, so a full disk must not fail the compile that already
        succeeded.
        """
        record = dict(record)
        record.setdefault("saved_at", time.time())
        if "poison" not in record:
            existing = self._read_raw(self._plan_path(key_hash))
            if existing and existing.get("poison"):
                record["poison"] = existing["poison"]
        try:
            maybe_inject("store.save", key=key_hash)
            call_with_retry(
                lambda: self._atomic_write(self._plan_path(key_hash), record),
                _IO_RETRY, name="store.save")
        except (OSError, InjectedFault) as e:
            get_tracer().counter("plan_store.save_failed")
            warn_event("plan_store.save_failed", key=key_hash,
                       reason=f"{type(e).__name__}: {e}")

    def load_plan(self, key_hash: str) -> Optional[Dict[str, Any]]:
        path = self._plan_path(key_hash)

        def _read() -> Optional[str]:
            try:
                return path.read_text()
            except FileNotFoundError:
                return None

        try:
            text = call_with_retry(_read, _IO_RETRY, name="store.load")
        except OSError as e:
            get_tracer().counter("plan_store.corrupt")
            warn_event("plan_store.corrupt", path=str(path),
                       reason=f"{type(e).__name__}: {e}")
            return None
        if text is None:
            get_tracer().counter("plan_store.miss")
            return None
        try:
            text = maybe_inject("store.load", text, corrupt=_mangle_json,
                                key=key_hash)
            record = json.loads(text)
        except InjectedFault as e:
            # an injected *raise* is a transient read failure, not bad bytes
            # on disk — degrade to a miss without quarantining a good record
            get_tracer().counter("plan_store.corrupt")
            warn_event("plan_store.corrupt", path=str(path), reason=str(e))
            return None
        except ValueError as e:
            # a present-but-unparseable record is data loss, not a miss —
            # surface it, and quarantine the bytes aside so every later run
            # sees a clean miss instead of re-parsing the same corruption
            quarantined = self._quarantine(key_hash)
            get_tracer().counter("plan_store.corrupt")
            warn_event("plan_store.corrupt", path=str(path),
                       quarantined=str(quarantined or ""),
                       reason=f"{type(e).__name__}: {e}")
            return None
        get_tracer().counter("plan_store.hit")
        return record

    def _quarantine(self, key_hash: str) -> Optional[Path]:
        """Rename a corrupt record to ``<key>.corrupt`` (best-effort)."""
        path = self._plan_path(key_hash)
        target = self._quarantine_path(key_hash)
        try:
            os.replace(path, target)
        except OSError:
            return None
        get_tracer().counter("plan_store.quarantined")
        return target

    def __len__(self) -> int:
        return sum(1 for p in self.root.glob("*.json")
                   if p.name != "calibration.json")

    # -- poison plans --------------------------------------------------------
    def mark_poison(self, key_hash: str, strategy: Iterable[Tuple[str, str]],
                    reason: str = "") -> None:
        """Record that ``strategy``'s compiled plan failed for this key.

        The driver consults the mark on replay (memory cache, store replay,
        and costed search all skip poisoned strategies), so a crashing plan
        is quarantined instead of being recompiled and re-crashed.  Uses raw
        reads/writes on purpose: the poison bookkeeping is the safety net
        itself and must not be subject to fault injection.
        """
        path = self._plan_path(key_hash)
        record = self._read_raw(path) or {}
        strat = sorted([str(k), str(v)] for k, v in strategy)
        poison = list(record.get("poison") or ())
        if strat not in [p.get("strategy") for p in poison]:
            poison.append({"strategy": strat, "reason": reason,
                           "at": time.time()})
        record["poison"] = poison
        try:
            self._atomic_write(path, record)
        except OSError as e:
            warn_event("plan_store.save_failed", key=key_hash,
                       reason=f"{type(e).__name__}: {e}")
            return
        get_tracer().counter("plan_store.poison")

    @staticmethod
    def poisoned_strategies(record: Optional[Dict[str, Any]],
                            ) -> Set[Tuple[Tuple[str, str], ...]]:
        """The set of (sorted) strategy tuples marked poison in a record."""
        out: Set[Tuple[Tuple[str, str], ...]] = set()
        for p in (record or {}).get("poison") or ():
            out.add(tuple(sorted((str(k), str(v))
                                 for k, v in p.get("strategy") or ())))
        return out

    # -- calibration ---------------------------------------------------------
    def load_calibration(self) -> CostCalibration:
        try:
            return CostCalibration.from_dict(
                json.loads(self._calib_path.read_text()))
        except FileNotFoundError:
            return CostCalibration()
        except (OSError, ValueError) as e:
            get_tracer().counter("plan_store.corrupt")
            warn_event("plan_store.corrupt", path=str(self._calib_path),
                       reason=f"{type(e).__name__}: {e}")
            return CostCalibration()

    def save_calibration(self, calib: CostCalibration) -> None:
        self._atomic_write(self._calib_path, calib.to_dict())

    # -- internals -----------------------------------------------------------
    @staticmethod
    def _read_raw(path: Path) -> Optional[Dict[str, Any]]:
        """Best-effort read outside the injection/warning machinery."""
        try:
            got = json.loads(path.read_text())
            return got if isinstance(got, dict) else None
        except (OSError, ValueError):
            return None

    def _atomic_write(self, path: Path, payload: Dict[str, Any]) -> None:
        fd, tmp = tempfile.mkstemp(dir=str(self.root), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, default=str)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


def default_store() -> Optional[PlanStore]:
    """The environment-configured store (``REPRO_PLAN_STORE``), if any."""
    root = os.environ.get("REPRO_PLAN_STORE")
    return PlanStore(root) if root else None
