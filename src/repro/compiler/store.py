"""Content-addressed on-disk store for compiled-plan metadata.

Executables (jitted callables) cannot be serialized, but everything needed
to *re-plan cheaply* can: the structural fingerprint, the chosen lowering
strategy, the pass records, and the cost estimates.  Spilling that metadata
keyed by the full plan-cache key means a restarted process (serve restarts,
elastic re-planning) skips the costed candidate search and re-lowers
straight down the previously chosen path, and the cost calibration keeps
learning across processes instead of starting cold.

Layout (``<root>/``):
  * ``<keyhash>.json``  — one plan record per (target, epoch, fingerprint,
    options) key, hashed content-address
  * ``calibration.json`` — the shared :class:`CostCalibration` state

Writes are atomic (tmp + rename) so concurrent processes can share a store
directory.  The default location honours ``REPRO_PLAN_STORE`` so serving
stacks can turn persistence on without code changes.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, Optional, Union

from ..obs.trace import get_tracer, warn_event
from .cost import CostCalibration

__all__ = ["PlanStore", "default_store"]


class PlanStore:
    """Directory-backed, content-addressed plan-metadata store."""

    def __init__(self, root: Union[str, Path]) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # -- paths -------------------------------------------------------------
    def _plan_path(self, key_hash: str) -> Path:
        return self.root / f"{key_hash}.json"

    @property
    def _calib_path(self) -> Path:
        return self.root / "calibration.json"

    # -- plan records --------------------------------------------------------
    def save_plan(self, key_hash: str, record: Dict[str, Any]) -> None:
        record = dict(record)
        record.setdefault("saved_at", time.time())
        self._atomic_write(self._plan_path(key_hash), record)

    def load_plan(self, key_hash: str) -> Optional[Dict[str, Any]]:
        path = self._plan_path(key_hash)
        try:
            record = json.loads(path.read_text())
        except FileNotFoundError:
            get_tracer().counter("plan_store.miss")
            return None
        except (OSError, ValueError) as e:
            # a present-but-unreadable record is data loss, not a miss —
            # surface it instead of silently re-planning from scratch
            get_tracer().counter("plan_store.corrupt")
            warn_event("plan_store.corrupt", path=str(path),
                       reason=f"{type(e).__name__}: {e}")
            return None
        get_tracer().counter("plan_store.hit")
        return record

    def __len__(self) -> int:
        return sum(1 for p in self.root.glob("*.json")
                   if p.name != "calibration.json")

    # -- calibration ---------------------------------------------------------
    def load_calibration(self) -> CostCalibration:
        try:
            return CostCalibration.from_dict(
                json.loads(self._calib_path.read_text()))
        except FileNotFoundError:
            return CostCalibration()
        except (OSError, ValueError) as e:
            get_tracer().counter("plan_store.corrupt")
            warn_event("plan_store.corrupt", path=str(self._calib_path),
                       reason=f"{type(e).__name__}: {e}")
            return CostCalibration()

    def save_calibration(self, calib: CostCalibration) -> None:
        self._atomic_write(self._calib_path, calib.to_dict())

    # -- internals -----------------------------------------------------------
    def _atomic_write(self, path: Path, payload: Dict[str, Any]) -> None:
        fd, tmp = tempfile.mkstemp(dir=str(self.root), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, default=str)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


def default_store() -> Optional[PlanStore]:
    """The environment-configured store (``REPRO_PLAN_STORE``), if any."""
    root = os.environ.get("REPRO_PLAN_STORE")
    return PlanStore(root) if root else None
