"""Unified compilation driver: one entry point for every backend.

``compile(program, target="spmd", parallel=8)`` looks up the registered
:class:`~repro.compiler.targets.Target`, consults the structural plan cache
(keyed by the alpha-invariant program fingerprint + the option cache key),
runs the target's declarative lowering path with per-pass instrumentation
(wall time + IR-size delta), hands the final program to the backend, and
caches the resulting :class:`CompileResult`.

Every frontend routes here: ``Context.compile`` (dataflow + SQL frontends)
and ``ElasticExecutor.plan`` (multipod) contain no inline pass lists, and
the tensor frontend's planning rewrites run through :func:`run_passes` so
they are instrumented the same way.
"""

from __future__ import annotations

import itertools
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from ..core.program import Program
from ..core.verify import verify
from ..obs.trace import get_tracer
from ..robust.admission import AdmissionError, admit, default_budget
from ..robust.fallback import degrade, fallback_ladder
from ..robust.inject import InjectedFault, maybe_inject
from .cost import CALIBRATION, Candidate, PlanDecision, estimate_cost
from .fingerprint import fingerprint, fingerprint_value
from .stats import Statistics
from .targets import (Choice, CompileOptions, StrategyStage, get_target,
                      target_epoch)

__all__ = [
    "compile", "run_passes", "program_size",
    "CompileResult", "PassRecord", "PlanCache", "PLAN_CACHE",
    "enable_auto_replan", "disable_auto_replan",
]


# ---------------------------------------------------------------------------
# instrumentation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PassRecord:
    """One pass execution: where it ran, how long, and what it did to the IR."""

    stage: str
    name: str
    wall_s: float
    size_before: int
    size_after: int

    @property
    def delta(self) -> int:
        return self.size_after - self.size_before


def program_size(program: Program) -> int:
    """Total instruction count, including nested programs."""
    return sum(len(p.body) for p in program.walk())


def run_passes(program: Program, passes: Sequence[Any], stage: str = "pipeline",
               records: Optional[List[PassRecord]] = None,
               check: bool = True) -> Program:
    """Apply passes in order, timing each and verifying between them.

    The shared instrumented runner: the driver uses it per stage, and
    frontends with their own planning rewrites (tensor) call it directly so
    their passes are measured identically.
    """
    tracer = get_tracer()
    for p in passes:
        before = program_size(program)
        t0 = time.perf_counter()
        with tracer.span(p.name, cat="compile.pass", stage=stage) as sp:
            out = p.apply(program)
        wall = time.perf_counter() - t0
        out = maybe_inject("driver.pass", out, corrupt=_truncate_program,
                           pass_name=p.name, stage=stage)
        after = program_size(out)
        sp.set(size_before=before, size_after=after)
        if check:
            try:
                verify(out, allow_unknown_ops=True)
            except Exception as e:
                raise AssertionError(
                    f"pass {p.name!r} broke the program:\n{out.render()}"
                ) from e
        if records is not None:
            records.append(PassRecord(stage, p.name, wall, before, after))
        program = out
    return program


def _truncate_program(program: Program, rule: Any) -> Program:
    """``driver.pass`` corruptor: drop the last instruction so verification
    fails the way a buggy rewrite does (a result register goes undefined)."""
    if not program.body:
        raise InjectedFault("injected driver.pass corruption on empty program")
    return replace(program, body=program.body[:-1])


# ---------------------------------------------------------------------------
# compile results
# ---------------------------------------------------------------------------


@dataclass
class CompileResult:
    """A compiled plan: callable executable + full compilation provenance."""

    target: str
    source: Program            # frontend program as handed to the driver
    program: Program           # final lowered program the backend consumed
    executable: Any            # backend-compiled callable
    records: Tuple[PassRecord, ...]
    fingerprint: str
    backend_s: float = 0.0
    cache_hit: bool = False
    #: (choice-name, variant) pairs the lowering actually used
    strategy: Tuple[Tuple[str, str], ...] = ()
    #: costed-search provenance (None for fixed-path compiles)
    decision: Optional[PlanDecision] = None
    #: the catalog statistics the plan was costed under (estimate side of
    #: the estimate-vs-actual join)
    stats: Optional[Statistics] = None
    #: where this result came from: "miss" (freshly compiled),
    #: "memory" (plan-cache hit), "store" (plan-store strategy replay)
    cache_source: str = "miss"
    #: latest traced execution's estimate-vs-actual profile
    #: (:class:`~repro.obs.feedback.RuntimeProfile`; None until a traced run)
    profile: Optional[Any] = None
    #: fallback-ladder rungs this plan stepped down (compile- or exec-time);
    #: empty means the cost-chosen plan is the plan that runs
    degraded: Tuple[str, ...] = ()
    #: resource-admission estimate (only computed when a byte budget is set)
    resources: Optional[Any] = None
    #: one-shot execution guard armed by the driver: catches the *first*
    #: execution's failure and walks the fallback ladder (jit traces lazily,
    #: so shard/trace-time faults surface here, not at backend compile).
    #: Disarmed after the first successful call — the steady-state hot path
    #: pays one attribute check.
    _guard: Optional[Any] = None
    #: adaptive re-plan closure armed by the driver: when auto-replan is
    #: enabled (:func:`enable_auto_replan`) and a traced execution's worst
    #: cardinality miss puts this plan over the threshold, the closure
    #: recompiles under the feedback catalog's observed statistics and
    #: splices the new plan in (one-shot per arming)
    _replan: Optional[Any] = None

    def __call__(self, sources: Any = None, *args: Any) -> Any:
        guard = self._guard
        if guard is None:
            return self._dispatch(sources, *args)
        try:
            out = self._dispatch(sources, *args)
        except Exception as e:
            out = guard(self, e, sources, args)
        self._guard = None
        return out

    def _dispatch(self, sources: Any = None, *args: Any) -> Any:
        maybe_inject("backend.execute", target=self.target,
                     program=self.source.name)
        tracer = get_tracer()
        runner = getattr(self.executable, "run_traced", None)
        if not tracer.enabled or runner is None:
            # the hot path: plain dispatch, no span, no profile bookkeeping
            return self.executable(sources, *args)

        from ..obs import feedback as fb

        t0 = time.perf_counter()
        with tracer.span(f"execute:{self.source.name}", cat="execute",
                         target=self.target,
                         fingerprint=self.fingerprint[:12]) as sp:
            outs, cards, walls = runner(sources, *args)
        wall = time.perf_counter() - t0
        profile = fb.build_profile(self, cards, wall, wall_by_key=walls)
        sp.set(rows_measured=len(profile.observations))
        if not getattr(self.executable, "emits_op_spans", False):
            # jitted backends can't time ops inside the compiled body;
            # record zero-duration cardinality annotations instead
            for o in profile.observations:
                tracer.record_complete(
                    o.opcode, cat="execute.op", t0=t0, dur_s=0.0,
                    register=o.register, rows_out=o.rows_out,
                    rows_in=o.rows_in, est_rows=o.est_rows,
                    rel_miss=o.rel_miss, table=o.table)
        self.profile = profile
        fb.FEEDBACK.record(profile)
        thresh = _AUTO_REPLAN[0]
        if (thresh is not None and self._replan is not None
                and any(f == self.fingerprint for f, _ in
                        fb.FEEDBACK.plans_over_threshold(thresh))):
            replan, self._replan = self._replan, None
            replan(self, profile)
        return outs

    @property
    def total_s(self) -> float:
        return self.backend_s + sum(r.wall_s for r in self.records)

    def explain(self) -> str:
        """Per-pass wall time, IR-size deltas, the plan decision, and —
        after a traced execution — the estimated-vs-actual cardinalities."""
        head = (f"compile[{self.target}] {self.source.name}: "
                + ("cache hit" if self.cache_hit
                   else f"{self.total_s * 1e3:.2f} ms")
                + f" (fingerprint {self.fingerprint[:12]})"
                + f" cache={'hit' if self.cache_hit else 'miss'}"
                + f" source={self.cache_source}")
        if self.strategy:
            head += (" strategy "
                     + ", ".join(f"{k}={v}" for k, v in self.strategy))
        if self.degraded:
            head += " DEGRADED via " + " → ".join(self.degraded)
        lines = [head,
                 "| stage | pass | wall ms | IR size | Δ |",
                 "|---|---|---:|---:|---:|"]
        for r in self.records:
            lines.append(f"| {r.stage} | {r.name} | {r.wall_s * 1e3:.3f} "
                         f"| {r.size_after} | {r.delta:+d} |")
        lines.append(f"| backend | {self.target} | {self.backend_s * 1e3:.3f} "
                     f"| {program_size(self.program)} | +0 |")
        if self.decision is not None:
            lines.append(self.decision.render())
        if self.profile is not None:
            lines.append(self.profile.render())
        return "\n".join(lines)

    def explain_records(self) -> List[Dict[str, Any]]:
        """The same data as :meth:`explain`, as JSON-ready records."""
        size = program_size(self.program)
        recs = [
            {"stage": r.stage, "pass": r.name, "wall_s": r.wall_s,
             "size_before": r.size_before, "size_after": r.size_after}
            for r in self.records
        ]
        recs.append({"stage": "backend", "pass": self.target,
                     "wall_s": self.backend_s,
                     "size_before": size, "size_after": size})
        return recs

    def metrics(self) -> Dict[str, Any]:
        """Structured metrics: compile provenance, runtime profile, and the
        active tracer's counters/histograms, in one JSON-ready dict."""
        out: Dict[str, Any] = {
            "target": self.target,
            "program": self.source.name,
            "fingerprint": self.fingerprint,
            "cache": "hit" if self.cache_hit else "miss",
            "cache_source": self.cache_source,
            "strategy": dict(self.strategy),
            "degraded": list(self.degraded),
            "compile": {"total_s": self.total_s,
                        "backend_s": self.backend_s,
                        "passes": self.explain_records()},
        }
        if self.resources is not None:
            out["resources"] = {"peak_bytes": self.resources.peak_bytes,
                                "peak_site": self.resources.peak_site}
        if self.decision is not None:
            out["decision"] = self.decision.records()
        if self.profile is not None:
            out["runtime"] = {
                "wall_s": self.profile.wall_s,
                "est_cost": self.profile.est_cost,
                "worst_miss": self.profile.worst_miss,
                "operators": self.profile.records(),
            }
        out["tracer"] = get_tracer().metrics()
        return out


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


class PlanCache:
    """LRU cache of CompileResults keyed by (target, fingerprint, options)."""

    def __init__(self, capacity: int = 256) -> None:
        self.capacity = capacity
        self._entries: "OrderedDict[Tuple, CompileResult]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def lookup(self, key: Tuple) -> Optional[CompileResult]:
        got = self._entries.get(key)
        if got is None:
            self.misses += 1
            get_tracer().counter("plan_cache.miss")
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        get_tracer().counter("plan_cache.hit")
        return got

    def store(self, key: Tuple, result: CompileResult) -> None:
        self._entries[key] = result
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
            get_tracer().counter("plan_cache.evict")

    def drop(self, key: Tuple) -> None:
        """Invalidate one entry (a cached plan whose execution crashed must
        not be served again — see the driver's fallback chain)."""
        self._entries.pop(key, None)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._entries)}


#: process-wide default cache — repeated compiles of the same frontend
#: program (serve paths, elastic re-planning) are near-free
PLAN_CACHE = PlanCache()


# ---------------------------------------------------------------------------
# adaptive re-planning (the ROADMAP auto-replan hook)
# ---------------------------------------------------------------------------


#: the armed auto-replan threshold (relative worst cardinality miss);
#: ``None`` → off.  A one-element list so closures see updates.
_AUTO_REPLAN: List[Optional[float]] = [None]


def enable_auto_replan(threshold: float = 1.0) -> None:
    """Arm adaptive re-planning for traced executions.

    After each traced run the driver asks the feedback catalog whether the
    plan's worst cardinality miss exceeds ``threshold``
    (``FEEDBACK.plans_over_threshold``); if so, it recompiles the program
    under ``Statistics.with_observed_rows`` (the measured base-table
    cardinalities) and swaps the cached plan — the manual replan recipe
    from the observability docs, made automatic.  Streaming consumers lean
    on this: per-micro-batch cardinality drift is their common case.
    """
    _AUTO_REPLAN[0] = float(threshold)


def disable_auto_replan() -> None:
    _AUTO_REPLAN[0] = None


def _make_replan(program: Program, tgt: Any, opts: CompileOptions,
                 check: bool, fp: str, plan_cache: Optional[PlanCache],
                 key: Tuple):
    """The re-plan closure armed on cached CompileResults (see
    :func:`enable_auto_replan`); mirrors the exec guard's splice-and-store
    so the caller's handle and the cache both serve the corrected plan."""

    def replan(result: CompileResult, profile: Any) -> None:
        from ..core.passes.lower_vec import Catalog
        from ..obs import feedback as fb

        tracer = get_tracer()
        observed = fb.FEEDBACK.observed_statistics(opts.stats())
        cat = opts.catalog
        new_cat = (replace(cat, stats=observed) if cat is not None
                   else Catalog(stats=observed))
        opts2 = replace(opts, catalog=new_cat, strategy=None,
                        optimize="cost" if tgt.choices() else opts.optimize)
        try:
            nxt = _build_plan(program, tgt, opts2, check, None, fp, None,
                              frozenset(), None, None, {})
        except Exception as e:
            from ..obs.trace import warn_event
            tracer.counter("driver.replan.failed")
            warn_event("replan.failed", program=program.name,
                       target=tgt.name, error=f"{type(e).__name__}: {e}")
            return
        tracer.counter("driver.replan")
        tracer.event("driver.replan", program=program.name, target=tgt.name,
                     worst_miss=profile.worst_miss,
                     old_strategy=dict(result.strategy),
                     new_strategy=dict(nxt.strategy))
        result.target = nxt.target
        result.program = nxt.program
        result.executable = nxt.executable
        result.strategy = nxt.strategy
        result.decision = nxt.decision
        result.stats = nxt.stats
        if plan_cache is not None:
            plan_cache.store(key, replace(result, cache_hit=False,
                                          cache_source="miss",
                                          _guard=None, _replan=None))

    return replan


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------


def _lower_with_strategy(program: Program, tgt: Any, opts: CompileOptions,
                         chosen: Dict[str, str], check: bool,
                         ) -> Tuple[Program, List[PassRecord]]:
    """Run the target's lowering path with each Choice bound to a variant."""
    records: List[PassRecord] = []
    lowered = program
    seen: set = set()
    for stage in tgt.lowering_path:
        if isinstance(stage, Choice):
            stage = stage.variant(chosen.get(stage.name, stage.default))
            if id(stage) in seen:
                continue  # several Choices may share one StrategyStage
            seen.add(id(stage))
        passes = (stage.build(opts, chosen) if isinstance(stage, StrategyStage)
                  else stage.build(opts))
        lowered = run_passes(lowered, passes, stage=stage.name,
                             records=records, check=check)
    return lowered, records


def _choose_strategy(program: Program, tgt: Any, opts: CompileOptions,
                     check: bool, stored: Optional[Dict[str, Any]],
                     poison: Any = frozenset(),
                     ) -> Tuple[Dict[str, str], Program, List[PassRecord],
                                Optional[PlanDecision]]:
    """Cost-based plan selection: enumerate the target's Choice points,
    lower each candidate, cost the final programs, keep the cheapest.

    A plan-store record from a previous process short-circuits the search:
    the recorded winner is re-lowered directly (source="store") — unless
    that strategy is marked poison (its compiled plan crashed before), in
    which case the search runs again over the surviving candidates.
    Candidates over the admission byte budget are dropped the same way.
    """
    choices = tgt.choices()
    forced = dict(opts.strategy or ())
    stats = opts.stats()
    budget = (opts.memory_budget if opts.memory_budget is not None
              else default_budget())

    if stored is not None and stored.get("strategy"):
        chosen = {str(k): str(v) for k, v in stored["strategy"]}
        chosen.update(forced)
        if tuple(sorted(chosen.items())) in poison:
            get_tracer().counter("robust.fallback.poison_skip")
        else:
            t0 = time.perf_counter()
            lowered, records = _lower_with_strategy(program, tgt, opts,
                                                    chosen, check)
            lower_s = time.perf_counter() - t0
            cand = Candidate(strategy=tuple(sorted(chosen.items())),
                             est_cost=estimate_cost(lowered, stats),
                             size=program_size(lowered), lower_s=lower_s)
            decision = PlanDecision(
                candidates=(cand,), chosen=0, source="store",
                est_seconds=CALIBRATION.seconds(cand.est_cost))
            return chosen, lowered, records, decision

    axes = []
    for c in choices:
        labels = (forced[c.name],) if c.name in forced else c.labels(opts)
        axes.append([(c.name, label) for label in labels])

    candidates: List[Candidate] = []
    lowerings: List[Tuple[Program, List[PassRecord]]] = []
    over_budget: List[Tuple[Any, Any]] = []
    for combo in itertools.product(*axes) if axes else [()]:
        chosen = dict(combo)
        strat = tuple(sorted(chosen.items()))
        if strat in poison:
            get_tracer().counter("robust.fallback.poison_skip")
            continue
        t0 = time.perf_counter()
        lowered, records = _lower_with_strategy(program, tgt, opts, chosen,
                                                check)
        lower_s = time.perf_counter() - t0
        if budget is not None:
            try:
                admit(lowered, budget, name=program.name)
            except AdmissionError as e:
                over_budget.append((strat, e))
                continue
        candidates.append(Candidate(
            strategy=strat,
            est_cost=estimate_cost(lowered, stats),
            size=program_size(lowered), lower_s=lower_s))
        lowerings.append((lowered, records))

    if not candidates:
        if over_budget:
            raise over_budget[0][1]
        raise RuntimeError(
            f"no admissible candidate plan for {program.name!r} on target "
            f"{tgt.name!r}: every strategy is poisoned "
            f"({sorted(poison)})")

    best = min(range(len(candidates)), key=lambda i: candidates[i].est_cost)
    decision = PlanDecision(
        candidates=tuple(candidates), chosen=best, source="search",
        est_seconds=CALIBRATION.seconds(candidates[best].est_cost))
    lowered, records = lowerings[best]
    return dict(candidates[best].strategy), lowered, records, decision


def compile(program: Program, target: str = "local", *,
            parallel: Optional[int] = None,
            catalog: Any = None,
            use_kernels: bool = False,
            fuse: bool = True,
            axis: str = "workers",
            mesh: Any = None,
            jit: bool = True,
            collectives: bool = True,
            parallelize_targets: Optional[Sequence[str]] = None,
            optimize: Optional[str] = None,
            strategy: Any = None,
            cache: Union[None, bool, PlanCache] = None,
            store: Any = None,
            backend: Any = None,
            check: bool = True,
            memory_budget: Optional[int] = None,
            guard: bool = True,
            stream_table: Optional[str] = None,
            batch_rows: Optional[int] = None) -> CompileResult:
    """Compile a frontend CVM program for a registered target.

    ``cache``: ``None``/``True`` → the process-wide :data:`PLAN_CACHE`;
    ``False`` → no caching; a :class:`PlanCache` → that cache.  An explicit
    ``backend`` instance overrides the target's factory and bypasses the
    cache (its configuration is invisible to the key).

    ``optimize="cost"`` turns the fixed lowering path into a costed search
    over the target's declared strategy :class:`~repro.compiler.targets.Choice`
    points; ``strategy={"grouped-recombine": "exchange", ...}`` forces
    specific variants.  ``store`` (a :class:`~repro.compiler.store.PlanStore`
    or path) persists plan metadata across processes; ``None`` falls back to
    the ``REPRO_PLAN_STORE`` environment default, ``False`` disables.

    ``memory_budget`` (bytes; default ``REPRO_MEM_BUDGET_BYTES``) turns on
    resource admission: plans whose estimated peak working set exceeds the
    budget are degraded or rejected before they can OOM the device.

    ``guard`` (default on) arms the fallback chain: when the chosen plan
    fails verification, lowering, backend compile, admission, or its first
    execution, the driver retries progressively safer strategies and
    finally the interp target, emitting a ``DegradedWarning`` instead of
    failing the query (see docs/robustness.md).  Invalid *inputs* — unknown
    targets, malformed strategies, impossible meshes — still raise.

    ``stream_table``/``batch_rows`` are for streaming targets
    (``target="stream"``): the named table is delivered as micro-batches
    of ``batch_rows`` rows and the executable folds them incrementally
    (see docs/streaming.md).
    """
    tracer = get_tracer()
    if not tracer.enabled:
        return _compile_impl(
            program, target, parallel=parallel, catalog=catalog,
            use_kernels=use_kernels, fuse=fuse, axis=axis, mesh=mesh, jit=jit,
            collectives=collectives, parallelize_targets=parallelize_targets,
            optimize=optimize, strategy=strategy, cache=cache, store=store,
            backend=backend, check=check, memory_budget=memory_budget,
            guard=guard, stream_table=stream_table, batch_rows=batch_rows)
    with tracer.span(f"compile:{program.name}", cat="compile",
                     target=target) as sp:
        result = _compile_impl(
            program, target, parallel=parallel, catalog=catalog,
            use_kernels=use_kernels, fuse=fuse, axis=axis, mesh=mesh, jit=jit,
            collectives=collectives, parallelize_targets=parallelize_targets,
            optimize=optimize, strategy=strategy, cache=cache, store=store,
            backend=backend, check=check, memory_budget=memory_budget,
            guard=guard, stream_table=stream_table, batch_rows=batch_rows)
        sp.set(cache="hit" if result.cache_hit else "miss",
               source=result.cache_source,
               fingerprint=result.fingerprint[:12])
        if result.degraded:
            sp.set(degraded=list(result.degraded))
    return result


class _PoisonedPlan(RuntimeError):
    """The requested strategy is quarantined: its compiled plan crashed
    before (plan-store poison mark) and must not be replayed from cache."""


def _compile_impl(program: Program, target: str = "local", *,
                  parallel: Optional[int] = None,
                  catalog: Any = None,
                  use_kernels: bool = False,
                  fuse: bool = True,
                  axis: str = "workers",
                  mesh: Any = None,
                  jit: bool = True,
                  collectives: bool = True,
                  parallelize_targets: Optional[Sequence[str]] = None,
                  optimize: Optional[str] = None,
                  strategy: Any = None,
                  cache: Union[None, bool, PlanCache] = None,
                  store: Any = None,
                  backend: Any = None,
                  check: bool = True,
                  memory_budget: Optional[int] = None,
                  guard: bool = True,
                  stream_table: Optional[str] = None,
                  batch_rows: Optional[int] = None) -> CompileResult:
    if optimize not in (None, "cost"):
        raise ValueError(f"unknown optimize mode {optimize!r}; "
                         "expected None or 'cost'")
    tgt = get_target(target)
    strat = _normalize_strategy(strategy, tgt)
    if getattr(tgt, "streaming", False):
        if not stream_table:
            raise ValueError(
                f"target {tgt.name!r} is streaming: pass stream_table=... "
                "(the table delivered as micro-batches)")
        batch_rows = int(batch_rows or 256)  # normalized → stable cache key
        if batch_rows <= 0:
            raise ValueError(f"batch_rows must be positive, got {batch_rows}")
    elif stream_table is not None or batch_rows is not None:
        raise ValueError(
            f"stream_table/batch_rows only apply to streaming targets; "
            f"{tgt.name!r} is not one")
    opts = CompileOptions(
        parallel=parallel, use_kernels=use_kernels, fuse=fuse, axis=axis,
        jit=jit, collectives=collectives, catalog=catalog, mesh=mesh,
        parallelize_targets=(tuple(sorted(parallelize_targets))
                             if parallelize_targets else None),
        optimize=optimize, strategy=strat,
        memory_budget=memory_budget,
        stream_table=stream_table, batch_rows=batch_rows,
    )
    _check_parallel_divides(program, opts)
    _check_mesh_available(tgt, opts)

    fp = fingerprint(program)
    if cache is False:
        plan_cache: Optional[PlanCache] = None
    elif cache is None or cache is True:
        plan_cache = PLAN_CACHE
    else:
        plan_cache = cache
    use_cache = plan_cache is not None and backend is None

    key = (tgt.name, target_epoch(tgt.name), fp, opts.cache_key())
    if use_cache:
        hit = plan_cache.lookup(key)
        if hit is not None:
            return replace(hit, cache_hit=True, cache_source="memory")

    plan_store = _resolve_store(store)
    store_key: Optional[str] = None
    stored: Optional[Dict[str, Any]] = None
    if plan_store is not None:
        store_key = fingerprint_value(key)
        _seed_calibration(plan_store)
        stored = plan_store.load_plan(store_key)
    poison = (plan_store.poisoned_strategies(stored)
              if plan_store is not None else set())

    attempt: Dict[str, Any] = {}
    try:
        result = _build_plan(program, tgt, opts, check, backend, fp, stored,
                             poison, plan_store, store_key, attempt)
    except Exception as e:
        if not guard:
            raise
        result = _fallback_compile(program, tgt, opts, check, backend, fp, e,
                                   attempt, plan_store, store_key, poison)
    if use_cache:
        plan_cache.store(key, result)
    if guard:
        result._guard = _make_exec_guard(
            program, tgt, opts, check, backend, fp, plan_store, store_key,
            plan_cache if use_cache else None, key)
    if backend is None:
        result._replan = _make_replan(
            program, tgt, opts, check, fp,
            plan_cache if use_cache else None, key)
    return result


def _build_plan(program: Program, tgt: Any, opts: CompileOptions, check: bool,
                backend: Any, fp: str, stored: Optional[Dict[str, Any]],
                poison: Any, plan_store: Any, store_key: Optional[str],
                attempt: Dict[str, Any]) -> CompileResult:
    """One compile attempt down a fixed or costed path.

    ``attempt`` is filled with the chosen strategy as soon as it is known,
    so the fallback chain can poison the right plan when this raises.
    """
    decision: Optional[PlanDecision] = None
    budget = (opts.memory_budget if opts.memory_budget is not None
              else default_budget())
    if opts.optimize == "cost" and tgt.choices():
        chosen, lowered, records, decision = _choose_strategy(
            program, tgt, opts, check, stored, poison)
        attempt["strategy"] = tuple(sorted(chosen.items()))
    else:
        chosen = dict(opts.strategy or ())
        for c in tgt.choices():
            chosen.setdefault(c.name, c.default)
        strat_t = tuple(sorted(chosen.items()))
        attempt["strategy"] = strat_t
        if tgt.choices() and strat_t in poison:
            get_tracer().counter("robust.fallback.poison_skip")
            raise _PoisonedPlan(
                f"strategy {dict(strat_t)} for {program.name!r} is "
                f"quarantined (a previous compiled plan crashed)")
        lowered, records = _lower_with_strategy(program, tgt, opts, chosen,
                                                check)

    _check_flavors(lowered, tgt)

    resources = None
    if budget is not None:
        # the costed search already admitted its winner; fixed paths and
        # store replays are admitted here, before the backend allocates
        resources = admit(lowered, budget, name=program.name)

    be = backend if backend is not None else tgt.make_backend(opts)
    maybe_inject("backend.compile", target=tgt.name, program=program.name)
    t0 = time.perf_counter()
    with get_tracer().span(f"backend:{tgt.name}", cat="compile.backend"):
        executable = be.compile(lowered)
    backend_s = time.perf_counter() - t0

    if decision is not None:
        measured = backend_s + sum(r.wall_s for r in records)
        CALIBRATION.update(decision.winner.est_cost, measured)
        decision = replace(decision, measured_s=measured)

    result = CompileResult(
        target=tgt.name,
        source=program,
        program=getattr(executable, "program", lowered),
        executable=executable,
        records=tuple(records),
        fingerprint=fp,
        backend_s=backend_s,
        strategy=tuple(sorted(chosen.items())),
        decision=decision,
        stats=opts.stats(),
        cache_source=("store" if decision is not None
                      and decision.source == "store" else "miss"),
        resources=resources,
    )
    if plan_store is not None and store_key is not None and backend is None:
        plan_store.save_plan(store_key, {
            "target": tgt.name,
            "fingerprint": fp,
            "strategy": sorted(chosen.items()),
            "optimize": opts.optimize,
            "records": result.explain_records(),
            "decision": decision.records() if decision is not None else None,
            "backend_s": backend_s,
        })
        # only persist calibration this compile actually updated — a plain
        # fixed-path compile must not clobber another process's learned scale
        if decision is not None and CALIBRATION.n:
            plan_store.save_calibration(CALIBRATION)
    return result


# ---------------------------------------------------------------------------
# the fallback chain (see docs/robustness.md)
# ---------------------------------------------------------------------------


def _mark_poison(plan_store: Any, store_key: Optional[str],
                 strategy: Any, reason: str) -> None:
    if plan_store is None or not store_key or not strategy:
        return
    plan_store.mark_poison(store_key, tuple(strategy), reason=reason)


def _fallback_compile(program: Program, tgt: Any, opts: CompileOptions,
                      check: bool, backend: Any, fp: str,
                      error: BaseException, attempt: Dict[str, Any],
                      plan_store: Any, store_key: Optional[str],
                      poison: Any) -> CompileResult:
    """Walk the fallback ladder after a compile-time plan failure."""
    chosen = dict(attempt.get("strategy") or ())
    if not chosen:
        for c in tgt.choices():
            chosen.setdefault(c.name, c.default)
    if not isinstance(error, _PoisonedPlan):
        _mark_poison(plan_store, store_key, sorted(chosen.items()),
                     f"compile: {type(error).__name__}: {error}")
    last: BaseException = error
    walked: List[str] = []
    names = [c.name for c in tgt.choices()]
    for rung, forced in fallback_ladder(chosen, names):
        walked.append(rung)
        degrade(rung, program=program.name, target=tgt.name,
                reason="compile", error=last)
        try:
            if forced is None:
                result = _interp_fallback(program, fp, check)
            else:
                opts2 = replace(opts, strategy=tuple(sorted(forced.items())),
                                optimize=None)
                result = _build_plan(program, tgt, opts2, check, backend, fp,
                                     None, poison, plan_store, store_key, {})
        except Exception as e:
            last = e
            if forced is not None and not isinstance(e, _PoisonedPlan):
                _mark_poison(plan_store, store_key, sorted(forced.items()),
                             f"compile {rung}: {type(e).__name__}: {e}")
            continue
        result.degraded = tuple(walked)
        get_tracer().counter("robust.fallback.recovered")
        return result
    raise last


def _make_exec_guard(program: Program, tgt: Any, opts: CompileOptions,
                     check: bool, backend: Any, fp: str, plan_store: Any,
                     store_key: Optional[str],
                     plan_cache: Optional[PlanCache], key: Tuple):
    """The one-shot first-execution guard armed on guarded CompileResults.

    jit traces lazily, so shard bodies and backend codegen only run at the
    first call — a plan that compiled fine can still die there.  The guard
    poisons the crashed plan, invalidates its cache entry, walks the same
    ladder as the compile-time chain, *executes* each rung's plan on the
    caller's sources, and splices the surviving plan into the caller's
    CompileResult handle.
    """

    def exec_guard(result: CompileResult, error: BaseException,
                   sources: Any, args: Tuple) -> Any:
        if plan_cache is not None:
            plan_cache.drop(key)
        _mark_poison(plan_store, store_key, result.strategy,
                     f"execute: {type(error).__name__}: {error}")
        last: BaseException = error
        walked: List[str] = []
        names = [c.name for c in tgt.choices()]
        for rung, forced in fallback_ladder(dict(result.strategy), names):
            walked.append(rung)
            degrade(rung, program=program.name, target=result.target,
                    reason="execute", error=last)
            try:
                if forced is None:
                    nxt = _interp_fallback(program, fp, check)
                else:
                    opts2 = replace(opts,
                                    strategy=tuple(sorted(forced.items())),
                                    optimize=None)
                    nxt = _build_plan(program, tgt, opts2, check, backend,
                                      fp, None, frozenset(), None, None, {})
                out = nxt._dispatch(sources, *args)
            except Exception as e:
                last = e
                if forced is not None:
                    _mark_poison(plan_store, store_key,
                                 sorted(forced.items()),
                                 f"execute {rung}: {type(e).__name__}: {e}")
                continue
            # splice the surviving plan into the caller's handle — later
            # calls dispatch straight to the safe executable
            result.target = nxt.target
            result.program = nxt.program
            result.executable = nxt.executable
            result.strategy = nxt.strategy
            result.profile = nxt.profile
            result.degraded = result.degraded + tuple(walked)
            get_tracer().counter("robust.fallback.recovered")
            if plan_cache is not None:
                plan_cache.store(key, replace(result, cache_hit=False,
                                              cache_source="miss",
                                              _guard=None))
            return out
        raise last

    return exec_guard


class _NumpySourceAdapter:
    """Adapts VecTable sources to the interp backend's numpy-dict model.

    The fallback chain's terminal rung re-targets a query at interp, but
    the caller already passed the sources the *original* target consumes
    (``source_kind="vec"`` → VecTables).  This shim converts at dispatch so
    the degraded plan is a drop-in replacement.
    """

    emits_op_spans = True

    def __init__(self, inner: Any) -> None:
        self.inner = inner
        self.program = getattr(inner, "program", None)

    @staticmethod
    def _convert(sources: Any) -> Any:
        if sources is None:
            return None
        return {k: (v.to_numpy() if hasattr(v, "to_numpy") else v)
                for k, v in dict(sources).items()}

    def __call__(self, sources: Any = None, *args: Any) -> Any:
        return self.inner(self._convert(sources), *args)

    def run_traced(self, sources: Any = None, *args: Any) -> Any:
        return self.inner.run_traced(self._convert(sources), *args)


def _interp_fallback(program: Program, fp: str, check: bool) -> CompileResult:
    """The terminal rung: compile ``program`` for the reference interpreter."""
    it = get_target("interp")
    iopts = CompileOptions()
    lowered, records = _lower_with_strategy(program, it, iopts, {}, check)
    be = it.make_backend(iopts)
    maybe_inject("backend.compile", target="interp", program=program.name)
    t0 = time.perf_counter()
    with get_tracer().span("backend:interp", cat="compile.backend"):
        executable = be.compile(lowered)
    backend_s = time.perf_counter() - t0
    return CompileResult(
        target="interp",
        source=program,
        program=lowered,
        executable=_NumpySourceAdapter(executable),
        records=tuple(records),
        fingerprint=fp,
        backend_s=backend_s,
    )


def _normalize_strategy(strategy: Any, tgt: Any,
                        ) -> Optional[Tuple[Tuple[str, str], ...]]:
    """Validate forced strategy overrides against the target's choices —
    a misspelled choice or variant must fail loudly, not silently compile
    the default plan under a polluted cache key."""
    if not strategy:
        return None
    try:
        pairs = sorted(strategy.items() if isinstance(strategy, dict)
                       else strategy)
        strat = tuple((str(k), str(v)) for k, v in pairs)
    except (TypeError, ValueError):
        raise ValueError(
            f"strategy must be a mapping or (choice, variant) pairs, "
            f"got {strategy!r}") from None
    known = {c.name: [label for label, _ in c.variants] for c in tgt.choices()}
    for name, label in strat:
        if name not in known:
            raise ValueError(
                f"target {tgt.name!r} declares no strategy choice {name!r}; "
                f"declared: {sorted(known) or 'none'}")
        if label not in known[name]:
            raise ValueError(
                f"choice {name!r} has no variant {label!r}; "
                f"known: {known[name]}")
    return strat


def _resolve_store(store: Any):
    """``False`` → off; ``None`` → env default; path/str → open; else as-is."""
    if store is False:
        return None
    from .store import PlanStore, default_store

    if store is None:
        return default_store()
    if isinstance(store, (str, bytes)) or hasattr(store, "__fspath__"):
        return PlanStore(store)
    return store


_CALIBRATION_SEEDED = False


def _seed_calibration(plan_store: Any) -> None:
    """Warm the in-process calibration from the store, once."""
    global _CALIBRATION_SEEDED
    if _CALIBRATION_SEEDED or CALIBRATION.n:
        return
    loaded = plan_store.load_calibration()
    if loaded.n:
        CALIBRATION.scale = loaded.scale
        CALIBRATION.n = loaded.n
    _CALIBRATION_SEEDED = True


def _check_parallel_divides(program: Program, opts: CompileOptions) -> None:
    """Fail early, with the table named, instead of deep inside the typing
    rules: a worker count must divide every scanned table's padded capacity."""
    catalog = opts.catalog
    if not opts.parallel or opts.parallel <= 1 or catalog is None:
        return
    capacities = getattr(catalog, "capacities", None) or {}
    scanned = [ins.param("table") for p in program.walk() for ins in p.body
               if ins.opcode in ("rel.Scan", "vec.ScanVec")]
    bad = {t: capacities[t] for t in scanned
           if t in capacities and capacities[t] % opts.parallel != 0}
    if bad:
        listing = ", ".join(f"{t} (capacity {c})" for t, c in sorted(bad.items()))
        raise ValueError(
            f"parallel={opts.parallel} does not divide the padded capacity of "
            f"{listing}; pick a worker count that divides the capacities or "
            "adjust Context(pad_to=...)")


def _check_mesh_available(tgt: Any, opts: CompileOptions) -> None:
    """Mesh-backed targets fail at the driver, naming the shortfall, rather
    than deep inside jax mesh construction."""
    if not tgt.needs_mesh or opts.mesh is not None:
        return
    import jax

    needed = opts.parallel or 1
    available = jax.device_count()
    if needed > available:
        raise ValueError(
            f"target {tgt.name!r} needs a {needed}-device mesh but only "
            f"{available} device(s) are visible; pass mesh=... or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={needed} "
            "before jax initializes")


def _check_flavors(program: Program, tgt: Any) -> None:
    """Soft check: the lowered program should only use flavors the target
    declared.  Unknown/exotic flavors warn rather than fail — passes are
    required to leave unknown instructions alone, and backends may still
    know how to execute them."""
    seen = {op.split(".", 1)[0] for op in program.opcodes() if "." in op}
    extra = seen - set(tgt.flavors)
    if extra:
        warnings.warn(
            f"target {tgt.name!r} received IR flavors {sorted(extra)} outside "
            f"its declared set {list(tgt.flavors)}",
            stacklevel=3,
        )
