"""Unified compilation driver: one entry point for every backend.

``compile(program, target="spmd", parallel=8)`` looks up the registered
:class:`~repro.compiler.targets.Target`, consults the structural plan cache
(keyed by the alpha-invariant program fingerprint + the option cache key),
runs the target's declarative lowering path with per-pass instrumentation
(wall time + IR-size delta), hands the final program to the backend, and
caches the resulting :class:`CompileResult`.

Every frontend routes here: ``Context.compile`` (dataflow + SQL frontends)
and ``ElasticExecutor.plan`` (multipod) contain no inline pass lists, and
the tensor frontend's planning rewrites run through :func:`run_passes` so
they are instrumented the same way.
"""

from __future__ import annotations

import itertools
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from ..core.program import Program
from ..core.verify import verify
from ..obs.trace import get_tracer
from .cost import CALIBRATION, Candidate, PlanDecision, estimate_cost
from .fingerprint import fingerprint, fingerprint_value
from .stats import Statistics
from .targets import Choice, CompileOptions, get_target, target_epoch

__all__ = [
    "compile", "run_passes", "program_size",
    "CompileResult", "PassRecord", "PlanCache", "PLAN_CACHE",
]


# ---------------------------------------------------------------------------
# instrumentation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PassRecord:
    """One pass execution: where it ran, how long, and what it did to the IR."""

    stage: str
    name: str
    wall_s: float
    size_before: int
    size_after: int

    @property
    def delta(self) -> int:
        return self.size_after - self.size_before


def program_size(program: Program) -> int:
    """Total instruction count, including nested programs."""
    return sum(len(p.body) for p in program.walk())


def run_passes(program: Program, passes: Sequence[Any], stage: str = "pipeline",
               records: Optional[List[PassRecord]] = None,
               check: bool = True) -> Program:
    """Apply passes in order, timing each and verifying between them.

    The shared instrumented runner: the driver uses it per stage, and
    frontends with their own planning rewrites (tensor) call it directly so
    their passes are measured identically.
    """
    tracer = get_tracer()
    for p in passes:
        before = program_size(program)
        t0 = time.perf_counter()
        with tracer.span(p.name, cat="compile.pass", stage=stage) as sp:
            out = p.apply(program)
        wall = time.perf_counter() - t0
        after = program_size(out)
        sp.set(size_before=before, size_after=after)
        if check:
            try:
                verify(out, allow_unknown_ops=True)
            except Exception as e:
                raise AssertionError(
                    f"pass {p.name!r} broke the program:\n{out.render()}"
                ) from e
        if records is not None:
            records.append(PassRecord(stage, p.name, wall, before, after))
        program = out
    return program


# ---------------------------------------------------------------------------
# compile results
# ---------------------------------------------------------------------------


@dataclass
class CompileResult:
    """A compiled plan: callable executable + full compilation provenance."""

    target: str
    source: Program            # frontend program as handed to the driver
    program: Program           # final lowered program the backend consumed
    executable: Any            # backend-compiled callable
    records: Tuple[PassRecord, ...]
    fingerprint: str
    backend_s: float = 0.0
    cache_hit: bool = False
    #: (choice-name, variant) pairs the lowering actually used
    strategy: Tuple[Tuple[str, str], ...] = ()
    #: costed-search provenance (None for fixed-path compiles)
    decision: Optional[PlanDecision] = None
    #: the catalog statistics the plan was costed under (estimate side of
    #: the estimate-vs-actual join)
    stats: Optional[Statistics] = None
    #: where this result came from: "miss" (freshly compiled),
    #: "memory" (plan-cache hit), "store" (plan-store strategy replay)
    cache_source: str = "miss"
    #: latest traced execution's estimate-vs-actual profile
    #: (:class:`~repro.obs.feedback.RuntimeProfile`; None until a traced run)
    profile: Optional[Any] = None

    def __call__(self, sources: Any = None, *args: Any) -> Any:
        tracer = get_tracer()
        runner = getattr(self.executable, "run_traced", None)
        if not tracer.enabled or runner is None:
            # the hot path: plain dispatch, no span, no profile bookkeeping
            return self.executable(sources, *args)

        from ..obs import feedback as fb

        t0 = time.perf_counter()
        with tracer.span(f"execute:{self.source.name}", cat="execute",
                         target=self.target,
                         fingerprint=self.fingerprint[:12]) as sp:
            outs, cards, walls = runner(sources, *args)
        wall = time.perf_counter() - t0
        profile = fb.build_profile(self, cards, wall, wall_by_key=walls)
        sp.set(rows_measured=len(profile.observations))
        if not getattr(self.executable, "emits_op_spans", False):
            # jitted backends can't time ops inside the compiled body;
            # record zero-duration cardinality annotations instead
            for o in profile.observations:
                tracer.record_complete(
                    o.opcode, cat="execute.op", t0=t0, dur_s=0.0,
                    register=o.register, rows_out=o.rows_out,
                    rows_in=o.rows_in, est_rows=o.est_rows,
                    rel_miss=o.rel_miss, table=o.table)
        self.profile = profile
        fb.FEEDBACK.record(profile)
        return outs

    @property
    def total_s(self) -> float:
        return self.backend_s + sum(r.wall_s for r in self.records)

    def explain(self) -> str:
        """Per-pass wall time, IR-size deltas, the plan decision, and —
        after a traced execution — the estimated-vs-actual cardinalities."""
        head = (f"compile[{self.target}] {self.source.name}: "
                + ("cache hit" if self.cache_hit
                   else f"{self.total_s * 1e3:.2f} ms")
                + f" (fingerprint {self.fingerprint[:12]})"
                + f" cache={'hit' if self.cache_hit else 'miss'}"
                + f" source={self.cache_source}")
        if self.strategy:
            head += (" strategy "
                     + ", ".join(f"{k}={v}" for k, v in self.strategy))
        lines = [head,
                 "| stage | pass | wall ms | IR size | Δ |",
                 "|---|---|---:|---:|---:|"]
        for r in self.records:
            lines.append(f"| {r.stage} | {r.name} | {r.wall_s * 1e3:.3f} "
                         f"| {r.size_after} | {r.delta:+d} |")
        lines.append(f"| backend | {self.target} | {self.backend_s * 1e3:.3f} "
                     f"| {program_size(self.program)} | +0 |")
        if self.decision is not None:
            lines.append(self.decision.render())
        if self.profile is not None:
            lines.append(self.profile.render())
        return "\n".join(lines)

    def explain_records(self) -> List[Dict[str, Any]]:
        """The same data as :meth:`explain`, as JSON-ready records."""
        size = program_size(self.program)
        recs = [
            {"stage": r.stage, "pass": r.name, "wall_s": r.wall_s,
             "size_before": r.size_before, "size_after": r.size_after}
            for r in self.records
        ]
        recs.append({"stage": "backend", "pass": self.target,
                     "wall_s": self.backend_s,
                     "size_before": size, "size_after": size})
        return recs

    def metrics(self) -> Dict[str, Any]:
        """Structured metrics: compile provenance, runtime profile, and the
        active tracer's counters/histograms, in one JSON-ready dict."""
        out: Dict[str, Any] = {
            "target": self.target,
            "program": self.source.name,
            "fingerprint": self.fingerprint,
            "cache": "hit" if self.cache_hit else "miss",
            "cache_source": self.cache_source,
            "strategy": dict(self.strategy),
            "compile": {"total_s": self.total_s,
                        "backend_s": self.backend_s,
                        "passes": self.explain_records()},
        }
        if self.decision is not None:
            out["decision"] = self.decision.records()
        if self.profile is not None:
            out["runtime"] = {
                "wall_s": self.profile.wall_s,
                "est_cost": self.profile.est_cost,
                "worst_miss": self.profile.worst_miss,
                "operators": self.profile.records(),
            }
        out["tracer"] = get_tracer().metrics()
        return out


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


class PlanCache:
    """LRU cache of CompileResults keyed by (target, fingerprint, options)."""

    def __init__(self, capacity: int = 256) -> None:
        self.capacity = capacity
        self._entries: "OrderedDict[Tuple, CompileResult]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def lookup(self, key: Tuple) -> Optional[CompileResult]:
        got = self._entries.get(key)
        if got is None:
            self.misses += 1
            get_tracer().counter("plan_cache.miss")
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        get_tracer().counter("plan_cache.hit")
        return got

    def store(self, key: Tuple, result: CompileResult) -> None:
        self._entries[key] = result
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
            get_tracer().counter("plan_cache.evict")

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._entries)}


#: process-wide default cache — repeated compiles of the same frontend
#: program (serve paths, elastic re-planning) are near-free
PLAN_CACHE = PlanCache()


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------


def _lower_with_strategy(program: Program, tgt: Any, opts: CompileOptions,
                         chosen: Dict[str, str], check: bool,
                         ) -> Tuple[Program, List[PassRecord]]:
    """Run the target's lowering path with each Choice bound to a variant."""
    records: List[PassRecord] = []
    lowered = program
    for stage in tgt.lowering_path:
        if isinstance(stage, Choice):
            stage = stage.variant(chosen.get(stage.name, stage.default))
        lowered = run_passes(lowered, stage.build(opts), stage=stage.name,
                             records=records, check=check)
    return lowered, records


def _choose_strategy(program: Program, tgt: Any, opts: CompileOptions,
                     check: bool, stored: Optional[Dict[str, Any]],
                     ) -> Tuple[Dict[str, str], Program, List[PassRecord],
                                Optional[PlanDecision]]:
    """Cost-based plan selection: enumerate the target's Choice points,
    lower each candidate, cost the final programs, keep the cheapest.

    A plan-store record from a previous process short-circuits the search:
    the recorded winner is re-lowered directly (source="store").
    """
    choices = tgt.choices()
    forced = dict(opts.strategy or ())
    stats = opts.stats()

    if stored is not None and stored.get("strategy"):
        chosen = {str(k): str(v) for k, v in stored["strategy"]}
        chosen.update(forced)
        t0 = time.perf_counter()
        lowered, records = _lower_with_strategy(program, tgt, opts, chosen,
                                                check)
        lower_s = time.perf_counter() - t0
        cand = Candidate(strategy=tuple(sorted(chosen.items())),
                         est_cost=estimate_cost(lowered, stats),
                         size=program_size(lowered), lower_s=lower_s)
        decision = PlanDecision(candidates=(cand,), chosen=0, source="store",
                                est_seconds=CALIBRATION.seconds(cand.est_cost))
        return chosen, lowered, records, decision

    axes = []
    for c in choices:
        labels = (forced[c.name],) if c.name in forced else c.labels(opts)
        axes.append([(c.name, label) for label in labels])

    candidates: List[Candidate] = []
    lowerings: List[Tuple[Program, List[PassRecord]]] = []
    for combo in itertools.product(*axes) if axes else [()]:
        chosen = dict(combo)
        t0 = time.perf_counter()
        lowered, records = _lower_with_strategy(program, tgt, opts, chosen,
                                                check)
        lower_s = time.perf_counter() - t0
        candidates.append(Candidate(
            strategy=tuple(sorted(chosen.items())),
            est_cost=estimate_cost(lowered, stats),
            size=program_size(lowered), lower_s=lower_s))
        lowerings.append((lowered, records))

    best = min(range(len(candidates)), key=lambda i: candidates[i].est_cost)
    decision = PlanDecision(
        candidates=tuple(candidates), chosen=best, source="search",
        est_seconds=CALIBRATION.seconds(candidates[best].est_cost))
    lowered, records = lowerings[best]
    return dict(candidates[best].strategy), lowered, records, decision


def compile(program: Program, target: str = "local", *,
            parallel: Optional[int] = None,
            catalog: Any = None,
            use_kernels: bool = False,
            fuse: bool = True,
            axis: str = "workers",
            mesh: Any = None,
            jit: bool = True,
            collectives: bool = True,
            parallelize_targets: Optional[Sequence[str]] = None,
            optimize: Optional[str] = None,
            strategy: Any = None,
            cache: Union[None, bool, PlanCache] = None,
            store: Any = None,
            backend: Any = None,
            check: bool = True) -> CompileResult:
    """Compile a frontend CVM program for a registered target.

    ``cache``: ``None``/``True`` → the process-wide :data:`PLAN_CACHE`;
    ``False`` → no caching; a :class:`PlanCache` → that cache.  An explicit
    ``backend`` instance overrides the target's factory and bypasses the
    cache (its configuration is invisible to the key).

    ``optimize="cost"`` turns the fixed lowering path into a costed search
    over the target's declared strategy :class:`~repro.compiler.targets.Choice`
    points; ``strategy={"grouped-recombine": "exchange", ...}`` forces
    specific variants.  ``store`` (a :class:`~repro.compiler.store.PlanStore`
    or path) persists plan metadata across processes; ``None`` falls back to
    the ``REPRO_PLAN_STORE`` environment default, ``False`` disables.
    """
    tracer = get_tracer()
    if not tracer.enabled:
        return _compile_impl(
            program, target, parallel=parallel, catalog=catalog,
            use_kernels=use_kernels, fuse=fuse, axis=axis, mesh=mesh, jit=jit,
            collectives=collectives, parallelize_targets=parallelize_targets,
            optimize=optimize, strategy=strategy, cache=cache, store=store,
            backend=backend, check=check)
    with tracer.span(f"compile:{program.name}", cat="compile",
                     target=target) as sp:
        result = _compile_impl(
            program, target, parallel=parallel, catalog=catalog,
            use_kernels=use_kernels, fuse=fuse, axis=axis, mesh=mesh, jit=jit,
            collectives=collectives, parallelize_targets=parallelize_targets,
            optimize=optimize, strategy=strategy, cache=cache, store=store,
            backend=backend, check=check)
        sp.set(cache="hit" if result.cache_hit else "miss",
               source=result.cache_source,
               fingerprint=result.fingerprint[:12])
    return result


def _compile_impl(program: Program, target: str = "local", *,
                  parallel: Optional[int] = None,
                  catalog: Any = None,
                  use_kernels: bool = False,
                  fuse: bool = True,
                  axis: str = "workers",
                  mesh: Any = None,
                  jit: bool = True,
                  collectives: bool = True,
                  parallelize_targets: Optional[Sequence[str]] = None,
                  optimize: Optional[str] = None,
                  strategy: Any = None,
                  cache: Union[None, bool, PlanCache] = None,
                  store: Any = None,
                  backend: Any = None,
                  check: bool = True) -> CompileResult:
    if optimize not in (None, "cost"):
        raise ValueError(f"unknown optimize mode {optimize!r}; "
                         "expected None or 'cost'")
    tgt = get_target(target)
    strat = _normalize_strategy(strategy, tgt)
    opts = CompileOptions(
        parallel=parallel, use_kernels=use_kernels, fuse=fuse, axis=axis,
        jit=jit, collectives=collectives, catalog=catalog, mesh=mesh,
        parallelize_targets=(tuple(sorted(parallelize_targets))
                             if parallelize_targets else None),
        optimize=optimize, strategy=strat,
    )
    _check_parallel_divides(program, opts)
    _check_mesh_available(tgt, opts)

    fp = fingerprint(program)
    if cache is False:
        plan_cache: Optional[PlanCache] = None
    elif cache is None or cache is True:
        plan_cache = PLAN_CACHE
    else:
        plan_cache = cache
    use_cache = plan_cache is not None and backend is None

    key = (tgt.name, target_epoch(tgt.name), fp, opts.cache_key())
    if use_cache:
        hit = plan_cache.lookup(key)
        if hit is not None:
            return replace(hit, cache_hit=True, cache_source="memory")

    plan_store = _resolve_store(store)
    store_key: Optional[str] = None
    if plan_store is not None:
        store_key = fingerprint_value(key)
        _seed_calibration(plan_store)

    decision: Optional[PlanDecision] = None
    if optimize == "cost" and tgt.choices():
        stored = (plan_store.load_plan(store_key)
                  if plan_store is not None else None)
        chosen, lowered, records, decision = _choose_strategy(
            program, tgt, opts, check, stored)
    else:
        chosen = dict(opts.strategy or ())
        for c in tgt.choices():
            chosen.setdefault(c.name, c.default)
        lowered, records = _lower_with_strategy(program, tgt, opts, chosen,
                                                check)

    _check_flavors(lowered, tgt)

    be = backend if backend is not None else tgt.make_backend(opts)
    t0 = time.perf_counter()
    with get_tracer().span(f"backend:{tgt.name}", cat="compile.backend"):
        executable = be.compile(lowered)
    backend_s = time.perf_counter() - t0

    if decision is not None:
        measured = backend_s + sum(r.wall_s for r in records)
        CALIBRATION.update(decision.winner.est_cost, measured)
        decision = replace(decision, measured_s=measured)

    result = CompileResult(
        target=tgt.name,
        source=program,
        program=getattr(executable, "program", lowered),
        executable=executable,
        records=tuple(records),
        fingerprint=fp,
        backend_s=backend_s,
        strategy=tuple(sorted(chosen.items())),
        decision=decision,
        stats=opts.stats(),
        cache_source=("store" if decision is not None
                      and decision.source == "store" else "miss"),
    )
    if use_cache:
        plan_cache.store(key, result)
    if plan_store is not None and store_key is not None and backend is None:
        plan_store.save_plan(store_key, {
            "target": tgt.name,
            "fingerprint": fp,
            "strategy": sorted(chosen.items()),
            "optimize": optimize,
            "records": result.explain_records(),
            "decision": decision.records() if decision is not None else None,
            "backend_s": backend_s,
        })
        # only persist calibration this compile actually updated — a plain
        # fixed-path compile must not clobber another process's learned scale
        if decision is not None and CALIBRATION.n:
            plan_store.save_calibration(CALIBRATION)
    return result


def _normalize_strategy(strategy: Any, tgt: Any,
                        ) -> Optional[Tuple[Tuple[str, str], ...]]:
    """Validate forced strategy overrides against the target's choices —
    a misspelled choice or variant must fail loudly, not silently compile
    the default plan under a polluted cache key."""
    if not strategy:
        return None
    try:
        pairs = sorted(strategy.items() if isinstance(strategy, dict)
                       else strategy)
        strat = tuple((str(k), str(v)) for k, v in pairs)
    except (TypeError, ValueError):
        raise ValueError(
            f"strategy must be a mapping or (choice, variant) pairs, "
            f"got {strategy!r}") from None
    known = {c.name: [label for label, _ in c.variants] for c in tgt.choices()}
    for name, label in strat:
        if name not in known:
            raise ValueError(
                f"target {tgt.name!r} declares no strategy choice {name!r}; "
                f"declared: {sorted(known) or 'none'}")
        if label not in known[name]:
            raise ValueError(
                f"choice {name!r} has no variant {label!r}; "
                f"known: {known[name]}")
    return strat


def _resolve_store(store: Any):
    """``False`` → off; ``None`` → env default; path/str → open; else as-is."""
    if store is False:
        return None
    from .store import PlanStore, default_store

    if store is None:
        return default_store()
    if isinstance(store, (str, bytes)) or hasattr(store, "__fspath__"):
        return PlanStore(store)
    return store


_CALIBRATION_SEEDED = False


def _seed_calibration(plan_store: Any) -> None:
    """Warm the in-process calibration from the store, once."""
    global _CALIBRATION_SEEDED
    if _CALIBRATION_SEEDED or CALIBRATION.n:
        return
    loaded = plan_store.load_calibration()
    if loaded.n:
        CALIBRATION.scale = loaded.scale
        CALIBRATION.n = loaded.n
    _CALIBRATION_SEEDED = True


def _check_parallel_divides(program: Program, opts: CompileOptions) -> None:
    """Fail early, with the table named, instead of deep inside the typing
    rules: a worker count must divide every scanned table's padded capacity."""
    catalog = opts.catalog
    if not opts.parallel or opts.parallel <= 1 or catalog is None:
        return
    capacities = getattr(catalog, "capacities", None) or {}
    scanned = [ins.param("table") for p in program.walk() for ins in p.body
               if ins.opcode in ("rel.Scan", "vec.ScanVec")]
    bad = {t: capacities[t] for t in scanned
           if t in capacities and capacities[t] % opts.parallel != 0}
    if bad:
        listing = ", ".join(f"{t} (capacity {c})" for t, c in sorted(bad.items()))
        raise ValueError(
            f"parallel={opts.parallel} does not divide the padded capacity of "
            f"{listing}; pick a worker count that divides the capacities or "
            "adjust Context(pad_to=...)")


def _check_mesh_available(tgt: Any, opts: CompileOptions) -> None:
    """Mesh-backed targets fail at the driver, naming the shortfall, rather
    than deep inside jax mesh construction."""
    if not tgt.needs_mesh or opts.mesh is not None:
        return
    import jax

    needed = opts.parallel or 1
    available = jax.device_count()
    if needed > available:
        raise ValueError(
            f"target {tgt.name!r} needs a {needed}-device mesh but only "
            f"{available} device(s) are visible; pass mesh=... or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={needed} "
            "before jax initializes")


def _check_flavors(program: Program, tgt: Any) -> None:
    """Soft check: the lowered program should only use flavors the target
    declared.  Unknown/exotic flavors warn rather than fail — passes are
    required to leave unknown instructions alone, and backends may still
    know how to execute them."""
    seen = {op.split(".", 1)[0] for op in program.opcodes() if "." in op}
    extra = seen - set(tgt.flavors)
    if extra:
        warnings.warn(
            f"target {tgt.name!r} received IR flavors {sorted(extra)} outside "
            f"its declared set {list(tgt.flavors)}",
            stacklevel=3,
        )
