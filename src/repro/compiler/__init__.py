"""The CVM compilation driver subsystem.

Six pieces (see docs/compiler.md):

* :mod:`repro.compiler.targets` — the backend target registry with
  declarative, flavor-aware lowering paths and strategy ``Choice`` points;
* :mod:`repro.compiler.driver` — the single ``compile()`` entry point with
  per-pass instrumentation, the structural plan cache, and the
  ``optimize="cost"`` candidate search;
* :mod:`repro.compiler.fingerprint` — alpha-renaming-invariant structural
  fingerprints of ``Program`` trees (the cache's content address);
* :mod:`repro.compiler.stats` — the table-statistics catalog and the
  estimate propagation rules;
* :mod:`repro.compiler.cost` — the cost model, calibration, and plan
  decisions;
* :mod:`repro.compiler.store` — the on-disk plan-metadata store.
"""

from .cost import (  # noqa: F401
    Candidate,
    CostCalibration,
    CostModel,
    PlanDecision,
    estimate_cost,
)
from .driver import (  # noqa: F401
    PLAN_CACHE,
    CompileResult,
    PassRecord,
    PlanCache,
    compile,
    program_size,
    run_passes,
)
from .fingerprint import canonicalize, fingerprint, fingerprint_value  # noqa: F401
from .stats import RegStats, Statistics, TableStats, propagate, stats_from_columns  # noqa: F401
from .store import PlanStore, default_store  # noqa: F401
from .targets import (  # noqa: F401
    Choice,
    CompileOptions,
    Stage,
    Target,
    available_targets,
    get_target,
    register_target,
)
