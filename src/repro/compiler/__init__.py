"""The CVM compilation driver subsystem.

Three pieces (see docs/compiler.md):

* :mod:`repro.compiler.targets` — the backend target registry with
  declarative, flavor-aware lowering paths;
* :mod:`repro.compiler.driver` — the single ``compile()`` entry point with
  per-pass instrumentation and the structural plan cache;
* :mod:`repro.compiler.fingerprint` — alpha-renaming-invariant structural
  fingerprints of ``Program`` trees (the cache's content address).
"""

from .driver import (  # noqa: F401
    PLAN_CACHE,
    CompileResult,
    PassRecord,
    PlanCache,
    compile,
    program_size,
    run_passes,
)
from .fingerprint import canonicalize, fingerprint, fingerprint_value  # noqa: F401
from .targets import (  # noqa: F401
    CompileOptions,
    Stage,
    Target,
    available_targets,
    get_target,
    register_target,
)
