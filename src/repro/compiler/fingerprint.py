"""Structural fingerprints for CVM programs (content-addressed plan keys).

The plan cache must recognise "the same program" across independent
constructions: builders and rewrites draw register names from global
counters, so two runs of the same frontend code produce programs that
differ only by alpha-renaming.  The fingerprint therefore never hashes
register *names*: registers are numbered by definition order (de Bruijn
style — program inputs first, then each instruction's outputs) and uses
hash as those indices.  Nested programs open a fresh scope, so
higher-order instructions (``ConcurrentExecute``, ``Loop``, ``df.Map``,
...) are fingerprinted structurally all the way down.

Everything that can change compiled behaviour *is* hashed: opcodes,
parameter values (expressions, agg specs, schemas, nested programs),
register types (static capacities live in types), and result order.
Program and register names are deliberately excluded.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict

import numpy as np

from ..core.program import Instruction, Program, Register
from ..core.types import Atom, CollectionKind, CollectionType, ItemType, TupleType

__all__ = ["fingerprint", "fingerprint_value", "canonicalize"]


def fingerprint(program: Program) -> str:
    """Hex digest of the program's canonical (alpha-invariant) structure."""
    if not isinstance(program, Program):
        raise TypeError(f"fingerprint() takes a Program, got {type(program).__name__}")
    return fingerprint_value(program)


def fingerprint_value(value: Any) -> str:
    """Hex digest of any parameter-like value (catalogs, options, ...)."""
    h = hashlib.sha256()
    h.update(repr(canonicalize(value)).encode("utf-8"))
    return h.hexdigest()


def canonicalize(value: Any) -> Any:
    """Canonical, name-free, repr-stable tree for a program or param value."""
    return _canon(value)


# ---------------------------------------------------------------------------
# canonical trees
# ---------------------------------------------------------------------------


def _canon_type(t: ItemType) -> Any:
    if isinstance(t, Atom):
        return ("atom", t.domain)
    if isinstance(t, TupleType):
        return ("tuple", tuple((n, _canon_type(ft)) for n, ft in t.fields))
    if isinstance(t, CollectionType):
        return (
            "coll",
            t.kind.name,
            tuple((k, _canon(v)) for k, v in t.attrs),
            _canon_type(t.item),
        )
    return ("type", type(t).__name__, repr(t))


def _canon_program(p: Program) -> Any:
    env: Dict[str, int] = {}
    for r in p.inputs:
        env[r.name] = len(env)

    def ref(r: Register) -> Any:
        idx = env.get(r.name)
        # a use of a register not defined in this scope (ill-formed SSA or a
        # cross-scope reference mid-rewrite): fall back to the name so the
        # fingerprint stays total rather than raising
        return idx if idx is not None else ("free", r.name)

    body = []
    for ins in p.body:
        in_refs = tuple(ref(r) for r in ins.inputs)
        for r in ins.outputs:
            env[r.name] = len(env)
        body.append((
            ins.opcode,
            in_refs,
            tuple(_canon_type(r.type) for r in ins.outputs),
            tuple(sorted(((k, _canon(v)) for k, v in ins.params),
                         key=lambda kv: kv[0])),
        ))
    return (
        "program",
        tuple(_canon_type(r.type) for r in p.inputs),
        tuple(body),
        tuple(ref(r) for r in p.results),
    )


def _canon(v: Any) -> Any:
    if isinstance(v, Program):
        return _canon_program(v)
    if isinstance(v, Instruction):
        return _canon_program(Program("_", (), (v,), ()))
    if isinstance(v, Register):
        return ("reg", _canon_type(v.type))
    if isinstance(v, ItemType):
        return _canon_type(v)
    if isinstance(v, CollectionKind):
        return ("kind", v.name)
    if v is None or isinstance(v, (bool, int, float, complex, str, bytes)):
        return (type(v).__name__, v)
    if isinstance(v, (list, tuple)):
        return ("seq", tuple(_canon(x) for x in v))
    if isinstance(v, (set, frozenset)):
        return ("set", tuple(sorted(repr(_canon(x)) for x in v)))
    if isinstance(v, dict):
        return ("map", tuple(sorted(
            (repr(_canon(k)), _canon(val)) for k, val in v.items())))
    if isinstance(v, np.ndarray):
        return ("ndarray", str(v.dtype), tuple(v.shape),
                hashlib.sha256(np.ascontiguousarray(v).tobytes()).hexdigest())
    if isinstance(v, np.generic):
        return ("npscalar", str(v.dtype), v.item())
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        # Expr trees, AggSpec, and any frontend-defined frozen param records
        return ("obj", type(v).__name__, tuple(
            (f.name, _canon(getattr(v, f.name)))
            for f in dataclasses.fields(v) if f.compare
        ))
    if hasattr(v, "dtype") and hasattr(v, "shape"):  # jax arrays et al.
        return _canon(np.asarray(v))
    # last resort: type + repr (deterministic for anything sane enough to
    # appear as an instruction parameter)
    return ("repr", type(v).__name__, repr(v))
