"""Statistics catalog + estimate propagation for cost-based plan selection.

The paper's rewriting pipelines are "highly flexible and configurable"; to
*choose* between two valid physical plans (Tupleware/Flare-style) the driver
needs cardinality estimates.  This module carries them:

  * :class:`TableStats` / :class:`Statistics` — the per-table catalog:
    row count, bytes per row, and per-column NDV (number of distinct
    values, i.e. key cardinality).  Frontends thread these into
    ``CompileOptions`` via ``Catalog.stats``.
  * :class:`RegStats` — the estimate attached to one register while
    propagating through a (possibly already rewritten) program.
  * :func:`propagate` — abstract interpretation of a CVM program under the
    catalog: every pass output stays estimable because the rules understand
    the rewritten forms too (``cf.Split``/``ConcurrentExecute`` chunks,
    ``mesh.MeshExecute`` bodies, fused ``vec.FusedSelectAgg``, collectives).
    Unknown instructions pass their first input's estimate through — the
    same "leave it as is" contract the rewrite rules follow.

Estimates are deliberately coarse (constant filter selectivity, independent
keys); they only need to rank alternative physical plans, not predict
runtimes.  Calibration against measured compiles lives in ``cost.py``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Mapping, Optional, Tuple

from ..core.program import Program, Register
from ..core.types import CollectionType, item_nbytes, is_coll

__all__ = [
    "TableStats", "Statistics", "RegStats", "propagate", "stats_from_columns",
    "DEFAULT_SELECTIVITY", "seq_chunks",
]

#: fraction of rows assumed to survive a filter when the predicate is opaque
DEFAULT_SELECTIVITY = 0.5


# ---------------------------------------------------------------------------
# the catalog
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TableStats:
    """Statistics for one base table."""

    rows: int
    bytes_per_row: float = 8.0
    ndv: Tuple[Tuple[str, int], ...] = ()  # per-column distinct-value counts
    #: per-column value bounds (lo, hi), integral columns only — these are
    #: what make *dense-bucket* physical operators (vec.GroupAggDirect,
    #: domain-packed composite join keys) plannable
    domains: Tuple[Tuple[str, Tuple[int, int]], ...] = ()

    def ndv_of(self, column: str, default: Optional[int] = None) -> Optional[int]:
        for name, n in self.ndv:
            if name == column:
                return n
        return default

    def domain_of(self, column: str) -> Optional[Tuple[int, int]]:
        for name, d in self.domains:
            if name == column:
                return d
        return None

    @staticmethod
    def make(rows: int, bytes_per_row: float = 8.0,
             ndv: Optional[Mapping[str, int]] = None,
             domains: Optional[Mapping[str, Tuple[int, int]]] = None,
             ) -> "TableStats":
        return TableStats(int(rows), float(bytes_per_row),
                          tuple(sorted((ndv or {}).items())),
                          tuple(sorted((k, (int(lo), int(hi)))
                                       for k, (lo, hi) in (domains or {}).items())))

    def with_rows(self, rows: int) -> "TableStats":
        """An *observed* copy: measured row count, everything else kept.

        NDV caps ride along — a measured table can't have more distinct
        values in a column than it has rows."""
        rows = int(rows)
        ndv = tuple((k, min(v, max(rows, 1))) for k, v in self.ndv)
        return replace(self, rows=rows, ndv=ndv)


@dataclass(frozen=True)
class Statistics:
    """Per-table statistics catalog (hashable: part of the plan-cache key)."""

    tables: Tuple[Tuple[str, TableStats], ...] = ()

    @staticmethod
    def make(tables: Mapping[str, TableStats]) -> "Statistics":
        return Statistics(tuple(sorted(tables.items())))

    def table(self, name: str) -> Optional[TableStats]:
        for n, t in self.tables:
            if n == name:
                return t
        return None

    def cache_key(self) -> Tuple:
        return tuple((n, t.rows, t.bytes_per_row, t.ndv, t.domains)
                     for n, t in self.tables)

    def with_observed_rows(self, rows: Mapping[str, int]) -> "Statistics":
        """Fold measured base-table cardinalities (from traced executions —
        see ``repro.obs.feedback``) into the catalog: measured row counts
        override the estimates, tables the catalog never saw are added with
        default per-row bytes, and NDV/domain knowledge is preserved."""
        tables = {n: t for n, t in self.tables}
        for name, n_rows in rows.items():
            base = tables.get(name)
            tables[name] = (base.with_rows(n_rows) if base is not None
                            else TableStats(int(n_rows)))
        return Statistics.make(tables)


def stats_from_columns(columns: Mapping[str, Any]) -> TableStats:
    """Exact statistics from in-memory numpy columns (small-data frontends)."""
    import numpy as np

    rows = len(next(iter(columns.values()))) if columns else 0
    bpr = float(sum(np.asarray(v).dtype.itemsize for v in columns.values())) or 8.0
    ndv = {k: int(np.unique(np.asarray(v)).size) for k, v in columns.items()}
    domains = {}
    for k, v in columns.items():
        a = np.asarray(v)
        if rows == 0:
            continue
        if a.dtype == np.bool_:
            domains[k] = (0, 1)
        elif np.issubdtype(a.dtype, np.integer):
            domains[k] = (int(a.min()), int(a.max()))
    return TableStats.make(rows, bpr, ndv, domains)


# ---------------------------------------------------------------------------
# register estimates
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RegStats:
    """Estimated properties of one register's value.

    For split registers (``Seq[n]`` of chunks) the estimate is *per chunk*,
    matching how the backends execute them.
    """

    rows: float
    bytes_per_row: float = 8.0
    ndv: Tuple[Tuple[str, float], ...] = ()
    #: per-column integral value bounds, carried through rewrites so the
    #: lowering can plan dense-bucket operators on derived registers
    domains: Tuple[Tuple[str, Tuple[int, int]], ...] = ()

    @property
    def bytes(self) -> float:
        return self.rows * self.bytes_per_row

    def ndv_of(self, column: str, default: Optional[float] = None) -> Optional[float]:
        for name, n in self.ndv:
            if name == column:
                return n
        return default

    def domain_of(self, column: str) -> Optional[Tuple[int, int]]:
        for name, d in self.domains:
            if name == column:
                return d
        return None

    def scaled(self, factor: float) -> "RegStats":
        rows = max(self.rows * factor, 1.0)
        ndv = tuple((k, min(v, rows)) for k, v in self.ndv)
        return replace(self, rows=rows, ndv=ndv)

    def group_rows(self, keys: Tuple[str, ...], cap: Optional[int] = None) -> float:
        """Estimated distinct groups for ``keys`` (independence assumption)."""
        est = 1.0
        for k in keys:
            est *= self.ndv_of(k) or min(self.rows, 64.0)
        est = min(est, self.rows)
        if cap is not None:
            est = min(est, float(cap))
        return max(est, 1.0)


def _bpr_of(reg: Register, default: float = 8.0) -> float:
    t = reg.type
    while is_coll(t) and isinstance(t, CollectionType) and is_coll(t.item):
        t = t.item  # unwrap Seq-of-chunks down to the element collection
    return float(item_nbytes(t, int(default)))


def _seq_n(reg: Register) -> int:
    t = reg.type
    if is_coll(t):
        n = t.attr("n")
        if n:
            return int(n)
    return 1


def seq_chunks(reg: Register) -> int:
    """Number of chunks of a split ``Seq[n]`` register (1 when unsplit) —
    how per-chunk estimates scale to the global cardinality."""
    return _seq_n(reg)


# ---------------------------------------------------------------------------
# propagation
# ---------------------------------------------------------------------------


class StatsEnv:
    """Register → RegStats over a program tree (nested scopes included)."""

    def __init__(self) -> None:
        self._env: Dict[Tuple[int, str], RegStats] = {}

    def get(self, program: Program, reg: Register) -> RegStats:
        got = self._env.get((id(program), reg.name))
        if got is not None:
            return got
        # total fallback: estimate from the type alone
        cap = reg.type.attr("max_count") if is_coll(reg.type) else None
        return RegStats(rows=float(cap or 64), bytes_per_row=_bpr_of(reg))

    def set(self, program: Program, reg: Register, s: RegStats) -> None:
        self._env[(id(program), reg.name)] = s


def propagate(program: Program, stats: Optional[Statistics] = None,
              input_stats: Optional[Mapping[str, RegStats]] = None,
              env: Optional[StatsEnv] = None) -> StatsEnv:
    """Propagate table statistics through a program (and nested programs).

    Works on any IR flavor mix, before or after rewriting: the rules cover
    the relational ops, their vec/mesh lowerings, and the control-flow
    scaffolding the parallelization rewrite introduces, so estimates
    "survive" ``Parallelize``, ``FuseSelectAgg``, and ``LowerToMesh``.
    """
    env = env or StatsEnv()
    for r in program.inputs:
        if input_stats and r.name in input_stats:
            env.set(program, r, input_stats[r.name])
    for ins in program.body:
        args = [env.get(program, r) for r in ins.inputs]
        outs = _propagate_ins(ins, args, stats, env, program)
        for reg, s in zip(ins.outputs, outs):
            env.set(program, reg, s)
    return env


def _scan_stats(table: str, reg: Register, stats: Optional[Statistics]) -> RegStats:
    ts = stats.table(table) if stats is not None else None
    if ts is None:
        cap = reg.type.attr("max_count") if is_coll(reg.type) else None
        return RegStats(rows=float(cap or 1024), bytes_per_row=_bpr_of(reg))
    return RegStats(rows=float(ts.rows), bytes_per_row=float(ts.bytes_per_row),
                    ndv=tuple((k, float(v)) for k, v in ts.ndv),
                    domains=tuple(ts.domains))


def _propagate_ins(ins, args, stats, env: StatsEnv, program: Program):
    op = ins.opcode
    first = args[0] if args else RegStats(rows=1.0)

    if op in ("rel.Scan", "vec.ScanVec"):
        return [_scan_stats(ins.param("table"), ins.outputs[0], stats)]

    if op in ("rel.Select", "vec.MaskSelect"):
        return [first.scaled(DEFAULT_SELECTIVITY)]

    if op in ("rel.Proj", "vec.ProjVec", "vec.SortByKey", "rel.OrderBy",
              "vec.Compact"):
        return [replace(first.scaled(1.0), bytes_per_row=_bpr_of(ins.outputs[0]))]

    if op in ("rel.ExProj", "vec.ExProjVec"):
        # computed columns invalidate their NDV/domain estimates: keep them
        # only where the expression is the identity Col — a stale domain
        # would make a downstream dense-bucket plan silently merge groups
        from ..core.expr import Col
        identity = {n for n, e in tuple(ins.param("exprs") or ())
                    if isinstance(e, Col) and e.name == n}
        return [replace(first.scaled(1.0), bytes_per_row=_bpr_of(ins.outputs[0]),
                        ndv=tuple((k, v) for k, v in first.ndv if k in identity),
                        domains=tuple((k, d) for k, d in first.domains
                                      if k in identity))]

    if op in ("rel.Aggr", "vec.AggrVec", "vec.FusedSelectAgg",
              "vec.FinalizeSingle", "rel.CombinePartials"):
        return [RegStats(rows=1.0, bytes_per_row=_bpr_of(ins.outputs[0]))]

    if op in ("rel.GroupByAggr", "vec.GroupAggSorted", "vec.GroupAggDirect"):
        keys = tuple(ins.param("keys") or ())
        cap = ins.param("max_groups")
        groups = first.group_rows(keys, int(cap) if cap else None)
        ndv = tuple((k, min(first.ndv_of(k) or groups, groups)) for k in keys)
        domains = tuple((k, d) for k in keys
                        for d in (first.domain_of(k),) if d is not None)
        return [RegStats(rows=groups, bytes_per_row=_bpr_of(ins.outputs[0]),
                         ndv=ndv, domains=domains)]

    if op in ("rel.Join", "vec.MergeJoinSorted", "vec.HashJoinDirect"):
        left = args[0]
        out = replace(left.scaled(1.0), bytes_per_row=_bpr_of(ins.outputs[0]),
                      ndv=tuple(left.ndv) + tuple(args[1].ndv),
                      domains=tuple(left.domains) + tuple(args[1].domains))
        return [out]

    if op == "vec.FusedJoinGroupAgg":
        # select→join→group in one op: the grouping sees the joined columns
        left = args[0]
        sel = DEFAULT_SELECTIVITY if ins.param("pred") is not None else 1.0
        joined = replace(left.scaled(sel),
                         ndv=tuple(left.ndv) + tuple(args[1].ndv),
                         domains=tuple(left.domains) + tuple(args[1].domains))
        keys = tuple(ins.param("keys") or ())
        cap = ins.param("max_groups")
        groups = joined.group_rows(keys, int(cap) if cap else None)
        ndv = tuple((k, min(joined.ndv_of(k) or groups, groups)) for k in keys)
        domains = tuple((k, d) for k in keys
                        for d in (joined.domain_of(k),) if d is not None)
        return [RegStats(rows=groups, bytes_per_row=_bpr_of(ins.outputs[0]),
                         ndv=ndv, domains=domains)]

    if op in ("rel.Limit", "vec.LimitVec", "vec.TopKVec"):
        k = float(ins.param("k", first.rows))
        return [first.scaled(min(1.0, k / max(first.rows, 1.0)))]

    if op == "cf.Split":
        n = int(ins.param("n"))
        return [first.scaled(1.0 / max(n, 1))]

    if op == "cf.Broadcast":
        return [first]

    if op == "cf.Merge":
        n = _seq_n(ins.inputs[0])
        return [first.scaled(float(n))]

    if op == "cf.CombineChunks":
        return [first]

    if op == "cf.TakeChunk":
        return [first]

    if op in ("cf.ConcurrentExecute", "mesh.MeshExecute"):
        inner: Program = ins.param("P")
        inner_in = {r.name: s for r, s in zip(inner.inputs, args)}
        propagate(inner, stats, inner_in, env)
        return [env.get(inner, r) for r in inner.results]

    if op == "mesh.AllReduce":
        return [first]

    if op == "mesh.AllGatherVec":
        n = int(ins.param("n", 1))
        return [first.scaled(float(n))]

    if op == "mesh.ExchangeByKey":
        # redistribution: per-shard row count is preserved on average, but
        # the key space is partitioned across the axis
        n = int(ins.param("n", 1))
        ndv = tuple((k, max(v / max(n, 1), 1.0)) for k, v in first.ndv)
        return [replace(first, ndv=ndv)]

    if op in ("cf.Loop", "cf.While", "cf.Cond", "cf.Call"):
        inner = ins.param("P") or ins.param("Pthen")
        if inner is not None:
            inner_in = {r.name: s for r, s in
                        zip(inner.inputs, args[1:] if op == "cf.Cond" else args)}
            propagate(inner, stats, inner_in, env)
        return [RegStats(rows=first.rows, bytes_per_row=_bpr_of(o))
                for o in ins.outputs]

    # unknown instruction: pass the first input's estimate through, one per
    # output (the "leave it as is" contract of the rewrite rules)
    return [replace(first, bytes_per_row=_bpr_of(o)) for o in ins.outputs]
