"""Statistics catalog + estimate propagation for cost-based plan selection.

The paper's rewriting pipelines are "highly flexible and configurable"; to
*choose* between two valid physical plans (Tupleware/Flare-style) the driver
needs cardinality estimates.  This module carries them:

  * :class:`TableStats` / :class:`Statistics` — the per-table catalog:
    row count, bytes per row, and per-column NDV (number of distinct
    values, i.e. key cardinality).  Frontends thread these into
    ``CompileOptions`` via ``Catalog.stats``.
  * :class:`RegStats` — the estimate attached to one register while
    propagating through a (possibly already rewritten) program.
  * :func:`propagate` — abstract interpretation of a CVM program under the
    catalog: every pass output stays estimable because the rules understand
    the rewritten forms too (``cf.Split``/``ConcurrentExecute`` chunks,
    ``mesh.MeshExecute`` bodies, fused ``vec.FusedSelectAgg``, collectives).
    Unknown instructions pass their first input's estimate through — the
    same "leave it as is" contract the rewrite rules follow.

Estimates are deliberately coarse (constant filter selectivity, independent
keys); they only need to rank alternative physical plans, not predict
runtimes.  Calibration against measured compiles lives in ``cost.py``.
"""

from __future__ import annotations

import bisect
import hashlib
from dataclasses import dataclass, replace
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple

from ..core.program import Program, Register
from ..core.types import CollectionType, item_nbytes, is_coll

__all__ = [
    "TableStats", "Statistics", "RegStats", "Dictionary", "propagate",
    "stats_from_columns", "selectivity_of",
    "DEFAULT_SELECTIVITY", "DICT_MAX_CARD", "seq_chunks",
]

#: fraction of rows assumed to survive a filter when the predicate is opaque
DEFAULT_SELECTIVITY = 0.5

#: largest dictionary the catalog will build/carry per column — beyond this
#: the rank tables stop paying for themselves (the dense direct tiers would
#: be bucket-bound anyway) and the sorted tiers keep the query
DICT_MAX_CARD = 1 << 16


@dataclass(frozen=True)
class Dictionary:
    """A sorted value→rank encoding dictionary for one column.

    ``values`` is the sorted tuple of distinct values, so rank order is
    value order: rank comparisons preserve ordering predicates and
    rank-sorted output matches value-sorted output row for row.  Catalog
    dictionaries hold *physical* key values — plain ints, since string
    columns are already global-rank i32 codes by the time they reach the
    vec flavor (the documented str→i32 TPU adaptation); the Context-level
    global string dictionary holds the strings themselves.

    ``digest`` is a deterministic content hash: Python's string hash is
    process-randomized, and dictionaries participate in cross-process
    plan-store cache keys.
    """

    values: Tuple[Any, ...]
    digest: str

    @staticmethod
    def make(values: Iterable[Any]) -> "Dictionary":
        vals = tuple(values)
        h = hashlib.sha256()
        for v in vals:
            h.update(repr(v).encode("utf-8"))
            h.update(b"\x1f")
        return Dictionary(vals, h.hexdigest())

    @property
    def card(self) -> int:
        return len(self.values)

    @property
    def lo(self) -> Any:
        return self.values[0]

    @property
    def hi(self) -> Any:
        return self.values[-1]

    @property
    def dense(self) -> bool:
        """Integer values forming a contiguous range — ranks are then just
        an offset and no encode instruction is needed at all."""
        if self.card == 0 or isinstance(self.values[0], str):
            return False
        return int(self.hi) - int(self.lo) + 1 == self.card

    def rank_of(self, value: Any) -> Optional[int]:
        i = bisect.bisect_left(self.values, value)
        if i < self.card and self.values[i] == value:
            return i
        return None

    def insertion(self, value: Any, side: str = "left") -> int:
        """Rank-space insertion point of ``value`` (for range predicates:
        ``x < v  ⟺  rank(x) < insertion(v, 'left')``)."""
        fn = bisect.bisect_left if side == "left" else bisect.bisect_right
        return fn(self.values, value)

    def merge(self, other: "Dictionary") -> "Dictionary":
        return Dictionary.make(sorted(set(self.values) | set(other.values)))


# ---------------------------------------------------------------------------
# the catalog
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TableStats:
    """Statistics for one base table."""

    rows: int
    bytes_per_row: float = 8.0
    ndv: Tuple[Tuple[str, int], ...] = ()  # per-column distinct-value counts
    #: per-column value bounds (lo, hi), integral columns only — these are
    #: what make *dense-bucket* physical operators (vec.GroupAggDirect,
    #: domain-packed composite join keys) plannable
    domains: Tuple[Tuple[str, Tuple[int, int]], ...] = ()
    #: per-column value→rank dictionaries for key columns whose raw domain
    #: is sparse or absent (string codes, wide ints) — what makes the dense
    #: direct tiers reachable *via encoding* when ``domains`` can't
    dicts: Tuple[Tuple[str, Dictionary], ...] = ()

    def ndv_of(self, column: str, default: Optional[int] = None) -> Optional[int]:
        for name, n in self.ndv:
            if name == column:
                return n
        return default

    def domain_of(self, column: str) -> Optional[Tuple[int, int]]:
        for name, d in self.domains:
            if name == column:
                return d
        return None

    def dict_of(self, column: str) -> Optional[Dictionary]:
        for name, d in self.dicts:
            if name == column:
                return d
        return None

    @staticmethod
    def make(rows: int, bytes_per_row: float = 8.0,
             ndv: Optional[Mapping[str, int]] = None,
             domains: Optional[Mapping[str, Tuple[int, int]]] = None,
             dicts: Optional[Mapping[str, Dictionary]] = None,
             ) -> "TableStats":
        return TableStats(int(rows), float(bytes_per_row),
                          tuple(sorted((ndv or {}).items())),
                          tuple(sorted((k, (int(lo), int(hi)))
                                       for k, (lo, hi) in (domains or {}).items())),
                          tuple(sorted((dicts or {}).items(),
                                       key=lambda kv: kv[0])))

    def with_rows(self, rows: int) -> "TableStats":
        """An *observed* copy: measured row count, everything else kept.

        NDV caps ride along — a measured table can't have more distinct
        values in a column than it has rows."""
        rows = int(rows)
        ndv = tuple((k, min(v, max(rows, 1))) for k, v in self.ndv)
        return replace(self, rows=rows, ndv=ndv)


@dataclass(frozen=True)
class Statistics:
    """Per-table statistics catalog (hashable: part of the plan-cache key)."""

    tables: Tuple[Tuple[str, TableStats], ...] = ()
    #: the session-wide string dictionary (``Context.statistics()`` builds
    #: it over *all* registered string values): physical string columns are
    #: its i32 rank codes, so cross-table joins compare consistently and
    #: string literals in predicates can be remapped into code space
    global_dict: Optional[Dictionary] = None

    @staticmethod
    def make(tables: Mapping[str, TableStats],
             global_dict: Optional[Dictionary] = None) -> "Statistics":
        return Statistics(tuple(sorted(tables.items())), global_dict)

    def table(self, name: str) -> Optional[TableStats]:
        for n, t in self.tables:
            if n == name:
                return t
        return None

    def cache_key(self) -> Tuple:
        return tuple((n, t.rows, t.bytes_per_row, t.ndv, t.domains,
                      tuple((c, d.digest) for c, d in t.dicts))
                     for n, t in self.tables) + (
            self.global_dict.digest if self.global_dict else None,)

    def with_observed_rows(self, rows: Mapping[str, int]) -> "Statistics":
        """Fold measured base-table cardinalities (from traced executions —
        see ``repro.obs.feedback``) into the catalog: measured row counts
        override the estimates, tables the catalog never saw are added with
        default per-row bytes, and NDV/domain knowledge is preserved."""
        tables = {n: t for n, t in self.tables}
        for name, n_rows in rows.items():
            base = tables.get(name)
            tables[name] = (base.with_rows(n_rows) if base is not None
                            else TableStats(int(n_rows)))
        return Statistics.make(tables, self.global_dict)


def stats_from_columns(columns: Mapping[str, Any],
                       global_dict: Optional[Dictionary] = None) -> TableStats:
    """Exact statistics from in-memory numpy columns (small-data frontends).

    String columns are measured in their *physical* representation — i32
    rank codes against ``global_dict`` (4 bytes/row, no raw domain entry:
    the raw string domain is unordered-from-the-planner's-view until
    encoded).  Per-column :class:`Dictionary` entries are built exactly
    when they could unlock the dense direct tiers: always for string
    columns, and for integer columns whose value range is sparse
    (span > NDV), capped at :data:`DICT_MAX_CARD` distinct values.
    """
    import numpy as np

    rows = len(next(iter(columns.values()))) if columns else 0
    bpr = float(sum(4.0 if np.asarray(v).dtype.kind in ("U", "S")
                    else np.asarray(v).dtype.itemsize
                    for v in columns.values())) or 8.0
    ndv = {k: int(np.unique(np.asarray(v)).size) for k, v in columns.items()}
    domains = {}
    dicts = {}
    for k, v in columns.items():
        a = np.asarray(v)
        if rows == 0:
            continue
        if a.dtype == np.bool_:
            domains[k] = (0, 1)
        elif np.issubdtype(a.dtype, np.integer):
            domains[k] = (int(a.min()), int(a.max()))
            uniq = np.unique(a)
            span = int(uniq[-1]) - int(uniq[0]) + 1
            if uniq.size <= DICT_MAX_CARD and span > uniq.size:
                dicts[k] = Dictionary.make(int(x) for x in uniq)
        elif a.dtype.kind in ("U", "S") and global_dict is not None:
            uniq = np.unique(a)
            if uniq.size <= DICT_MAX_CARD:
                gvals = np.asarray(global_dict.values)
                codes = np.searchsorted(gvals, uniq)
                dicts[k] = Dictionary.make(int(c) for c in codes)
    return TableStats.make(rows, bpr, ndv, domains, dicts)


# ---------------------------------------------------------------------------
# register estimates
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RegStats:
    """Estimated properties of one register's value.

    For split registers (``Seq[n]`` of chunks) the estimate is *per chunk*,
    matching how the backends execute them.
    """

    rows: float
    bytes_per_row: float = 8.0
    ndv: Tuple[Tuple[str, float], ...] = ()
    #: per-column integral value bounds, carried through rewrites so the
    #: lowering can plan dense-bucket operators on derived registers
    domains: Tuple[Tuple[str, Tuple[int, int]], ...] = ()
    #: per-column encoding dictionaries, carried through rewrites (incl.
    #: MeshExecute bodies) so derived registers keep their encodings
    dicts: Tuple[Tuple[str, Dictionary], ...] = ()

    @property
    def bytes(self) -> float:
        return self.rows * self.bytes_per_row

    def ndv_of(self, column: str, default: Optional[float] = None) -> Optional[float]:
        for name, n in self.ndv:
            if name == column:
                return n
        return default

    def domain_of(self, column: str) -> Optional[Tuple[int, int]]:
        for name, d in self.domains:
            if name == column:
                return d
        return None

    def dict_of(self, column: str) -> Optional[Dictionary]:
        for name, d in self.dicts:
            if name == column:
                return d
        return None

    def scaled(self, factor: float) -> "RegStats":
        rows = max(self.rows * factor, 1.0)
        ndv = tuple((k, min(v, rows)) for k, v in self.ndv)
        return replace(self, rows=rows, ndv=ndv)

    def group_rows(self, keys: Tuple[str, ...], cap: Optional[int] = None) -> float:
        """Estimated distinct groups for ``keys`` (independence assumption)."""
        est = 1.0
        for k in keys:
            est *= self.ndv_of(k) or min(self.rows, 64.0)
        est = min(est, self.rows)
        if cap is not None:
            est = min(est, float(cap))
        return max(est, 1.0)


def _bpr_of(reg: Register, default: float = 8.0) -> float:
    t = reg.type
    while is_coll(t) and isinstance(t, CollectionType) and is_coll(t.item):
        t = t.item  # unwrap Seq-of-chunks down to the element collection
    return float(item_nbytes(t, int(default)))


def _seq_n(reg: Register) -> int:
    t = reg.type
    if is_coll(t):
        n = t.attr("n")
        if n:
            return int(n)
    return 1


def seq_chunks(reg: Register) -> int:
    """Number of chunks of a split ``Seq[n]`` register (1 when unsplit) —
    how per-chunk estimates scale to the global cardinality."""
    return _seq_n(reg)


# ---------------------------------------------------------------------------
# predicate selectivity
# ---------------------------------------------------------------------------


def _col_bounds(rs: RegStats, name: str):
    """Integral (lo, hi) for a column: the raw domain when known, else the
    code-space bounds of its dictionary (post-lowering predicates compare
    against codes, so dictionary bounds are the right domain there)."""
    d = rs.domain_of(name)
    if d is not None:
        return int(d[0]), int(d[1])
    dc = rs.dict_of(name)
    if dc is not None and dc.card > 0 and not isinstance(dc.lo, str):
        return int(dc.lo), int(dc.hi)
    return None


def _cmp_selectivity(cmp_op: str, col: str, value: Any, rs: RegStats,
                     global_dict: Optional[Dictionary]) -> float:
    if isinstance(value, str):
        # string literal against an i32-coded column: translate the literal
        # into global-code space first (the same mapping the lowering's
        # predicate remap applies)
        if global_dict is None:
            return DEFAULT_SELECTIVITY
        if cmp_op in ("eq", "ne"):
            present = global_dict.rank_of(value) is not None
            if cmp_op == "eq" and not present:
                return 0.0
            if cmp_op == "ne" and not present:
                return 1.0
            ndv = rs.ndv_of(col) or global_dict.card
            return 1.0 / max(float(ndv), 1.0) if cmp_op == "eq" \
                else 1.0 - 1.0 / max(float(ndv), 1.0)
        # x < v ⟺ code < insertion_left(v); x <= v ⟺ code < insertion_right
        if cmp_op in ("lt", "le"):
            bound = global_dict.insertion(
                value, "left" if cmp_op == "lt" else "right")
            return _cmp_selectivity("lt", col, bound, rs, None)
        if cmp_op in ("gt", "ge"):
            bound = global_dict.insertion(
                value, "right" if cmp_op == "gt" else "left")
            return _cmp_selectivity("ge", col, bound, rs, None)
        return DEFAULT_SELECTIVITY

    bounds = _col_bounds(rs, col)
    if cmp_op in ("eq", "ne"):
        ndv = rs.ndv_of(col)
        if ndv is None and bounds is not None:
            ndv = bounds[1] - bounds[0] + 1
        if ndv is None:
            return DEFAULT_SELECTIVITY
        eq = 1.0 / max(float(ndv), 1.0)
        if bounds is not None and not (bounds[0] <= value <= bounds[1]):
            eq = 0.0  # min/max pruning: the literal is outside the domain
        return eq if cmp_op == "eq" else 1.0 - eq
    if bounds is None:
        return DEFAULT_SELECTIVITY
    lo, hi = bounds
    span = float(hi - lo + 1)
    try:
        v = float(value)
    except (TypeError, ValueError):
        return DEFAULT_SELECTIVITY
    if cmp_op == "lt":
        frac = (v - lo) / span
    elif cmp_op == "le":
        frac = (v - lo + 1) / span
    elif cmp_op == "gt":
        frac = (hi - v) / span
    elif cmp_op == "ge":
        frac = (hi - v + 1) / span
    else:
        return DEFAULT_SELECTIVITY
    return min(max(frac, 0.0), 1.0)


_FLIP = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le", "eq": "eq", "ne": "ne"}


def selectivity_of(pred, rs: RegStats,
                   global_dict: Optional[Dictionary] = None) -> float:
    """Estimated fraction of rows satisfying ``pred`` under ``rs``.

    Range and equality predicates over columns with known domains (or
    dictionaries) get min/max pruning; conjunctions multiply under the
    independence assumption; anything opaque falls back to
    :data:`DEFAULT_SELECTIVITY`.  Works both on source programs (string
    literals resolve through ``global_dict``) and on lowered ones (integer
    code literals resolve through dictionary code bounds), so the estimate
    the optimizer prints matches the plan that ran.
    """
    from ..core.expr import BinOp, Col, Const, UnOp

    def sel(e) -> float:
        if isinstance(e, Const):
            return 1.0 if bool(e.value) else 0.0
        if isinstance(e, UnOp) and e.op == "not":
            return min(max(1.0 - sel(e.arg), 0.0), 1.0)
        if isinstance(e, BinOp):
            if e.op == "and":
                return sel(e.lhs) * sel(e.rhs)
            if e.op == "or":
                a, b = sel(e.lhs), sel(e.rhs)
                return min(a + b - a * b, 1.0)
            if e.op in _FLIP:
                lhs, rhs = e.lhs, e.rhs
                if isinstance(lhs, Col) and isinstance(rhs, Const):
                    return _cmp_selectivity(e.op, lhs.name, rhs.value, rs,
                                            global_dict)
                if isinstance(lhs, Const) and isinstance(rhs, Col):
                    return _cmp_selectivity(_FLIP[e.op], rhs.name, lhs.value,
                                            rs, global_dict)
        return DEFAULT_SELECTIVITY

    return min(max(sel(pred), 0.0), 1.0)


# ---------------------------------------------------------------------------
# propagation
# ---------------------------------------------------------------------------


class StatsEnv:
    """Register → RegStats over a program tree (nested scopes included)."""

    def __init__(self) -> None:
        self._env: Dict[Tuple[int, str], RegStats] = {}

    def get(self, program: Program, reg: Register) -> RegStats:
        got = self._env.get((id(program), reg.name))
        if got is not None:
            return got
        # total fallback: estimate from the type alone
        cap = reg.type.attr("max_count") if is_coll(reg.type) else None
        return RegStats(rows=float(cap or 64), bytes_per_row=_bpr_of(reg))

    def set(self, program: Program, reg: Register, s: RegStats) -> None:
        self._env[(id(program), reg.name)] = s


def propagate(program: Program, stats: Optional[Statistics] = None,
              input_stats: Optional[Mapping[str, RegStats]] = None,
              env: Optional[StatsEnv] = None) -> StatsEnv:
    """Propagate table statistics through a program (and nested programs).

    Works on any IR flavor mix, before or after rewriting: the rules cover
    the relational ops, their vec/mesh lowerings, and the control-flow
    scaffolding the parallelization rewrite introduces, so estimates
    "survive" ``Parallelize``, ``FuseSelectAgg``, and ``LowerToMesh``.
    """
    env = env or StatsEnv()
    for r in program.inputs:
        if input_stats and r.name in input_stats:
            env.set(program, r, input_stats[r.name])
    for ins in program.body:
        args = [env.get(program, r) for r in ins.inputs]
        outs = _propagate_ins(ins, args, stats, env, program)
        for reg, s in zip(ins.outputs, outs):
            env.set(program, reg, s)
    return env


def _scan_stats(table: str, reg: Register, stats: Optional[Statistics]) -> RegStats:
    ts = stats.table(table) if stats is not None else None
    if ts is None:
        cap = reg.type.attr("max_count") if is_coll(reg.type) else None
        return RegStats(rows=float(cap or 1024), bytes_per_row=_bpr_of(reg))
    return RegStats(rows=float(ts.rows), bytes_per_row=float(ts.bytes_per_row),
                    ndv=tuple((k, float(v)) for k, v in ts.ndv),
                    domains=tuple(ts.domains), dicts=tuple(ts.dicts))


def _propagate_ins(ins, args, stats, env: StatsEnv, program: Program):
    op = ins.opcode
    first = args[0] if args else RegStats(rows=1.0)

    if op in ("rel.Scan", "vec.ScanVec"):
        return [_scan_stats(ins.param("table"), ins.outputs[0], stats)]

    if op in ("rel.Select", "vec.MaskSelect"):
        gd = stats.global_dict if stats is not None else None
        return [first.scaled(selectivity_of(ins.param("pred"), first, gd))]

    if op in ("rel.Proj", "vec.ProjVec", "vec.SortByKey", "rel.OrderBy",
              "vec.Compact"):
        return [replace(first.scaled(1.0), bytes_per_row=_bpr_of(ins.outputs[0]))]

    if op in ("rel.ExProj", "vec.ExProjVec"):
        # computed columns invalidate their NDV/domain estimates: keep them
        # only where the expression is the identity Col — a stale domain
        # would make a downstream dense-bucket plan silently merge groups
        from ..core.expr import Col
        identity = {n for n, e in tuple(ins.param("exprs") or ())
                    if isinstance(e, Col) and e.name == n}
        return [replace(first.scaled(1.0), bytes_per_row=_bpr_of(ins.outputs[0]),
                        ndv=tuple((k, v) for k, v in first.ndv if k in identity),
                        domains=tuple((k, d) for k, d in first.domains
                                      if k in identity),
                        dicts=tuple((k, d) for k, d in first.dicts
                                    if k in identity))]

    if op in ("rel.Aggr", "vec.AggrVec", "vec.FusedSelectAgg",
              "vec.FinalizeSingle", "rel.CombinePartials"):
        return [RegStats(rows=1.0, bytes_per_row=_bpr_of(ins.outputs[0]))]

    if op in ("rel.GroupByAggr", "vec.GroupAggSorted", "vec.GroupAggDirect"):
        keys = tuple(ins.param("keys") or ())
        cap = ins.param("max_groups")
        groups = first.group_rows(keys, int(cap) if cap else None)
        ndv = tuple((k, min(first.ndv_of(k) or groups, groups)) for k in keys)
        domains = tuple((k, d) for k in keys
                        for d in (first.domain_of(k),) if d is not None)
        dicts = tuple((k, d) for k in keys
                      for d in (first.dict_of(k),) if d is not None)
        return [RegStats(rows=groups, bytes_per_row=_bpr_of(ins.outputs[0]),
                         ndv=ndv, domains=domains, dicts=dicts)]

    if op in ("rel.Join", "vec.MergeJoinSorted", "vec.HashJoinDirect"):
        left = args[0]
        out = replace(left.scaled(1.0), bytes_per_row=_bpr_of(ins.outputs[0]),
                      ndv=tuple(left.ndv) + tuple(args[1].ndv),
                      domains=tuple(left.domains) + tuple(args[1].domains),
                      dicts=tuple(left.dicts) + tuple(args[1].dicts))
        return [out]

    if op == "vec.DictEncode":
        # encoded key columns become dense ranks [0, card): their domain is
        # the rank space and their raw-value dictionary no longer applies
        cards = {c: int(n) for c, n in
                 zip(ins.param("cols"), ins.param("cards"))}
        domains = tuple((k, d) for k, d in first.domains if k not in cards)
        domains += tuple(sorted((c, (0, n - 1)) for c, n in cards.items()))
        ndv = tuple((k, min(v, cards[k]) if k in cards else v)
                    for k, v in first.ndv)
        return [replace(first, domains=domains, ndv=ndv,
                        dicts=tuple((k, d) for k, d in first.dicts
                                    if k not in cards))]

    if op == "vec.DictDecode":
        # ranks gathered back to raw values: the rank-space domains no
        # longer describe the column
        cols = set(ins.param("cols"))
        return [replace(first,
                        domains=tuple((k, d) for k, d in first.domains
                                      if k not in cols))]

    if op == "vec.FusedJoinGroupAgg":
        # select→join→group in one op: the grouping sees the joined columns
        left = args[0]
        gd = stats.global_dict if stats is not None else None
        pred = ins.param("pred")
        sel = selectivity_of(pred, left, gd) if pred is not None else 1.0
        joined = replace(left.scaled(sel),
                         ndv=tuple(left.ndv) + tuple(args[1].ndv),
                         domains=tuple(left.domains) + tuple(args[1].domains),
                         dicts=tuple(left.dicts) + tuple(args[1].dicts))
        keys = tuple(ins.param("keys") or ())
        cap = ins.param("max_groups")
        groups = joined.group_rows(keys, int(cap) if cap else None)
        ndv = tuple((k, min(joined.ndv_of(k) or groups, groups)) for k in keys)
        domains = tuple((k, d) for k in keys
                        for d in (joined.domain_of(k),) if d is not None)
        return [RegStats(rows=groups, bytes_per_row=_bpr_of(ins.outputs[0]),
                         ndv=ndv, domains=domains)]

    if op in ("rel.Limit", "vec.LimitVec", "vec.TopKVec"):
        k = float(ins.param("k", first.rows))
        return [first.scaled(min(1.0, k / max(first.rows, 1.0)))]

    if op == "cf.Split":
        n = int(ins.param("n"))
        return [first.scaled(1.0 / max(n, 1))]

    if op == "cf.Broadcast":
        return [first]

    if op == "cf.Merge":
        n = _seq_n(ins.inputs[0])
        return [first.scaled(float(n))]

    if op == "cf.CombineChunks":
        return [first]

    if op == "cf.TakeChunk":
        return [first]

    if op in ("cf.ConcurrentExecute", "mesh.MeshExecute"):
        inner: Program = ins.param("P")
        inner_in = {r.name: s for r, s in zip(inner.inputs, args)}
        propagate(inner, stats, inner_in, env)
        return [env.get(inner, r) for r in inner.results]

    if op == "mesh.AllReduce":
        return [first]

    if op == "mesh.AllGatherVec":
        n = int(ins.param("n", 1))
        return [first.scaled(float(n))]

    if op == "mesh.ExchangeByKey":
        # redistribution: per-shard row count is preserved on average, but
        # the key space is partitioned across the axis
        n = int(ins.param("n", 1))
        ndv = tuple((k, max(v / max(n, 1), 1.0)) for k, v in first.ndv)
        return [replace(first, ndv=ndv)]

    if op in ("cf.Loop", "cf.While", "cf.Cond", "cf.Call"):
        inner = ins.param("P") or ins.param("Pthen")
        if inner is not None:
            inner_in = {r.name: s for r, s in
                        zip(inner.inputs, args[1:] if op == "cf.Cond" else args)}
            propagate(inner, stats, inner_in, env)
        return [RegStats(rows=first.rows, bytes_per_row=_bpr_of(o))
                for o in ins.outputs]

    # unknown instruction: pass the first input's estimate through, one per
    # output (the "leave it as is" contract of the rewrite rules)
    return [replace(first, bytes_per_row=_bpr_of(o)) for o in ins.outputs]
