"""Cost model for choosing between alternative physical lowerings.

Tupleware's observation (and Flare's, for native Spark plans) is that a
*lightweight* cost model choosing between execution strategies is where
compiled analytics wins — the model only has to rank a handful of candidate
plans, not predict wall times.  Costs are abstract "byte-ops":

  * local work:   rows × bytes/row          (× log rows for sorts)
  * network work: rows × bytes/row × C_NET  (gathers, exchanges)
  * collectives:  fixed startup A_COLL      (all-to-all / all-reduce latency)

Work inside a ``MeshExecute``/``ConcurrentExecute`` body is costed once —
it runs on every shard *in parallel* — while work after a ``cf.Merge`` of a
mesh output runs on the full gathered data on one device.  That asymmetry
is exactly what separates *gather-then-aggregate* from
*exchange-by-key + per-shard aggregation*.

Estimated costs are calibrated into seconds by :class:`CostCalibration`,
an EMA over the driver's measured compile+pass observations
(``PassRecord`` history); the calibration is persisted by the plan store so
estimates improve across processes.  Calibration scales the reported
seconds — it never reorders candidates.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from ..core.program import Instruction, Program
from .stats import Statistics, StatsEnv, propagate

__all__ = [
    "estimate_cost", "CostModel", "CostCalibration",
    "Candidate", "PlanDecision", "CALIBRATION", "EXEC_CALIBRATION",
]

#: relative cost of moving one byte across the interconnect vs touching it
C_NET = 8.0
#: fixed startup cost of a collective, in local byte-op units — the
#: equivalent of ~32 KiB over the interconnect, so a collective only pays
#: off once it saves that much gathered/serialized traffic
A_COLL = 262_144.0


class CostModel:
    """Walk a lowered program under propagated statistics and sum op costs."""

    def __init__(self, net: float = C_NET, coll: float = A_COLL) -> None:
        self.net = net
        self.coll = coll

    # ------------------------------------------------------------------
    def estimate(self, program: Program, stats: Optional[Statistics] = None) -> float:
        env = propagate(program, stats)
        return self._program_cost(program, env)

    # ------------------------------------------------------------------
    def _program_cost(self, program: Program, env: StatsEnv) -> float:
        producers = program.producers()
        total = 0.0
        for ins in program.body:
            total += self._op_cost(ins, program, env, producers)
        return total

    def _op_cost(self, ins: Instruction, program: Program, env: StatsEnv,
                 producers: Dict[str, Instruction]) -> float:
        op = ins.opcode
        args = [env.get(program, r) for r in ins.inputs]
        outs = [env.get(program, r) for r in ins.outputs]
        rows = args[0].rows if args else 1.0
        bpr = args[0].bytes_per_row if args else 8.0

        if op in ("cf.ConcurrentExecute", "mesh.MeshExecute"):
            # SPMD: every shard runs the body concurrently — cost it once
            return self._program_cost(ins.param("P"), env)

        if op in ("vec.SortByKey", "rel.OrderBy"):
            return rows * max(math.log2(max(rows, 2.0)), 1.0) * bpr

        if op in ("vec.GroupAggSorted", "rel.GroupByAggr"):
            return 2.0 * rows * bpr

        if op == "vec.GroupAggDirect":
            # sort-free dense buckets: one pass over the rows plus the
            # bucket-table epilogue (build + compact) — the term that grows
            # with the key domain and hands the win back to the sorted tier
            # at high NDV
            nb = float(ins.param("num_buckets") or 1.0)
            return rows * bpr + 2.0 * nb * outs[0].bytes_per_row

        if op == "vec.DictEncode":
            # rank lookup per encoded key column: log2(card) searchsorted
            # probes of 4-byte ranks, or one O(1) gather through the dense
            # remap table — the cost the elided sort has to beat
            total = 0.0
            for mode, card in zip(ins.param("modes"), ins.param("cards")):
                per = (max(math.log2(max(float(card), 2.0)), 1.0)
                       if mode == "searchsorted" else 1.0)
                total += rows * 4.0 * per
            return total

        if op == "vec.DictDecode":
            # decode-late: one gather per surviving key column on the
            # compacted output, never the full input
            return outs[0].rows * 4.0 * len(tuple(ins.param("cols")))

        if op in ("vec.MergeJoinSorted", "rel.Join"):
            right = args[1] if len(args) > 1 else args[0]
            probe = rows * max(math.log2(max(right.rows, 2.0)), 1.0) * bpr
            return probe + right.rows * right.bytes_per_row

        if op == "vec.HashJoinDirect":
            # sort-free direct table: one linear pass over each side plus the
            # dense-table build/probe epilogue — the bucket term grows with
            # the key domain and hands the win back to the sorted tier at
            # high NDV, exactly like GroupAggDirect
            right = args[1] if len(args) > 1 else args[0]
            nb = float(ins.param("num_buckets") or 1.0)
            if ins.param("key_domains") is not None:
                nb = 1.0
                for lo, hi in ins.param("key_domains"):
                    nb *= float(hi) - float(lo) + 1.0
            # the per-bucket weight is the i32 slot ×8: a scatter-min build
            # plus a gathered probe cost well more per bucket than the
            # groupby tier's segment-sum rows (calibrated on the BENCH_8
            # cells so the sorted tier takes back sparse ~2^19 domains)
            return (rows * bpr + right.rows * right.bytes_per_row
                    + 8.0 * nb * 4.0)

        if op == "vec.FusedJoinGroupAgg":
            # single fused pass: probe side + build side touched once, plus
            # the join direct table and the group bucket epilogue; no join
            # materialization / compact term at all
            right = args[1] if len(args) > 1 else args[0]
            nbj = float(ins.param("join_num_buckets") or 1.0)
            nbg = float(ins.param("num_buckets") or 1.0)
            return (rows * bpr + right.rows * right.bytes_per_row
                    + 8.0 * nbj * 4.0 + 2.0 * nbg * outs[0].bytes_per_row)

        if op == "cf.Merge":
            src = producers.get(ins.inputs[0].name)
            gathered = outs[0].rows * outs[0].bytes_per_row
            if src is not None and src.opcode == "mesh.MeshExecute":
                # gather: every shard's chunk crosses the interconnect and
                # all downstream work on the result is single-device
                return gathered * self.net
            return gathered

        if op == "mesh.ExchangeByKey":
            return self.coll + rows * bpr * self.net

        if op == "mesh.AllReduce":
            return self.coll + rows * bpr * self.net

        if op == "mesh.AllGatherVec":
            return self.coll + outs[0].rows * outs[0].bytes_per_row * self.net

        if op in ("cf.Split", "cf.Broadcast", "cf.TakeChunk"):
            return rows * bpr * 0.1

        if op in ("rel.Scan", "vec.ScanVec", "df.Source", "la.Literal"):
            return 0.0

        # default: one pass over the input rows
        return rows * bpr


_DEFAULT_MODEL = CostModel()


def estimate_cost(program: Program, stats: Optional[Statistics] = None,
                  model: Optional[CostModel] = None) -> float:
    """Estimated cost (abstract byte-op units) of a lowered program."""
    return (model or _DEFAULT_MODEL).estimate(program, stats)


# ---------------------------------------------------------------------------
# calibration: abstract units → seconds, from measured observations
# ---------------------------------------------------------------------------


@dataclass
class CostCalibration:
    """EMA mapping of estimated cost units to measured seconds."""

    scale: float = 0.0
    n: int = 0

    def update(self, est_cost: float, measured_s: float) -> None:
        if est_cost <= 0.0 or measured_s <= 0.0:
            return
        obs = measured_s / est_cost
        self.scale = obs if self.n == 0 else 0.8 * self.scale + 0.2 * obs
        self.n += 1

    def seconds(self, est_cost: float) -> Optional[float]:
        return est_cost * self.scale if self.n else None

    def to_dict(self) -> Dict[str, Any]:
        return {"scale": self.scale, "n": self.n}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "CostCalibration":
        return CostCalibration(scale=float(d.get("scale", 0.0)),
                               n=int(d.get("n", 0)))


#: process-wide calibration, seeded from the plan store when one is used
CALIBRATION = CostCalibration()

#: the runtime sibling of :data:`CALIBRATION`: abstract plan-cost units →
#: measured *execution* seconds, fed by traced executions through
#: ``repro.obs.feedback.FEEDBACK`` — the measured leg of the
#: estimate-vs-actual feedback loop
EXEC_CALIBRATION = CostCalibration()


# ---------------------------------------------------------------------------
# decisions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Candidate:
    """One enumerated lowering alternative and its estimated cost."""

    strategy: Tuple[Tuple[str, str], ...]
    est_cost: float
    size: int
    lower_s: float

    def label(self) -> str:
        return ", ".join(f"{k}={v}" for k, v in self.strategy) or "(default)"


@dataclass(frozen=True)
class PlanDecision:
    """Outcome of the costed search: candidates, winner, provenance."""

    candidates: Tuple[Candidate, ...]
    chosen: int
    source: str  # "search" | "store" | "default"
    est_seconds: Optional[float] = None
    #: measured compile+lowering seconds of the winner (the PassRecord
    #: observation that feeds calibration) — NOT plan execution time
    measured_s: Optional[float] = None

    @property
    def winner(self) -> Candidate:
        return self.candidates[self.chosen]

    def render(self) -> str:
        lines = [f"cost search ({self.source}): "
                 f"{len(self.candidates)} candidate(s), "
                 f"winner {self.winner.label()}",
                 "| strategy | est cost | IR size | lower ms | chosen |",
                 "|---|---:|---:|---:|:---:|"]
        for i, c in enumerate(self.candidates):
            mark = "✓" if i == self.chosen else ""
            lines.append(f"| {c.label()} | {c.est_cost:,.0f} | {c.size} "
                         f"| {c.lower_s * 1e3:.3f} | {mark} |")
        est = (f"{self.est_seconds * 1e3:.3f} ms" if self.est_seconds
               else "uncalibrated")
        meas = (f"{self.measured_s * 1e3:.3f} ms" if self.measured_s
                else "n/a")
        lines.append(f"estimated {est} vs measured compile {meas}")
        return "\n".join(lines)

    def records(self) -> List[Dict[str, Any]]:
        return [
            {"strategy": dict(c.strategy), "est_cost": c.est_cost,
             "size": c.size, "lower_s": c.lower_s,
             "chosen": i == self.chosen, "source": self.source,
             "est_seconds": self.est_seconds, "measured_s": self.measured_s}
            for i, c in enumerate(self.candidates)
        ]
