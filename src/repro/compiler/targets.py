"""Backend target registry: declarative, flavor-aware lowering paths.

The paper's claim (§3.5–§3.6) is that rewriting pipelines are "highly
flexible and configurable, such that every frontend/backend combination can
do the rewritings that are best suited for that combination".  This module
makes that concrete: each backend registers a :class:`Target` declaring

  * its name (``interp`` / ``local`` / ``spmd`` / ``multipod`` / ...),
  * the IR flavors its executables accept after lowering,
  * a declarative *lowering path* — an ordered tuple of :class:`Stage`
    factories that, given the :class:`CompileOptions`, produce the rewrite
    passes to run (canonicalize → optional parallelize → flavor lowering →
    fusion → backend-specific rules such as ``LowerToMesh``),
  * how to construct the backend object, and
  * what kind of source collections its executables consume.

Adding a backend is now: implement emitters, then ``register_target`` a
lowering path — no new copy of the pipeline anywhere.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from ..core.passes import (
    CommonSubexpressionElimination,
    DeadCodeElimination,
    FuseJoinGroupAgg,
    FuseSelectAgg,
    FuseSelectGroupAgg,
    LowerToMesh,
    Parallelize,
    PushCombineIntoMesh,
    PushGroupedCombineIntoMesh,
)
from ..core.passes.lower_vec import Catalog, LowerRelToVec

__all__ = [
    "CompileOptions", "Stage", "StrategyStage", "Choice", "Target",
    "register_target", "get_target", "available_targets",
    "CANONICALIZE", "PARALLELIZE", "LOWER_REL_TO_VEC", "FUSE", "LOWER_TO_MESH",
    "FUSE_CHOICE", "GROUPED_RECOMBINE", "GROUPBY_CHOICE", "JOIN_CHOICE",
    "ENCODE_CHOICE",
]


# ---------------------------------------------------------------------------
# options
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CompileOptions:
    """Everything a lowering path may depend on — and the plan-cache key covers."""

    parallel: Optional[int] = None
    use_kernels: bool = False
    fuse: bool = True
    axis: str = "workers"
    jit: bool = True
    collectives: bool = True
    catalog: Optional[Catalog] = None
    mesh: Any = None
    parallelize_targets: Optional[Tuple[str, ...]] = None
    #: None → fixed default lowering path; "cost" → enumerate the target's
    #: Choice points and pick the cheapest candidate under the cost model
    optimize: Optional[str] = None
    #: explicit strategy overrides ((choice-name, label), ...) — forces
    #: specific variants regardless of the optimizer
    strategy: Optional[Tuple[Tuple[str, str], ...]] = None
    #: resource-admission byte budget for the plan's estimated peak working
    #: set (see ``repro.robust.admission``); None → the
    #: ``REPRO_MEM_BUDGET_BYTES`` environment default (off when unset)
    memory_budget: Optional[int] = None
    #: streaming target only: the source table delivered as micro-batches
    stream_table: Optional[str] = None
    #: streaming target only: micro-batch capacity (rows per batch); the
    #: stream table is lowered at this capacity, so per-batch cost is
    #: O(batch), not O(full table)
    batch_rows: Optional[int] = None

    def stats(self):
        return self.catalog.stats if self.catalog is not None else None

    def cache_key(self) -> Tuple:
        cat = None
        if self.catalog is not None:
            stats = self.catalog.stats
            cat = (tuple(sorted(self.catalog.capacities.items())),
                   self.catalog.default_max_groups,
                   self.catalog.join_selectivity,
                   stats.cache_key() if stats is not None else None)
        mesh_key = None
        if self.mesh is not None:
            axis_names = tuple(getattr(self.mesh, "axis_names", ()))
            shape = getattr(self.mesh, "shape", None)
            if hasattr(shape, "items"):
                shape = tuple(shape.items())
            devices = getattr(self.mesh, "devices", None)
            # device identity matters: an equally-shaped mesh over different
            # devices must not reuse an executable bound to the old devices
            dev_ids = (tuple(int(d.id) for d in devices.flat)
                       if devices is not None else None)
            mesh_key = (axis_names, shape, dev_ids)
        return (self.parallel, self.use_kernels, self.fuse, self.axis,
                self.jit, self.collectives, self.parallelize_targets,
                cat, mesh_key, self.optimize, self.strategy,
                self.memory_budget, self.stream_table, self.batch_rows)


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Stage:
    """One named step of a lowering path: options → a sequence of passes.

    A "pass" here is anything with ``.name`` and ``.apply(program)`` —
    fixpoint rules (:class:`~repro.core.passes.rewriter.Pass`) and one-shot
    reconstructions (:class:`~repro.core.passes.lower_vec.LowerRelToVec`)
    alike.  Returning ``[]`` makes the stage a no-op for these options.
    """

    name: str
    build: Callable[[CompileOptions], Sequence[Any]]


@dataclass(frozen=True)
class StrategyStage(Stage):
    """A Stage whose passes depend on the WHOLE bound strategy.

    ``build`` receives ``(opts, chosen)`` — the full choice-name → label
    binding of the candidate being lowered.  This is what lets several
    Choices (``groupby``, ``join``) parameterize one shared pass
    (:class:`LowerRelToVec`) instead of multiplying variant Stages per
    label combination.
    """

    build: Callable[[CompileOptions, Dict[str, str]], Sequence[Any]]


def _canonicalize(opts: CompileOptions) -> Sequence[Any]:
    return [CommonSubexpressionElimination(), DeadCodeElimination()]


def _parallelize(opts: CompileOptions) -> Sequence[Any]:
    if opts.parallel and opts.parallel > 1:
        targets = set(opts.parallelize_targets) if opts.parallelize_targets else None
        return [Parallelize(n=opts.parallel, targets=targets)]
    return []


def _effective_catalog(opts: CompileOptions) -> Catalog:
    """The catalog the vec lowering sees.

    For streaming compiles the stream table's capacity (and its observed
    row count, when statistics are present) is rebound to the micro-batch
    capacity: the per-batch segment of the split plan must size its
    intermediates — and be costed — at O(batch), not O(full table)."""
    from dataclasses import replace as _replace

    cat = opts.catalog if opts.catalog is not None else Catalog()
    if opts.stream_table is None:
        return cat
    rows = int(opts.batch_rows or 256)
    caps = dict(cat.capacities)
    caps[opts.stream_table] = rows
    stats = cat.stats
    if stats is not None:
        stats = stats.with_observed_rows({opts.stream_table: rows})
    return _replace(cat, capacities=caps, stats=stats)


def _lower_rel_to_vec(opts: CompileOptions) -> Sequence[Any]:
    return [LowerRelToVec(_effective_catalog(opts))]


def _fuse(opts: CompileOptions) -> Sequence[Any]:
    if opts.fuse:
        return [FuseSelectAgg(), FuseSelectGroupAgg(), FuseJoinGroupAgg(),
                DeadCodeElimination()]
    return []


def _lower_to_mesh(opts: CompileOptions) -> Sequence[Any]:
    rules: list = [LowerToMesh(opts.axis)]
    if opts.collectives:
        rules.append(PushCombineIntoMesh())
    return rules


CANONICALIZE = Stage("canonicalize", _canonicalize)
PARALLELIZE = Stage("parallelize", _parallelize)
LOWER_REL_TO_VEC = Stage("lower-rel-to-vec", _lower_rel_to_vec)
FUSE = Stage("fuse", _fuse)
LOWER_TO_MESH = Stage("lower-to-mesh", _lower_to_mesh)


# ---------------------------------------------------------------------------
# strategy choices
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Choice:
    """A strategy point in a lowering path: named alternative Stage variants.

    Under the default compile the ``default`` variant runs; under
    ``optimize="cost"`` the driver enumerates every available variant,
    costs the resulting candidate plans, and picks the cheapest.  An
    ``available`` predicate can narrow the variants for given options
    (e.g. no exchange strategy when collectives are disabled).
    """

    name: str
    variants: Tuple[Tuple[str, Stage], ...]
    default: str
    available: Optional[Callable[[CompileOptions], Tuple[str, ...]]] = None

    def labels(self, opts: CompileOptions) -> Tuple[str, ...]:
        if self.available is not None:
            return tuple(self.available(opts))
        return tuple(label for label, _ in self.variants)

    def variant(self, label: str) -> Stage:
        for l, stage in self.variants:
            if l == label:
                return stage
        raise KeyError(
            f"choice {self.name!r} has no variant {label!r}; "
            f"known: {[l for l, _ in self.variants]}")


def _lower_rel_to_vec_chosen(opts: CompileOptions,
                             chosen: Dict[str, str]) -> Sequence[Any]:
    return [LowerRelToVec(_effective_catalog(opts),
                          groupby=chosen.get("groupby", "sorted"),
                          join=chosen.get("join", "sorted"),
                          encode=chosen.get("encode", "raw"))]


#: the one lowering stage both physical-operator Choices parameterize: the
#: groupby and join tier labels of the bound strategy become LowerRelToVec
#: constructor arguments
LOWER_REL_TO_VEC_STRATEGY = StrategyStage("lower-rel-to-vec",
                                          _lower_rel_to_vec_chosen)


#: grouped aggregation tier: SortByKey + GroupAggSorted (O(n log n), always
#: valid) vs the sort-free dense-bucket GroupAggDirect (O(n), needs catalog
#: key-domain bounds).  The first Choice whose variants have asymptotically
#: different cost — NDV/domain size decides, like gather-vs-exchange.  Both
#: variants bind the SAME shared lowering stage; the label reaches it via
#: the strategy dict.
GROUPBY_CHOICE = Choice(
    name="groupby",
    variants=(("sorted", LOWER_REL_TO_VEC_STRATEGY),
              ("direct", LOWER_REL_TO_VEC_STRATEGY)),
    default="sorted",
    available=lambda opts: (("sorted", "direct") if opts.stats() is not None
                            else ("sorted",)),
)


_JOIN_TIER = Stage("join-strategy", lambda opts: [])

#: physical join tier: SortByKey(build) + MergeJoinSorted (O(n log n),
#: always valid) vs the sort-free dense direct-table vec.HashJoinDirect
#: (O(n), needs the joint key domain bounded — or falls back in-trace via
#: its dynamic-bounds variant).  The variants are no-op Stages: the label
#: is consumed by LOWER_REL_TO_VEC_STRATEGY, which GROUPBY_CHOICE binds.
JOIN_CHOICE = Choice(
    name="join",
    variants=(("sorted", _JOIN_TIER), ("hash", _JOIN_TIER)),
    default="sorted",
    available=lambda opts: (("sorted", "hash") if opts.stats() is not None
                            else ("sorted",)),
)


_ENCODE_TIER = Stage("encode-strategy", lambda opts: [])

#: key-encoding tier for the direct physical operators: ``raw`` plans dense
#: buckets only over raw catalog domain bounds, ``dict`` re-encodes sparse
#: and string keys to dense dictionary ranks (vec.DictEncode/DictDecode) so
#: GroupAggDirect/HashJoinDirect apply where raw domains are missing or
#: over budget.  The variants are no-op Stages: the label is consumed by
#: LOWER_REL_TO_VEC_STRATEGY (same pattern as the join tier).
ENCODE_CHOICE = Choice(
    name="encode",
    variants=(("raw", _ENCODE_TIER), ("dict", _ENCODE_TIER)),
    default="raw",
    available=lambda opts: (("raw", "dict") if opts.stats() is not None
                            else ("raw",)),
)


_NO_FUSE = Stage("no-fuse", lambda opts: [])
_GROUPED_GATHER = Stage("grouped-gather", lambda opts: [])
_GROUPED_EXCHANGE = Stage(
    "grouped-exchange", lambda opts: [PushGroupedCombineIntoMesh()])

#: fuse vs no-fuse for FuseSelectAgg (JITQ's single-pass Q6 shape): fusing
#: saves passes over the block but denies the backend intermediate reuse
FUSE_CHOICE = Choice(
    name="fuse",
    variants=(("fused", FUSE), ("unfused", _NO_FUSE)),
    default="fused",
    available=lambda opts: ("fused", "unfused") if opts.fuse else ("unfused",),
)

#: grouped recombine after a MeshExecute: gather-then-aggregate (cheap at
#: low group cardinality) vs mesh.ExchangeByKey + per-shard aggregation
#: (wins when the partial-aggregate gather would swamp one device)
GROUPED_RECOMBINE = Choice(
    name="grouped-recombine",
    variants=(("gather", _GROUPED_GATHER), ("exchange", _GROUPED_EXCHANGE)),
    default="gather",
    available=lambda opts: (("gather", "exchange") if opts.collectives
                            else ("gather",)),
)


# ---------------------------------------------------------------------------
# targets
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Target:
    """A registered backend: lowering path + backend factory + data model.

    ``lowering_path`` entries are :class:`Stage`\\ s (always run) or
    :class:`Choice`\\ s (strategy points the cost-based optimizer may
    search over).
    """

    name: str
    flavors: Tuple[str, ...]
    lowering_path: Tuple[Any, ...]  # Stage | Choice
    make_backend: Callable[[CompileOptions], Any]
    source_kind: str = "vec"  # "vec" (VecTable sources) | "numpy" (raw columns)
    needs_mesh: bool = False
    #: the backend executes micro-batched incremental plans: compiles
    #: require ``stream_table=`` and lower the stream scan at batch capacity
    streaming: bool = False

    def choices(self) -> Tuple[Choice, ...]:
        return tuple(s for s in self.lowering_path if isinstance(s, Choice))


_TARGETS: Dict[str, Target] = {}
_EPOCHS: Dict[str, int] = {}


def register_target(target: Target, overwrite: bool = False) -> Target:
    if target.name in _TARGETS and not overwrite:
        raise ValueError(f"target {target.name!r} already registered")
    _TARGETS[target.name] = target
    # bump the registration epoch so plan-cache entries compiled under a
    # previous lowering path for this name can never be served again
    _EPOCHS[target.name] = _EPOCHS.get(target.name, 0) + 1
    return target


def target_epoch(name: str) -> int:
    return _EPOCHS.get(name, 0)


def get_target(name: str) -> Target:
    try:
        return _TARGETS[name]
    except KeyError:
        raise KeyError(
            f"unknown compile target {name!r}; registered: {sorted(_TARGETS)}"
        ) from None


def available_targets() -> Dict[str, Target]:
    return dict(sorted(_TARGETS.items()))


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------


def _make_interp(opts: CompileOptions) -> Any:
    from ..backends.interp import InterpBackend
    return InterpBackend()


def _make_local(opts: CompileOptions) -> Any:
    from ..backends.local import LocalBackend
    return LocalBackend(use_kernels=opts.use_kernels, jit=opts.jit)


def _make_spmd(opts: CompileOptions) -> Any:
    from ..backends.spmd import SpmdBackend
    mesh = opts.mesh
    if mesh is None:
        from ..launch.mesh import make_mesh
        mesh = make_mesh((opts.parallel or 1,), (opts.axis,))
    # rewrite=False: the driver already ran LowerToMesh/PushCombineIntoMesh
    # as registered pipeline stages
    return SpmdBackend(mesh, axis=opts.axis, use_kernels=opts.use_kernels,
                       collectives=opts.collectives, jit=opts.jit,
                       rewrite=False)


register_target(Target(
    name="interp",
    flavors=("rel", "cf", "df", "la", "mesh", "tz"),
    lowering_path=(CANONICALIZE, PARALLELIZE),
    make_backend=_make_interp,
    source_kind="numpy",
))

register_target(Target(
    name="local",
    flavors=("vec", "cf", "rel", "df", "la", "tz"),
    lowering_path=(CANONICALIZE, PARALLELIZE, GROUPBY_CHOICE, JOIN_CHOICE,
                   ENCODE_CHOICE, FUSE_CHOICE),
    make_backend=_make_local,
    source_kind="vec",
))

def _make_stream(opts: CompileOptions) -> Any:
    from ..backends.stream import StreamBackend
    return StreamBackend(opts)


# The streaming target shares the local lowering path (same physical-tier
# Choices — the carried state *is* a GroupAggDirect/GroupAggSorted
# accumulator), then StreamBackend splits the lowered program into
# static / per-batch / merge / finalize segments (core/passes/lower_stream)
# for checkpointed incremental execution.  No Parallelize stage: the
# micro-batch is the unit of work.
register_target(Target(
    name="stream",
    flavors=("vec", "cf", "rel", "df", "la", "tz"),
    lowering_path=(CANONICALIZE, GROUPBY_CHOICE, JOIN_CHOICE,
                   ENCODE_CHOICE, FUSE_CHOICE),
    make_backend=_make_stream,
    source_kind="vec",
    streaming=True,
))


register_target(Target(
    name="spmd",
    flavors=("vec", "cf", "rel", "la", "mesh"),
    lowering_path=(CANONICALIZE, PARALLELIZE, GROUPBY_CHOICE, JOIN_CHOICE,
                   ENCODE_CHOICE, FUSE_CHOICE, LOWER_TO_MESH, GROUPED_RECOMBINE),
    make_backend=_make_spmd,
    source_kind="vec",
    needs_mesh=True,
))

# The multipod (Lambada-analogue) target shares the SPMD lowering path; the
# elastic facade (ElasticExecutor) re-enters the driver per worker count and
# relies on the structural plan cache instead of its own plan table.
register_target(Target(
    name="multipod",
    flavors=("vec", "cf", "rel", "la", "mesh"),
    lowering_path=(CANONICALIZE, PARALLELIZE, GROUPBY_CHOICE, JOIN_CHOICE,
                   ENCODE_CHOICE, FUSE_CHOICE, LOWER_TO_MESH, GROUPED_RECOMBINE),
    make_backend=_make_spmd,
    source_kind="vec",
    needs_mesh=True,
))


# The tensor frontend's pjit binding, as a registered target: the LM
# trainer's planning rewrite (Alg. 1 → Alg. 2) is the parallelize stage of
# an ordinary lowering path, and ``compile(plan, target="pjit")`` yields a
# plan-summary executable; ``lower_to_pjit`` passes a model-bound
# ``PjitBackend`` via ``backend=`` to get a runnable train step.

def _tensor_parallelize(opts: CompileOptions) -> Sequence[Any]:
    targets = set(opts.parallelize_targets) if opts.parallelize_targets else None
    return [Parallelize(n=opts.parallel or 1, targets=targets)]


TENSOR_PARALLELIZE = Stage("parallelize", _tensor_parallelize)


def _make_pjit(opts: CompileOptions) -> Any:
    from ..frontends.tensor import PjitBackend
    return PjitBackend()  # plan-only unless a model binding is supplied


register_target(Target(
    name="pjit",
    flavors=("tz", "cf", "mesh"),
    lowering_path=(CANONICALIZE, TENSOR_PARALLELIZE),
    make_backend=_make_pjit,
    source_kind="numpy",
))
