"""Pallas TPU kernel: fused select + project + aggregate (TPC-H Q6 shape).

The single-pass pipeline JITQ compiles selective scan-aggregate queries
into.  Expressions (predicate + aggregated projections) are *compiled into
the kernel body* — the CVM lowering passes them as closure constants, so
each query gets its own specialized kernel, exactly like JITQ's per-pipeline
machine code.

Layout: each column is reshaped to (R, 128) lanes; the grid walks row-blocks
of ``block_rows`` sublanes; partial aggregates accumulate into a single
(8, 128)-padded VMEM output block (grid iterations on TPU are sequential, so
read-modify-write accumulation is safe).
"""

from __future__ import annotations

import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.expr import AggSpec, Expr, evaluate

LANES = 128
_NEG = -3.0e38
_POS = 3.0e38


def _kernel(pred: Expr, aggs: Tuple[AggSpec, ...], names: Tuple[str, ...], nblocks: int,
            *refs):
    col_refs, valid_ref, out_ref = refs[:-2], refs[-2], refs[-1]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        init = jnp.zeros_like(out_ref)
        for j, a in enumerate(aggs):
            if a.fn == "min":
                init = init.at[j, :].set(_POS)
            elif a.fn == "max":
                init = init.at[j, :].set(_NEG)
        out_ref[...] = init

    cols = {n: r[...] for n, r in zip(names, col_refs)}
    keep = valid_ref[...] & evaluate(pred, cols, jnp)

    acc = out_ref[...]
    for j, a in enumerate(aggs):
        if a.fn == "count":
            part = jnp.sum(keep.astype(jnp.float32), axis=0)
            acc = acc.at[j, :].add(part)
            continue
        arr = evaluate(a.expr, cols, jnp).astype(jnp.float32)
        if a.fn == "sum":
            acc = acc.at[j, :].add(jnp.sum(jnp.where(keep, arr, 0.0), axis=0))
        elif a.fn == "min":
            acc = acc.at[j, :].min(jnp.min(jnp.where(keep, arr, _POS), axis=0))
        elif a.fn == "max":
            acc = acc.at[j, :].max(jnp.max(jnp.where(keep, arr, _NEG), axis=0))
        else:
            raise ValueError(a.fn)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("pred", "aggs", "names", "block_rows", "interpret"))
def fused_select_agg_p(cols: Tuple[jax.Array, ...], valid: jax.Array, *,
                       pred: Expr, aggs: Tuple[AggSpec, ...], names: Tuple[str, ...],
                       block_rows: int = 512, interpret: bool = True) -> jax.Array:
    """cols: tuple of (R, 128) arrays; valid: (R, 128) bool. Returns (n_aggs,)."""
    rows = valid.shape[0]
    assert rows % block_rows == 0, (rows, block_rows)
    nblocks = rows // block_rows
    n_aggs = len(aggs)
    out_rows = max(8, n_aggs)

    in_specs = [
        pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
        for _ in range(len(cols) + 1)
    ]
    out_spec = pl.BlockSpec((out_rows, LANES), lambda i: (0, 0))

    lane_acc = pl.pallas_call(
        functools.partial(_kernel, pred, aggs, names, nblocks),
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, LANES), jnp.float32),
        interpret=interpret,
    )(*cols, valid)

    # final cross-lane reduction (tiny) outside the kernel
    outs = []
    for j, a in enumerate(aggs):
        lane = lane_acc[j]
        if a.fn in ("sum", "count"):
            outs.append(jnp.sum(lane))
        elif a.fn == "min":
            outs.append(jnp.min(lane))
        else:
            outs.append(jnp.max(lane))
    return jnp.stack(outs)
