"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel ships as ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), with ``ops.py`` as the jit'd public wrapper and ``ref.py`` as the
pure-jnp oracle.  On this CPU container kernels run with ``interpret=True``;
on TPU the same BlockSpecs bind to real VMEM tiles.

Kernels:
  * fused_select_agg   — single-pass select+project+aggregate (TPC-H Q6 pipeline)
  * grouped_select_agg — fused select + dense-bucket grouped aggregation
                         (TPC-H Q1 pipeline: vec.GroupAggDirect under kernels)
  * segsum             — segment reduction as one-hot MXU matmul (GroupBy)
  * kmeans_step      — fused assign+accumulate k-means iteration
  * flash_attention  — causal/windowed GQA online-softmax attention
"""

from . import ops, ref  # noqa: F401
