"""Pallas TPU kernel: fused select + direct-table join + grouped aggregation.

The whole-pipeline operator (TPC-H Q3/Q12 shape): one blockwise pass over
the probe side evaluates the fused predicate, derives each row's JOIN
bucket id from its key columns (checked against the static joint key
domain), "gathers" the build-side payload through a one-hot reduction
against small dense per-bucket tables (scatter- and gather-free — the same
one-hot idiom ``grouped_select_agg`` uses for accumulation, run in reverse
for the lookup), then derives the GROUP bucket id over the joined columns
and accumulates every aggregate into per-bucket per-lane VMEM accumulators.
The join result is never materialized.

The build side is preprocessed OUTSIDE the kernel into dense tables over
the join-bucket axis (one f32 value per bucket per needed column, plus a
0/1 presence table); duplicate build keys resolve to the lowest row index,
matching the unfused tiers.  Build-side values ride through the one-hot
reduction in f32 — integral columns are exact up to 2^24, far beyond the
gated bucket budgets.

Layout matches ``grouped_select_agg``: probe columns reshaped to (R, 128)
lanes, the grid walks row-blocks, outputs are (NBG_pad, 128) lane
accumulators (count first, then one per agg).  The per-bucket build tables
are (NBJ_pad, 1) blocks — scalar-per-bucket side inputs (interpret-mode
friendly; a hardware port would pad them to the lane width).  Grid
iterations on TPU are sequential, so read-modify-write accumulation is
safe.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.expr import AggSpec, Expr, evaluate

LANES = 128
_NEG = -3.0e38
_POS = 3.0e38


def _kernel(pred: Optional[Expr], aggs: Tuple[AggSpec, ...],
            lnames: Tuple[str, ...], rnames: Tuple[str, ...],
            jkey_specs: Tuple[Tuple[str, int, int], ...],
            gkey_specs: Tuple[Tuple[str, int, int], ...], *refs):
    nl, nr = len(lnames), len(rnames)
    col_refs = refs[:nl]
    valid_ref = refs[nl]
    present_ref = refs[nl + 1]
    rtab_refs = refs[nl + 2:nl + 2 + nr]
    cnt_ref = refs[nl + 2 + nr]
    agg_refs = refs[nl + 3 + nr:]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        for j, a in enumerate(aggs):
            init = jnp.zeros_like(agg_refs[j])
            if a.fn == "min":
                init = jnp.full_like(agg_refs[j], _POS)
            elif a.fn == "max":
                init = jnp.full_like(agg_refs[j], _NEG)
            agg_refs[j][...] = init

    cols = {n: r[...] for n, r in zip(lnames, col_refs)}
    keep = valid_ref[...]
    if pred is not None:
        keep = keep & evaluate(pred, cols, jnp)

    # join bucket id per element, checked against the declared domain: an
    # out-of-domain probe key must NOT alias the clipped boundary bucket
    jbid = jnp.zeros_like(keep, jnp.int32)
    for name, lo, size in jkey_specs:
        v = cols[name].astype(jnp.int32) - lo
        keep = keep & (v >= 0) & (v < size)
        jbid = jbid * size + jnp.clip(v, 0, size - 1)

    # one-hot over the (static, padded) join-bucket axis: (NBJ_pad, B, L)
    nbj_pad = present_ref.shape[0]
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (nbj_pad, 1, 1), 0)
    memj = (jbid[None, :, :] == iota_j) & keep[None, :, :]

    # probe: present-bucket membership is the match mask, and each needed
    # build column is "gathered" by reducing its dense table through the
    # same one-hot (exactly one bucket contributes per element)
    present = present_ref[...][:, :, None]  # (NBJ_pad, 1, 1)
    keep = keep & (jnp.sum(jnp.where(memj, present, 0.0), axis=0) > 0.0)
    for n, r in zip(rnames, rtab_refs):
        tbl = r[...][:, :, None]  # (NBJ_pad, 1, 1)
        cols[n] = jnp.sum(jnp.where(memj, tbl, 0.0), axis=0)

    # group bucket id over the joined columns (post-join domain is exact by
    # construction, so the clip is the same as grouped_select_agg's)
    gbid = jnp.zeros_like(keep, jnp.int32)
    for name, lo, size in gkey_specs:
        v = jnp.clip(cols[name].astype(jnp.int32) - lo, 0, size - 1)
        gbid = gbid * size + v
    nbg_pad = cnt_ref.shape[0]
    iota_g = jax.lax.broadcasted_iota(jnp.int32, (nbg_pad, 1, 1), 0)
    member = (gbid[None, :, :] == iota_g) & keep[None, :, :]

    cnt_ref[...] += jnp.sum(member.astype(jnp.float32), axis=1)
    for j, a in enumerate(aggs):
        if a.fn == "count":
            agg_refs[j][...] += jnp.sum(member.astype(jnp.float32), axis=1)
            continue
        arr = evaluate(a.expr, cols, jnp).astype(jnp.float32)[None, :, :]
        if a.fn == "sum":
            agg_refs[j][...] += jnp.sum(jnp.where(member, arr, 0.0), axis=1)
        elif a.fn == "min":
            agg_refs[j][...] = jnp.minimum(
                agg_refs[j][...], jnp.min(jnp.where(member, arr, _POS), axis=1))
        elif a.fn == "max":
            agg_refs[j][...] = jnp.maximum(
                agg_refs[j][...], jnp.max(jnp.where(member, arr, _NEG), axis=1))
        else:
            raise ValueError(a.fn)


@functools.partial(jax.jit, static_argnames=(
    "pred", "aggs", "lnames", "rnames", "jkey_specs", "gkey_specs",
    "num_join_buckets", "num_buckets", "block_rows", "interpret"))
def grouped_join_agg_p(cols: Tuple[jax.Array, ...], valid: jax.Array,
                       present: jax.Array, rtabs: Tuple[jax.Array, ...], *,
                       pred: Optional[Expr], aggs: Tuple[AggSpec, ...],
                       lnames: Tuple[str, ...], rnames: Tuple[str, ...],
                       jkey_specs: Tuple[Tuple[str, int, int], ...],
                       gkey_specs: Tuple[Tuple[str, int, int], ...],
                       num_join_buckets: int, num_buckets: int,
                       block_rows: int = 256,
                       interpret: bool = True) -> Tuple[jax.Array, ...]:
    """cols: tuple of (R, 128) probe arrays; valid: (R, 128) bool;
    present/rtabs: (NBJ_pad, 1) f32 dense build tables.

    Returns lane accumulators ``(count, agg_0, ..., agg_k)`` each of shape
    (num_buckets_padded, 128) f32; callers cross-lane-reduce and slice to
    ``num_buckets``."""
    rows = valid.shape[0]
    assert rows % block_rows == 0, (rows, block_rows)
    nblocks = rows // block_rows
    nbj_pad = present.shape[0]
    nbg_pad = max(8, num_buckets)

    in_specs = [
        pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
        for _ in range(len(cols) + 1)
    ] + [
        pl.BlockSpec((nbj_pad, 1), lambda i: (0, 0))
        for _ in range(len(rtabs) + 1)
    ]
    out_spec = pl.BlockSpec((nbg_pad, LANES), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((nbg_pad, LANES), jnp.float32)

    return pl.pallas_call(
        functools.partial(_kernel, pred, aggs, lnames, rnames,
                          jkey_specs, gkey_specs),
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=[out_spec] * (len(aggs) + 1),
        out_shape=[out_shape] * (len(aggs) + 1),
        interpret=interpret,
    )(*cols, valid, present, *rtabs)
