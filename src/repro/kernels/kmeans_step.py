"""Pallas TPU kernel: fused k-means assignment + accumulation step.

One grid pass over the point blocks computes, entirely in VMEM:
  d²(x, c) = ‖x‖² − 2·x@cᵀ + ‖c‖²  (MXU),
  labels   = argmin rows,
  sums    += one_hot(labels)ᵀ @ X    (MXU again),
  counts  += Σ one_hot(labels).

This is the fused "run-based aggregation" plan the paper credits for
matching scikit-learn's hand-written C++ k-means — adapted to the MXU:
both the distance matrix and the scatter-accumulate become matmuls.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, sums_ref, counts_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...]                      # (B, d)
    c = c_ref[...]                      # (k, d)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)          # (B, 1)
    c2 = jnp.sum(c * c, axis=1, keepdims=True).T        # (1, k)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (B, k)
    d2 = x2 - 2.0 * xc + c2
    k = c.shape[0]
    lab = jnp.argmin(d2, axis=1)                        # (B,)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1) == lab[:, None]
    ).astype(jnp.float32)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    counts_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def kmeans_step_p(x: jax.Array, c: jax.Array, *, block_rows: int = 1024,
                  interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """x: (n, d) f32, c: (k, d) f32 → (sums (k, d), counts (k,))."""
    n, d = x.shape
    k = c.shape[0]
    assert n % block_rows == 0, (n, block_rows)
    nblocks = n // block_rows

    sums, counts = pl.pallas_call(
        _kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ],
        interpret=interpret,
    )(x, c)
    return sums, counts[0]
