"""Pallas TPU kernel: fused select + dense-bucket grouped aggregation.

The grouped sibling of ``fused_select_agg`` (TPC-H Q1 shape): one blockwise
pass evaluates the fused predicate, derives each row's dense bucket id from
its key columns (static catalog-bounded domains), and accumulates every
aggregate into per-bucket per-lane VMEM accumulators — no sort, no gather,
no scatter.  Bucket membership is materialized as a one-hot over the
(static) bucket axis and reduced with masked sums/mins/maxes per block —
the same scatter-free idiom as the ``segsum`` one-hot matmul, extended to
min/max and a fused predicate.

Layout matches ``fused_select_agg``: each column reshaped to (R, 128)
lanes; the grid walks row-blocks; outputs are (NB_pad, 128) lane
accumulators (count first, then one per agg), cross-lane-reduced outside
the kernel.  Grid iterations on TPU are sequential, so read-modify-write
accumulation is safe.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.expr import AggSpec, Expr, evaluate

LANES = 128
_NEG = -3.0e38
_POS = 3.0e38


def _kernel(pred: Optional[Expr], aggs: Tuple[AggSpec, ...], names: Tuple[str, ...],
            key_specs: Tuple[Tuple[str, int, int], ...], nb: int, *refs):
    col_refs, valid_ref = refs[:len(names)], refs[len(names)]
    cnt_ref, agg_refs = refs[len(names) + 1], refs[len(names) + 2:]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        for j, a in enumerate(aggs):
            init = jnp.zeros_like(agg_refs[j])
            if a.fn == "min":
                init = jnp.full_like(agg_refs[j], _POS)
            elif a.fn == "max":
                init = jnp.full_like(agg_refs[j], _NEG)
            agg_refs[j][...] = init

    cols = {n: r[...] for n, r in zip(names, col_refs)}
    keep = valid_ref[...]
    if pred is not None:
        keep = keep & evaluate(pred, cols, jnp)

    # dense bucket id per element: lexicographic rank in the key domain
    bid = jnp.zeros_like(keep, jnp.int32)
    for name, lo, size in key_specs:
        v = jnp.clip(cols[name].astype(jnp.int32) - lo, 0, size - 1)
        bid = bid * size + v
    # one-hot over the (static, padded) bucket axis: (NB_pad, B, L)
    nb_pad = cnt_ref.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (nb_pad, 1, 1), 0)
    member = (bid[None, :, :] == iota) & keep[None, :, :]

    cnt_ref[...] += jnp.sum(member.astype(jnp.float32), axis=1)
    for j, a in enumerate(aggs):
        if a.fn == "count":
            agg_refs[j][...] += jnp.sum(member.astype(jnp.float32), axis=1)
            continue
        arr = evaluate(a.expr, cols, jnp).astype(jnp.float32)[None, :, :]
        if a.fn == "sum":
            agg_refs[j][...] += jnp.sum(jnp.where(member, arr, 0.0), axis=1)
        elif a.fn == "min":
            agg_refs[j][...] = jnp.minimum(
                agg_refs[j][...], jnp.min(jnp.where(member, arr, _POS), axis=1))
        elif a.fn == "max":
            agg_refs[j][...] = jnp.maximum(
                agg_refs[j][...], jnp.max(jnp.where(member, arr, _NEG), axis=1))
        else:
            raise ValueError(a.fn)


@functools.partial(jax.jit, static_argnames=(
    "pred", "aggs", "names", "key_specs", "num_buckets", "block_rows", "interpret"))
def grouped_select_agg_p(cols: Tuple[jax.Array, ...], valid: jax.Array, *,
                         pred: Optional[Expr], aggs: Tuple[AggSpec, ...],
                         names: Tuple[str, ...],
                         key_specs: Tuple[Tuple[str, int, int], ...],
                         num_buckets: int, block_rows: int = 256,
                         interpret: bool = True) -> Tuple[jax.Array, ...]:
    """cols: tuple of (R, 128) arrays; valid: (R, 128) bool.

    Returns lane accumulators ``(count, agg_0, ..., agg_k)`` each of shape
    (num_buckets_padded, 128) f32; callers cross-lane-reduce and slice to
    ``num_buckets``."""
    rows = valid.shape[0]
    assert rows % block_rows == 0, (rows, block_rows)
    nblocks = rows // block_rows
    nb_pad = max(8, num_buckets)

    in_specs = [
        pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
        for _ in range(len(cols) + 1)
    ]
    out_spec = pl.BlockSpec((nb_pad, LANES), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((nb_pad, LANES), jnp.float32)

    return pl.pallas_call(
        functools.partial(_kernel, pred, aggs, names, key_specs, num_buckets),
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=[out_spec] * (len(aggs) + 1),
        out_shape=[out_shape] * (len(aggs) + 1),
        interpret=interpret,
    )(*cols, valid)
