"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic definition*; the kernels must match it to
tolerance on every shape/dtype sweep (tests/test_kernels.py).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.expr import AggSpec, Expr, evaluate


def fused_select_agg(cols: Dict[str, jax.Array], valid: jax.Array, pred: Expr,
                     aggs: Sequence[AggSpec]) -> jax.Array:
    """Masked single-pass select+aggregate. Returns (n_aggs,) f32."""
    keep = valid & evaluate(pred, cols, jnp)
    outs = []
    for a in aggs:
        if a.fn == "count":
            outs.append(jnp.sum(keep.astype(jnp.float32)))
            continue
        arr = evaluate(a.expr, cols, jnp).astype(jnp.float32)
        if a.fn == "sum":
            outs.append(jnp.sum(jnp.where(keep, arr, 0.0)))
        elif a.fn == "min":
            outs.append(jnp.min(jnp.where(keep, arr, jnp.inf)))
        elif a.fn == "max":
            outs.append(jnp.max(jnp.where(keep, arr, -jnp.inf)))
        else:
            raise ValueError(a.fn)
    return jnp.stack(outs)


def segsum(data: jax.Array, seg_ids: jax.Array, num_segments: int) -> jax.Array:
    """Sum rows of ``data`` (n, d) by segment id (n,) → (num_segments, d)."""
    return jax.ops.segment_sum(data, seg_ids, num_segments=num_segments)


def kmeans_step(x: jax.Array, c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One k-means iteration: (sums (k,d), counts (k,)) of nearest-centroid."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1, keepdims=True).T
    d2 = x2 - 2.0 * (x @ c.T) + c2
    lab = jnp.argmin(d2, axis=1)
    k = c.shape[0]
    sums = jax.ops.segment_sum(x, lab, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones_like(lab, dtype=jnp.float32), lab, num_segments=k)
    return sums, counts


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    sm_scale: Optional[float] = None) -> jax.Array:
    """Reference GQA attention.

    q: (B, Hq, S, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0.
    ``window``: sliding-window size (Mistral-style), None = full.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), vv)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array | int, *,
                     sm_scale: Optional[float] = None) -> jax.Array:
    """Single-token decode attention against a (B, Hkv, S, D) cache.

    Grouped-head einsum form: q is reshaped to (B, Hkv, G, 1, D) and
    contracted against the cache directly — no ``jnp.repeat`` of K/V, so a
    head- or sequence-sharded cache is never resharded (the repeat forced
    GSPMD into involuntary full rematerializations; see EXPERIMENTS §Perf).
    """
    import os
    b, hq, one, d = q.shape
    hkv = k_cache.shape[1]
    group = hq // hkv
    s = k_cache.shape[2]
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    if os.environ.get("REPRO_DECODE_REPEAT") == "1":  # baseline path (perf log)
        kk = jnp.repeat(k_cache, group, axis=1)
        vv = jnp.repeat(v_cache, group, axis=1)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
        valid = jnp.arange(s)[None, None, None, :] < jnp.reshape(
            jnp.asarray(cache_len), (-1, 1, 1, 1))
        logits = jnp.where(valid, logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(vv.dtype), vv).astype(q.dtype)
    qg = q.reshape(b, hkv, group, d)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(s)[None, None, None, :] < jnp.reshape(
        jnp.asarray(cache_len), (-1, 1, 1, 1))
    logits = jnp.where(valid, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, 1, d).astype(q.dtype)
