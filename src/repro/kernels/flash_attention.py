"""Pallas TPU kernel: causal/windowed GQA flash attention (the LM hot loop).

Online-softmax attention with VMEM-resident accumulators.  Grid is
(batch, q_heads, q_blocks, kv_blocks); the kv axis is the innermost
(sequential) dimension so the m/l/acc scratch carries across kv blocks.
Blocks entirely above the causal diagonal, or entirely left of the sliding
window, are skipped — the kernel-level realization of the sub-quadratic
windowed archs (mixtral SWA).

Block shapes: q/o (bq, d), k/v (bk, d) with d padded to a lane multiple;
masked logits use a large-negative finite sentinel (−1e30) so fully-masked
prefixes flush out of the accumulator when the first real block arrives
(α = exp(m_prev − m_new) underflows to 0), avoiding −inf NaNs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1.0e30


def _flash_kernel(causal: bool, window: Optional[int], scale: float,
                  bq: int, bk: int, nk: int,
                  q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = jnp.bool_(True)
    if causal:
        run &= ki * bk < (qi + 1) * bq          # not entirely above diagonal
    if window is not None:
        run &= (ki + 1) * bk - 1 > qi * bq - window  # not entirely left of window

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows → 0 output
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention_p(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      sm_scale: Optional[float] = None,
                      block_q: int = 128, block_k: int = 128,
                      interpret: bool = True) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0 → (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)

    kernel = functools.partial(_flash_kernel, causal, window, scale, bq, bk, nk)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
