"""Pallas TPU kernel: segment-sum as one-hot matmul (grouped aggregation).

The TPU-native replacement for hash aggregation: instead of scattering rows
into buckets (no efficient random scatter in VMEM), each row-block builds a
(B, K) one-hot of its segment ids and hits the MXU with
``one_hotᵀ @ data  →  (K, d)`` partials accumulated across the grid.
Arithmetic intensity scales with d, and the scatter becomes a systolic
matmul — the hardware-adaptation point of DESIGN.md §2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(k_total: int, x_ref, seg_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]              # (B, d)
    seg = seg_ref[...]          # (B, 1) int32
    b = x.shape[0]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (b, k_total), 1) == seg
    ).astype(x.dtype)           # (B, K)
    out_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("num_segments", "block_rows", "interpret"))
def segsum_p(data: jax.Array, seg_ids: jax.Array, *, num_segments: int,
             block_rows: int = 512, interpret: bool = True) -> jax.Array:
    """data: (n, d) f32; seg_ids: (n,) i32 in [0, num_segments). → (K, d)."""
    n, d = data.shape
    assert n % block_rows == 0, (n, block_rows)
    nblocks = n // block_rows

    out = pl.pallas_call(
        functools.partial(_kernel, num_segments),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
        interpret=interpret,
    )(data, seg_ids.reshape(n, 1))
    return out
