"""Jit'd public wrappers around the Pallas kernels (+ jnp fallbacks).

Dispatch policy:
  * ``mode="pallas"``    — the Pallas kernel (``interpret=True`` on CPU);
  * ``mode="chunked"``   — memory-efficient pure-jnp flash (lax.scan over kv
    blocks + remat): what train/serve steps use so the *compiled* HLO has
    O(S·d) attention footprint — this is the shape the dry-run measures;
  * ``mode="ref"``       — materialized oracle (small tests only).

The relational entry points (``fused_select_agg``, ``segsum_table``) adapt
VecTable blocks to kernel layout (pad → reshape to lanes).
"""

from __future__ import annotations

import functools
import os
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.expr import AggSpec, Expr
from . import ref
from .flash_attention import flash_attention_p
from .fused_select_agg import LANES, fused_select_agg_p
from .grouped_join_agg import grouped_join_agg_p
from .grouped_select_agg import grouped_select_agg_p
from .kmeans_step import kmeans_step_p
from .segsum import segsum_p


# ---------------------------------------------------------------------------
# relational kernels
# ---------------------------------------------------------------------------


def _pad_rows(a: jax.Array, rows: int) -> jax.Array:
    pad = rows - a.shape[0]
    if pad == 0:
        return a
    return jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])


def fused_select_agg(table, pred: Expr, aggs: Sequence[AggSpec], *,
                     block_rows: int = 512, interpret: bool = True) -> Dict[str, jax.Array]:
    """VecTable → Single⟨aggs⟩ via the fused Pallas kernel."""
    names = tuple(sorted(set(pred.fields()) | {f for a in aggs for f in a.expr.fields()}))
    cap = table.capacity
    rows = -(-cap // LANES)  # ceil
    rows = -(-rows // block_rows) * block_rows
    total = rows * LANES

    def to_lanes(arr):
        return _pad_rows(arr, total).reshape(rows, LANES)

    cols = tuple(to_lanes(table.cols[n].astype(jnp.float32)
                          if jnp.issubdtype(table.cols[n].dtype, jnp.floating)
                          else table.cols[n]) for n in names)
    valid = to_lanes(table.valid)
    out = fused_select_agg_p(cols, valid, pred=pred, aggs=tuple(aggs), names=names,
                             block_rows=block_rows, interpret=interpret)
    # empty-selection min/max: map the kernel's finite sentinels back to ±inf
    out = jnp.where(out >= 3.0e38, jnp.inf, jnp.where(out <= -3.0e38, -jnp.inf, out))
    return {a.name: out[i] for i, a in enumerate(aggs)}


def grouped_select_agg(table, pred: Optional[Expr], keys: Sequence[str],
                       aggs: Sequence[AggSpec],
                       max_groups: int,
                       key_domains: Sequence[Tuple[int, int]],
                       num_buckets: int, *,
                       block_rows: int = 256, interpret: bool = True):
    """VecTable → Vec⟨keys+aggs⟩ via the fused Pallas kernel.

    One blockwise pass: fused predicate + dense-bucket accumulation
    (``vec.GroupAggDirect`` under ``use_kernels``).  The tiny per-bucket
    epilogue (cross-lane reduce, key decode, compaction to ``max_groups``)
    runs outside the kernel.
    """
    from ..relational import runtime as rt

    agg_fields = {f for a in aggs for f in a.expr.fields()}
    pred_fields = set(pred.fields()) if pred is not None else set()
    names = tuple(sorted(pred_fields | agg_fields | set(keys)))
    cap = table.capacity
    rows = -(-cap // LANES)  # ceil
    rows = -(-rows // block_rows) * block_rows
    total = rows * LANES

    def to_lanes(arr):
        return _pad_rows(arr, total).reshape(rows, LANES)

    cols = tuple(to_lanes(table.cols[n].astype(jnp.float32)
                          if jnp.issubdtype(table.cols[n].dtype, jnp.floating)
                          else table.cols[n]) for n in names)
    valid = to_lanes(table.valid)
    key_specs = tuple((k, int(lo), int(hi) - int(lo) + 1)
                      for k, (lo, hi) in zip(keys, key_domains))
    lane_accs = grouped_select_agg_p(
        cols, valid, pred=pred, aggs=tuple(aggs), names=names,
        key_specs=key_specs, num_buckets=num_buckets,
        block_rows=block_rows, interpret=interpret)

    counts = jnp.sum(lane_accs[0], axis=1)[:num_buckets]
    out_cols = rt.decode_bucket_keys(keys, key_domains,
                                     [table.cols[k].dtype for k in keys],
                                     num_buckets)
    for j, a in enumerate(aggs):
        lane = lane_accs[j + 1]
        if a.fn in ("sum", "count"):
            red = jnp.sum(lane, axis=1)
        elif a.fn == "min":
            red = jnp.min(lane, axis=1)
        else:
            red = jnp.max(lane, axis=1)
        red = red[:num_buckets]
        if a.fn == "count":
            red = red.astype(jnp.int32)
        else:
            # empty-bucket min/max: finite kernel sentinels back to ±inf
            red = jnp.where(red >= 3.0e38, jnp.inf,
                            jnp.where(red <= -3.0e38, -jnp.inf, red))
        out_cols[a.name] = red
    buckets = rt.VecTable(out_cols, counts > 0)
    return rt.compact(buckets, max_groups)


def grouped_join_agg(left, right, *, left_on: Sequence[str],
                     right_on: Sequence[str],
                     join_key_domains: Sequence[Tuple[int, int]],
                     join_num_buckets: int, keys: Sequence[str],
                     aggs: Sequence[AggSpec], max_groups: int,
                     key_domains: Sequence[Tuple[int, int]],
                     num_buckets: int, pred: Optional[Expr] = None,
                     block_rows: int = 256, interpret: bool = True):
    """(probe VecTable, build VecTable) → Vec⟨keys+aggs⟩, one fused kernel.

    The whole select→join→group pipeline (``vec.FusedJoinGroupAgg`` under
    ``use_kernels``): the build side is condensed OUTSIDE the kernel into
    dense per-join-bucket tables (presence + one f32 value per needed
    column, duplicate keys → lowest row index, matching the unfused tiers);
    the kernel then runs predicate, probe, group-bucket derivation and all
    accumulators blockwise in a single pass — the join result is never
    materialized.  The tiny epilogue (cross-lane reduce, key decode,
    compaction to ``max_groups``) runs outside the kernel.
    """
    from ..relational import runtime as rt

    keys = tuple(keys)
    aggs = tuple(aggs)
    agg_fields = {f for a in aggs for f in a.expr.fields() if a.fn != "count"}
    pred_fields = set(pred.fields()) if pred is not None else set()
    rnames = tuple(sorted((set(keys) | agg_fields)
                          & (set(right.cols) - set(right_on))))
    lnames = tuple(sorted((pred_fields | set(left_on)
                           | ((set(keys) | agg_fields) & set(left.cols)))))

    cap = left.capacity
    rows = -(-cap // LANES)  # ceil
    rows = -(-rows // block_rows) * block_rows
    total = rows * LANES

    def to_lanes(arr):
        return _pad_rows(arr, total).reshape(rows, LANES)

    cols = tuple(to_lanes(left.cols[n].astype(jnp.float32)
                          if jnp.issubdtype(left.cols[n].dtype, jnp.floating)
                          else left.cols[n]) for n in lnames)
    valid = to_lanes(left.valid)

    # dense build tables over the join-bucket axis (first occurrence wins)
    nbj = int(join_num_buckets)
    nbj_pad = max(8, nbj)
    cap_r = right.capacity
    rbid, rok = rt._bucket_ids_checked(right, right_on, join_key_domains)
    slot = jnp.where(rok & right.valid, rbid, nbj)
    ridx = jnp.full((nbj + 1,), cap_r, jnp.int32)
    ridx = ridx.at[slot].min(jnp.arange(cap_r, dtype=jnp.int32),
                             mode="drop")[:nbj]
    present_b = ridx < cap_r
    ridx_c = jnp.minimum(ridx, cap_r - 1)

    def to_table(arr):
        vals = jnp.where(present_b, arr[ridx_c].astype(jnp.float32), 0.0)
        return jnp.pad(vals, (0, nbj_pad - nbj))[:, None]

    present = to_table(present_b)
    rtabs = tuple(to_table(right.cols[n]) for n in rnames)

    jkey_specs = tuple((k, int(lo), int(hi) - int(lo) + 1)
                       for k, (lo, hi) in zip(left_on, join_key_domains))
    gkey_specs = tuple((k, int(lo), int(hi) - int(lo) + 1)
                       for k, (lo, hi) in zip(keys, key_domains))
    lane_accs = grouped_join_agg_p(
        cols, valid, present, rtabs, pred=pred, aggs=aggs,
        lnames=lnames, rnames=rnames, jkey_specs=jkey_specs,
        gkey_specs=gkey_specs, num_join_buckets=nbj, num_buckets=num_buckets,
        block_rows=block_rows, interpret=interpret)

    counts = jnp.sum(lane_accs[0], axis=1)[:num_buckets]
    key_dtypes = [left.cols[k].dtype if k in left.cols else right.cols[k].dtype
                  for k in keys]
    out_cols = rt.decode_bucket_keys(keys, key_domains, key_dtypes, num_buckets)
    for j, a in enumerate(aggs):
        lane = lane_accs[j + 1]
        if a.fn in ("sum", "count"):
            red = jnp.sum(lane, axis=1)
        elif a.fn == "min":
            red = jnp.min(lane, axis=1)
        else:
            red = jnp.max(lane, axis=1)
        red = red[:num_buckets]
        if a.fn == "count":
            red = red.astype(jnp.int32)
        else:
            # empty-bucket min/max: finite kernel sentinels back to ±inf
            red = jnp.where(red >= 3.0e38, jnp.inf,
                            jnp.where(red <= -3.0e38, -jnp.inf, red))
        out_cols[a.name] = red
    buckets = rt.VecTable(out_cols, counts > 0)
    return rt.compact(buckets, max_groups)


def segsum(data: jax.Array, seg_ids: jax.Array, num_segments: int, *,
           block_rows: int = 512, interpret: bool = True) -> jax.Array:
    n, d = data.shape
    rows = -(-n // block_rows) * block_rows
    data_p = _pad_rows(data.astype(jnp.float32), rows)
    seg_p = jnp.concatenate([
        seg_ids.astype(jnp.int32),
        jnp.full((rows - n,), num_segments, jnp.int32),  # padded rows → dumped
    ]) if rows != n else seg_ids.astype(jnp.int32)
    out = segsum_p(data_p, seg_p, num_segments=num_segments + 1,
                   block_rows=block_rows, interpret=interpret)
    return out[:num_segments]


def kmeans_step(x: jax.Array, c: jax.Array, *, block_rows: int = 1024,
                interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    n, d = x.shape
    rows = -(-n // block_rows) * block_rows
    if rows != n:
        # pad with copies of the first centroid → corrected afterwards
        pad = rows - n
        x_p = jnp.concatenate([x, jnp.broadcast_to(c[0], (pad, d))])
        sums, counts = kmeans_step_p(x_p, c, block_rows=block_rows, interpret=interpret)
        sums = sums.at[0].add(-pad * c[0])
        counts = counts.at[0].add(-float(pad))
        return sums, counts
    return kmeans_step_p(x, c, block_rows=block_rows, interpret=interpret)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_k", "policy", "unroll"),
)
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      sm_scale: Optional[float] = None, block_k: int = 512,
                      policy: str = "remat", unroll: bool = False) -> jax.Array:
    """Memory-efficient GQA flash attention in pure jnp (scan over kv blocks).

    Differentiable; with remat the backward recomputes per-block logits so
    peak memory is O(S·d) instead of O(S²) — this is the attention the
    train/serve pipelines compile (and what the dry-run memory analysis
    sees).  Semantics identical to ``ref.flash_attention``.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    bk = min(block_k, s)
    assert s % bk == 0
    nk = s // bk

    # matmuls run in the input dtype (bf16 on the MXU) with f32 accumulation;
    # the online-softmax state (m, l, acc) stays f32.  REPRO_ATTN_F32=1
    # restores the baseline all-f32 math (perf-iteration A/B attribution).
    out_dtype = q.dtype
    if os.environ.get("REPRO_ATTN_F32") == "1":
        q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(b, hkv, group, s, d)
    kf = k
    vf = v

    qpos = jnp.arange(s)

    def block(carry, ki):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(kf, ki * bk, bk, axis=2)   # (b,hkv,bk,d)
        vs = jax.lax.dynamic_slice_in_dim(vf, ki * bk, bk, axis=2)
        s_blk = jnp.einsum("bhgqd,bhkd->bhgqk", qf, ks,
                           preferred_element_type=jnp.float32)       # (b,hkv,g,s,bk)
        kpos = ki * bk + jnp.arange(bk)
        mask = jnp.ones((s, bk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s_blk = jnp.where(mask, s_blk, -1.0e30)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s_blk - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vs.dtype), vs,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    if policy == "remat":
        block = jax.checkpoint(block)

    init = (
        jnp.full((b, hkv, group, s), -1.0e30, jnp.float32),
        jnp.zeros((b, hkv, group, s), jnp.float32),
        jnp.zeros((b, hkv, group, s, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(block, init, jnp.arange(nk),
                                  unroll=nk if unroll else 1)
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(b, hq, s, d)
    return out.astype(out_dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              sm_scale: Optional[float] = None, mode: str = "chunked",
              interpret: bool = True, unroll: bool = False) -> jax.Array:
    if mode == "pallas":
        return flash_attention_p(q, k, v, causal=causal, window=window,
                                 sm_scale=sm_scale, interpret=interpret)
    if mode == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 sm_scale=sm_scale, unroll=unroll)
    return ref.flash_attention(q, k, v, causal=causal, window=window, sm_scale=sm_scale)


def decode_attention(q, k_cache, v_cache, cache_len, *, sm_scale=None):
    return ref.decode_attention(q, k_cache, v_cache, cache_len, sm_scale=sm_scale)
