"""Execution backends.

* ``interp``  — numpy reference interpreter: the executable semantics of the
  abstract Collection Virtual Machine.  Slow, exact, the oracle for every
  rewriting test ("transformations must preserve behaviour *as if executed
  on that machine*").
* ``local``   — JITQ analogue: lower pipelines to XLA via ``jax.jit`` on a
  single device.
* ``spmd``    — Modularis analogue: ``mesh.*`` flavor lowered to
  ``jax.shard_map`` + ``jax.lax`` collectives over a device mesh.
* ``multipod``— Lambada analogue: adds the elastic "pod" axis.
"""
