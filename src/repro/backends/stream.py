"""Streaming backend: micro-batched incremental execution of a split plan.

``StreamBackend.compile`` runs :func:`~repro.core.passes.lower_stream.lower_stream`
on the lowered vec program and compiles each segment through the ordinary
:class:`~repro.backends.local.LocalBackend` (each segment is one jitted
callable).  The resulting :class:`StreamExecutable` exposes two faces:

* the **batch face** — ``executable(sources)`` folds the full stream table
  as a sequence of micro-batches and finalizes, so a stream plan is a
  drop-in :class:`~repro.backends.local.Compiled` replacement: the
  driver's dispatch, the exec-guard fallback chain, and
  ``Context.execute`` all work unchanged, and the result is
  element-identical to the batch targets (the exactly-once oracle);
* the **incremental face** — ``bind(sources)`` → ``init_state()`` →
  ``step(state, batch)`` per micro-batch → ``finalize(state)`` on demand,
  which is what :class:`~repro.launch.serve.StreamConsumer` drives, with
  ``state_to_tree``/``state_from_tree`` converting the carried accumulator
  to a plain dict pytree for :class:`~repro.distributed.checkpoint.CheckpointManager`.

The carried state is the terminal aggregation's own output collection — a
``GroupAggDirect``/``GroupAggSorted`` grouped VecTable or an ``AggrVec``
scalar dict — and the initial state is the batch segment applied to an
all-invalid batch, which yields the aggregation identities (sum 0, count
0, min +inf, max −inf) with the exact state structure for free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Mapping, Optional

import jax.numpy as jnp
import numpy as np

from ..core.passes.lower_stream import StreamPlan, lower_stream
from ..core.program import Program
from ..relational.runtime import VecTable
from .local import Compiled, LocalBackend

__all__ = ["StreamBackend", "StreamExecutable"]


@dataclass
class StreamExecutable:
    """A compiled stream plan: fold micro-batches, snapshot-able state."""

    program: Program                  # full lowered program (provenance)
    plan: StreamPlan
    stream_table: str
    batch_rows: int
    _static: Optional[Compiled]
    _batch: Compiled
    _merge: Compiled
    _finalize: Optional[Compiled]
    #: boundary values from the one-shot static segment (build tables,
    #: encode dictionaries, ...), split per consuming segment
    _batch_args: Optional[List[Any]] = None
    _finalize_args: Optional[List[Any]] = None
    #: stream column dtypes, captured at bind() for empty/padded batches
    _schema: Optional[Dict[str, Any]] = None

    # -- the incremental face ------------------------------------------------

    def bind(self, sources: Mapping[str, Any]) -> "StreamExecutable":
        """Run the static segment once and capture the stream schema.

        ``sources`` must hold every non-stream table the plan scans plus
        the stream table itself (possibly with zero valid rows — only its
        column dtypes are read).  The static results — including join
        build tables — are carried across every subsequent micro-batch.
        """
        src = dict(sources)
        tmpl = src.get(self.stream_table)
        if tmpl is None:
            raise KeyError(
                f"bind() needs the stream table {self.stream_table!r} in "
                f"sources (its dtypes type the micro-batches); got "
                f"{sorted(src)}")
        self._schema = {k: np.asarray(v[:1]).dtype for k, v in tmpl.cols.items()}
        if self._static is not None:
            outs = self._static(src)
            by_name = {r.name: v for r, v in
                       zip(self.plan.static_program.results, outs)}
            self._batch_args = [by_name[r.name]
                                for r in self.plan.batch_boundary]
            self._finalize_args = [by_name[r.name]
                                   for r in self.plan.finalize_boundary]
        else:
            self._batch_args = []
            self._finalize_args = []
        return self

    def _require_bound(self) -> None:
        if self._batch_args is None:
            raise RuntimeError("StreamExecutable is unbound; call "
                               "bind(sources) before init_state/step")

    def empty_batch(self) -> VecTable:
        """An all-invalid micro-batch (the aggregation identity input)."""
        self._require_bound()
        n = self.batch_rows
        return VecTable({k: jnp.zeros((n,), dtype=dt)
                         for k, dt in self._schema.items()},
                        jnp.zeros((n,), dtype=bool))

    def as_batch(self, batch: Any) -> VecTable:
        """Coerce one micro-batch to a VecTable at batch capacity."""
        if isinstance(batch, VecTable):
            if batch.capacity != self.batch_rows:
                batch = VecTable.from_numpy(batch.to_numpy(), self.batch_rows)
            return batch
        return VecTable.from_numpy(dict(batch), self.batch_rows)

    def init_state(self) -> Any:
        self._require_bound()
        (state,) = self._batch({self.stream_table: self.empty_batch()},
                               *self._batch_args)
        return state

    def step(self, state: Any, batch: Any) -> Any:
        """Fold one micro-batch into the carried state (pure)."""
        self._require_bound()
        vt = self.as_batch(batch)
        (delta,) = self._batch({self.stream_table: vt}, *self._batch_args)
        (merged,) = self._merge({}, state, delta)
        return merged

    def finalize(self, state: Any) -> List[Any]:
        """Answer the query from the current state (decode, avg, sort...)."""
        self._require_bound()
        if self._finalize is None:
            return [state]
        return self._finalize({}, state, *self._finalize_args)

    # -- snapshot conversion (stable pytree paths for the checkpointer) -----

    def state_to_tree(self, state: Any) -> Dict[str, Any]:
        if self.plan.state_kind == "grouped":
            return {"cols": {k: np.asarray(v) for k, v in state.cols.items()},
                    "valid": np.asarray(state.valid)}
        return {k: np.asarray(v) for k, v in state.items()}

    def state_from_tree(self, tree: Mapping[str, Any]) -> Any:
        if self.plan.state_kind == "grouped":
            return VecTable({k: jnp.asarray(v)
                             for k, v in tree["cols"].items()},
                            jnp.asarray(tree["valid"]))
        return {k: jnp.asarray(v) for k, v in tree.items()}

    # -- the batch face ------------------------------------------------------

    def batches_of(self, table: VecTable) -> Iterator[Dict[str, np.ndarray]]:
        """Split a full table's valid rows into micro-batch column dicts."""
        rows = table.to_numpy()
        n = len(next(iter(rows.values()))) if rows else 0
        for lo in range(0, n, self.batch_rows):
            yield {k: v[lo:lo + self.batch_rows] for k, v in rows.items()}
        if n == 0:
            yield {k: v[:0] for k, v in rows.items()}

    def __call__(self, sources: Optional[Mapping[str, Any]] = None,
                 *args: Any) -> List[Any]:
        src = dict(sources or {})
        self.bind(src)
        state = self.init_state()
        for batch in self.batches_of(src[self.stream_table]):
            state = self.step(state, batch)
        return self.finalize(state)


class StreamBackend:
    name = "stream"

    def __init__(self, opts: Any) -> None:
        self.opts = opts

    def compile(self, program: Program) -> StreamExecutable:
        stream_table = self.opts.stream_table
        if not stream_table:
            raise ValueError(
                "the stream target needs stream_table=... (the table "
                "delivered as micro-batches)")
        batch_rows = int(self.opts.batch_rows or 256)
        plan = lower_stream(program, stream_table)
        local = LocalBackend(use_kernels=self.opts.use_kernels,
                             jit=self.opts.jit)
        return StreamExecutable(
            program=program,
            plan=plan,
            stream_table=stream_table,
            batch_rows=batch_rows,
            _static=(local.compile(plan.static_program)
                     if plan.static_program is not None else None),
            _batch=local.compile(plan.batch_program),
            _merge=local.compile(plan.merge_program),
            _finalize=(local.compile(plan.finalize_program)
                       if plan.finalize_program is not None else None),
        )
