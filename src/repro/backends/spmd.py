"""SPMD mesh backend — the Modularis analogue on TPU.

Backend-specific rewrite + lowering:

  * ``cf.ConcurrentExecute`` → ``mesh.MeshExecute(axis)``: the chunk axis
    becomes a named mesh axis; the nested program body runs under
    ``jax.shard_map`` (per-device slice), so XLA compiles ONE program for
    all workers (SPMD) — the TPU equivalent of Modularis' MPIExecutor.
  * value model: a split ``Seq[n]⟨X⟩`` is a *stacked* global array (leading
    worker dim) sharded along that dim; ``Broadcast`` replicates.
  * combines after a MeshExecute can be pulled inside as collectives
    (``PushCombineIntoMesh``): CombineChunks(sum) → ``lax.psum`` over the
    mesh axis inside the body — the paper's pre-aggregation becoming an
    all-reduce instead of a gather+reduce.  Exchange-by-key lowers to
    histogram partitioning + ``lax.all_to_all`` (MPIHistogram+MPIExchange).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.program import Program
# The backend-specific rewritings (LowerToMesh, PushCombineIntoMesh) are
# registered pipeline stages now — re-exported here for compatibility.
from ..core.passes.mesh_lower import LowerToMesh, PushCombineIntoMesh  # noqa: F401
from ..relational.runtime import VecTable
from ..robust.inject import maybe_inject
from . import emit as base_emit
from .emit import EvalCtx, evaluate_program


# ---------------------------------------------------------------------------
# SPMD emitters
# ---------------------------------------------------------------------------

_SPMD_EMIT: Dict[str, Callable[..., List[Any]]] = {}


def spmd_emitter(opcode: str):
    def deco(fn):
        _SPMD_EMIT[opcode] = fn
        return fn
    return deco


def _shard_map(body, mesh, in_specs, out_specs):
    """jax.shard_map moved out of jax.experimental (and check_vma was called
    check_rep) across jax releases — paper over both spellings."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as esm
    return esm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _stack_split(v: Any, n: int) -> Any:
    """Split a value into a stacked leading worker dim (global view)."""
    if isinstance(v, VecTable):
        cap = v.capacity
        assert cap % n == 0
        return VecTable(
            {k: a.reshape(n, cap // n) for k, a in v.cols.items()},
            v.valid.reshape(n, cap // n),
        )
    return v.reshape((n, v.shape[0] // n) + v.shape[1:])


def _unstack_merge(v: Any) -> Any:
    if isinstance(v, VecTable):
        n, c = v.valid.shape[0], v.valid.shape[1]
        return VecTable(
            {k: a.reshape((n * c,) + a.shape[2:]) for k, a in v.cols.items()},
            v.valid.reshape(n * c),
        )
    return v.reshape((v.shape[0] * v.shape[1],) + v.shape[2:])


@spmd_emitter("cf.Split")
def _split(ctx, ins, args):
    return [_stack_split(args[0], int(ins.param("n")))]


@spmd_emitter("cf.Merge")
def _merge(ctx, ins, args):
    return [_unstack_merge(args[0])]


@spmd_emitter("cf.Broadcast")
def _broadcast(ctx, ins, args):
    return [("bcast", args[0])]


@spmd_emitter("cf.TakeChunk")
def _take(ctx, ins, args):
    v = args[0]
    i = int(ins.param("i", 0))
    if isinstance(v, VecTable):
        return [VecTable({k: a[i] for k, a in v.cols.items()}, v.valid[i])]
    return [jax.tree_util.tree_map(lambda a: a[i], v)]


@spmd_emitter("cf.CombineChunks")
def _combine(ctx, ins, args):
    op = ins.param("op")
    fn = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[op]
    return [jax.tree_util.tree_map(lambda a: fn(a, axis=0), args[0])]


@spmd_emitter("rel.CombinePartials")
def _combine_partials(ctx, ins, args):
    (stacked,) = args  # dict of (n,) arrays
    out = {}
    for a in ins.param("aggs"):
        vals = stacked[a.name]
        out[a.name] = {"sum": jnp.sum, "min": jnp.min, "max": jnp.max}[a.combine_fn](vals)
    return [out]


@spmd_emitter("mesh.MeshExecute")
def _mesh_execute(ctx, ins, args):
    """Run the nested program as one SPMD body under shard_map."""
    p: Program = ins.param("P")
    axis = ins.param("axis", "workers")
    mesh: Mesh = ctx.mesh

    bcast_flags = [isinstance(a, tuple) and len(a) == 2 and a[0] == "bcast" for a in args]
    values = [a[1] if f else a for a, f in zip(args, bcast_flags)]

    def spec_for(v, bcast):
        def leaf_spec(x):
            return P() if bcast else P(axis)
        return jax.tree_util.tree_map(leaf_spec, v)

    in_specs = tuple(spec_for(v, f) for v, f in zip(values, bcast_flags))
    out_specs = P(axis)

    def body(*worker_args):
        local = []
        for a, f in zip(worker_args, bcast_flags):
            if f:
                local.append(a)
            else:
                local.append(jax.tree_util.tree_map(lambda x: x[0], a))
        inner_ctx = EvalCtx(sources=ctx.sources, use_kernels=ctx.use_kernels,
                            mesh=mesh, axis=axis, interpret=ctx.interpret)
        outs = evaluate_spmd_program(inner_ctx, p, *local)
        return tuple(jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], o)
                     for o in outs)

    shard_fn = _shard_map(body, mesh, in_specs,
                          tuple(out_specs for _ in p.results))
    outs = shard_fn(*values)
    return list(outs)


@spmd_emitter("mesh.AllReduce")
def _allreduce(ctx, ins, args):
    axis = ins.param("axis")
    op = ins.param("op", "sum")
    (x,) = args
    if op == "combine_aggs":
        out = {}
        for a in ins.param("aggs"):
            fn = {"sum": jax.lax.psum, "min": jax.lax.pmin, "max": jax.lax.pmax}[a.combine_fn]
            out[a.name] = fn(x[a.name], axis)
        return [out]
    fn = {"sum": jax.lax.psum, "min": jax.lax.pmin, "max": jax.lax.pmax}[op]
    return [jax.tree_util.tree_map(lambda v: fn(v, axis), x)]


@spmd_emitter("mesh.AllGatherVec")
def _allgather(ctx, ins, args):
    (v,) = args
    axis = ins.param("axis")
    if isinstance(v, VecTable):
        cols = {k: jax.lax.all_gather(a, axis, tiled=True) for k, a in v.cols.items()}
        return [VecTable(cols, jax.lax.all_gather(v.valid, axis, tiled=True))]
    return [jax.lax.all_gather(v, axis, tiled=True)]


@spmd_emitter("mesh.ExchangeByKey")
def _exchange(ctx, ins, args):
    """Histogram partition + all_to_all: rows with equal keys land on the
    same device (MPIHistogram + MPIExchange)."""
    (v,) = args
    axis = ins.param("axis")
    n = int(ins.param("n"))
    key = ins.param("key")
    skew = float(ins.param("skew", 2.0))
    cap = v.capacity
    per = int(cap * skew) // n * n // n  # per-destination slots

    dest = (v.cols[key].astype(jnp.uint32) % jnp.uint32(n)).astype(jnp.int32)
    dest = jnp.where(v.valid, dest, n)  # invalid → dropped bucket

    # slot position within destination bucket
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    start = jnp.searchsorted(sorted_dest, jnp.arange(n + 1))
    pos_sorted = jnp.arange(cap) - start[sorted_dest]
    keep = (pos_sorted < per) & (sorted_dest < n)
    slot_sorted = jnp.where(keep, sorted_dest * per + pos_sorted, n * per)

    def scatter(col):
        buf = jnp.zeros((n * per + 1,), col.dtype)
        return buf.at[slot_sorted].set(col[order])[:-1].reshape(n, per)

    cols = {k: scatter(a) for k, a in v.cols.items()}
    valid = jnp.zeros((n * per + 1,), jnp.bool_).at[slot_sorted].set(
        keep)[:-1].reshape(n, per)
    # exchange: concat over source workers of bucket for me
    cols = {k: jax.lax.all_to_all(a, axis, split_axis=0, concat_axis=0)
            for k, a in cols.items()}
    valid = jax.lax.all_to_all(valid, axis, split_axis=0, concat_axis=0)
    return [VecTable({k: a.reshape(-1) for k, a in cols.items()}, valid.reshape(-1))]


def evaluate_spmd_program(ctx: EvalCtx, program: Program, *args: Any) -> List[Any]:
    maybe_inject("spmd.shard", program=program.name)
    env: Dict[str, Any] = {r.name: v for r, v in zip(program.inputs, args)}
    for i, ins in enumerate(program.body):
        fn = _SPMD_EMIT.get(ins.opcode) or base_emit._EMIT.get(ins.opcode)
        if fn is None:
            raise NotImplementedError(f"spmd backend: no emitter for {ins.opcode}")
        ins_args = [env[r.name] for r in ins.inputs]
        outs = fn(ctx, ins, ins_args)
        if ctx.taps is not None:
            # top-level only: MeshExecute bodies run under shard_map with a
            # fresh tap-free ctx, so a stacked MeshExecute output is tapped
            # here once — its count() sums valid rows across all shards
            base_emit.record_tap(ctx, program, i, ins, ins_args, outs)
        for r, v in zip(ins.outputs, outs):
            env[r.name] = v
    return [env[r.name] for r in program.results]


# ---------------------------------------------------------------------------
# backend facade
# ---------------------------------------------------------------------------


@dataclass
class SpmdCompiled:
    program: Program
    fn: Callable[..., List[Any]]
    traced_fn: Optional[Callable[..., Any]] = None

    def __call__(self, sources=None, *args):
        return self.fn(dict(sources or {}), *args)

    def run_traced(self, sources=None, *args):
        """Execute and measure: ``(results, {tap key → TapRecord}, {})``."""
        from ..obs.feedback import TapRecord

        outs, taps = self.traced_fn(dict(sources or {}), *args)
        cards = {
            k: TapRecord(int(occ), None if ri is None else int(ri), int(ro))
            for k, (occ, ri, ro) in taps.items()
        }
        return outs, cards, {}


class SpmdBackend:
    """Compile a parallelized CVM program for a device mesh."""

    name = "spmd"

    def __init__(self, mesh: Mesh, axis: str = "workers", use_kernels: bool = False,
                 collectives: bool = True, jit: bool = True,
                 rewrite: bool = True) -> None:
        self.mesh = mesh
        self.axis = axis
        self.use_kernels = use_kernels
        self.collectives = collectives
        self.jit = jit
        # standalone use still rewrites here; the compilation driver runs the
        # same rules as pipeline stages and passes rewrite=False
        self.rewrite = rewrite

    def compile(self, program: Program) -> SpmdCompiled:
        if self.rewrite:
            program = LowerToMesh(self.axis).apply(program)
            if self.collectives:
                program = PushCombineIntoMesh().apply(program)

        def run(sources: Dict[str, Any], *args: Any) -> List[Any]:
            ctx = EvalCtx(sources=sources, use_kernels=self.use_kernels,
                          mesh=self.mesh)
            return evaluate_spmd_program(ctx, program, *args)

        def run_traced(sources: Dict[str, Any], *args: Any):
            ctx = EvalCtx(sources=sources, use_kernels=self.use_kernels,
                          mesh=self.mesh, taps={})
            outs = evaluate_spmd_program(ctx, program, *args)
            return outs, ctx.taps

        fn = jax.jit(run) if self.jit else run
        tfn = jax.jit(run_traced) if self.jit else run_traced
        return SpmdCompiled(program, fn, tfn)
