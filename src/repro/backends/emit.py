"""JAX emitters: executable meaning of each opcode during lowering.

The final stage of compilation (paper §3.5): every instruction of the final
IR corresponds to an executable building block.  Here the building blocks
are pure JAX functions; tracing the whole program under ``jax.jit`` is the
JIT-compile-the-pipeline step (XLA plays the role of LLVM in JITQ).

Value model (mirrors ``backends.interp`` but on device):
  Vec⟨tuple⟩ → VecTable, Single⟨tuple⟩ → dict[str, scalar], Tensor → Array,
  split Seq[n]⟨X⟩ → list of n values.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from ..core.expr import AggSpec, evaluate
from ..core.program import Instruction, Program
from ..relational import runtime as rt
from ..relational.runtime import VecTable

_EMIT: Dict[str, Callable[..., List[Any]]] = {}


def emitter(opcode: str):
    def deco(fn):
        _EMIT[opcode] = fn
        return fn
    return deco


@dataclass
class EvalCtx:
    """Carries sources and backend knobs through evaluation."""

    sources: Dict[str, Any] = field(default_factory=dict)
    use_kernels: bool = False
    mesh: Any = None            # set by the SPMD backend
    axis: Optional[str] = None  # mesh axis inside shard_map bodies
    interpret: bool = True      # pallas interpret mode (CPU container)
    #: traced executions install a dict here; tapped ops accumulate
    #: ``key → [occurrences, rows_in, rows_out]`` (rows are traced scalars
    #: under jit — returned from the compiled body, never host callbacks)
    taps: Optional[Dict[str, List[Any]]] = None


def tap_rows(v: Any) -> Any:
    """Cardinality of one runtime value: valid rows for a VecTable (a traced
    scalar under jit), leading dim for arrays and column dicts, summed
    chunks for split sequences, 1 for singles."""
    if isinstance(v, VecTable):
        return v.count()
    if isinstance(v, dict):
        if not v:
            return 0
        first = next(iter(v.values()))
        return first.shape[0] if getattr(first, "ndim", 0) >= 1 else 1
    if isinstance(v, (list, tuple)):
        return sum(tap_rows(c) for c in v)
    shape = getattr(v, "shape", None)
    if shape:
        return shape[0]
    return 1


def record_tap(ctx: EvalCtx, program: Program, index: int, ins: Instruction,
               args: Sequence[Any], outs: Sequence[Any]) -> None:
    """Accumulate one instruction's measured cardinality into ``ctx.taps``.

    Repeated hits of the same instruction (unrolled ConcurrentExecute
    bodies) sum their row counts — the summed-chunk global cardinality the
    profile joins against the per-chunk estimate × occurrences."""
    from ..obs.feedback import TAPPED_OPS, tap_key

    if ins.opcode not in TAPPED_OPS or not ins.outputs:
        return
    key = tap_key(program.name, index, ins.opcode, ins.outputs[0].name)
    rows_in = tap_rows(args[0]) if args else None
    rows_out = tap_rows(outs[0])
    entry = ctx.taps.get(key)
    if entry is None:
        ctx.taps[key] = [1, rows_in, rows_out]
    else:
        entry[0] += 1
        entry[1] = (None if entry[1] is None or rows_in is None
                    else entry[1] + rows_in)
        entry[2] = entry[2] + rows_out


def evaluate_program(ctx: EvalCtx, program: Program, *args: Any) -> List[Any]:
    """Trace a CVM program into JAX ops (call under jit)."""
    if len(args) != len(program.inputs):
        raise ValueError(f"{program.name}: expected {len(program.inputs)} args")
    env: Dict[str, Any] = {r.name: v for r, v in zip(program.inputs, args)}
    for i, ins in enumerate(program.body):
        fn = _EMIT.get(ins.opcode)
        if fn is None:
            raise NotImplementedError(f"no JAX emitter for {ins.opcode}")
        ins_args = [env[r.name] for r in ins.inputs]
        outs = fn(ctx, ins, ins_args)
        if ctx.taps is not None:
            record_tap(ctx, program, i, ins, ins_args, outs)
        for r, v in zip(ins.outputs, outs):
            env[r.name] = v
    return [env[r.name] for r in program.results]


# ---------------------------------------------------------------------------
# vec flavor
# ---------------------------------------------------------------------------


@emitter("vec.ScanVec")
def _scanvec(ctx, ins, args):
    return [ctx.sources[ins.param("table")]]


@emitter("vec.MaskSelect")
def _maskselect(ctx, ins, args):
    return [rt.mask_select(args[0], ins.param("pred"))]


@emitter("vec.ProjVec")
def _projvec(ctx, ins, args):
    return [rt.proj(args[0], ins.param("names"))]


@emitter("vec.ExProjVec")
def _exprojvec(ctx, ins, args):
    return [rt.exproj(args[0], ins.param("exprs"))]


@emitter("vec.AggrVec")
def _aggrvec(ctx, ins, args):
    return [rt.aggr(args[0], ins.param("aggs"))]


@emitter("vec.FusedSelectAgg")
def _fused_select_agg(ctx, ins, args):
    (t,) = args
    pred, aggs = ins.param("pred"), ins.param("aggs")
    if ctx.use_kernels:
        from ..kernels import ops as kops
        return [kops.fused_select_agg(t, pred, aggs, interpret=ctx.interpret)]
    return [rt.aggr(rt.mask_select(t, pred), aggs)]


@emitter("vec.FinalizeSingle")
def _finalize_single(ctx, ins, args):
    (single,) = args
    return [{n: evaluate(e, single, jnp) for n, e in ins.param("exprs")}]


@emitter("vec.SortByKey")
def _sortbykey(ctx, ins, args):
    keys = ins.param("keys")
    asc = ins.param("ascending") or [True] * len(keys)
    return [rt.sort_by_key(args[0], keys, asc)]


@emitter("vec.GroupAggSorted")
def _groupagg(ctx, ins, args):
    return [rt.group_agg_sorted(args[0], ins.param("keys"), ins.param("aggs"),
                                int(ins.param("max_groups")))]


#: bucket counts beyond this skip the Pallas kernel (its per-block one-hot
#: accumulator scales with num_buckets) and use the XLA segment reduction
_KERNEL_MAX_BUCKETS = 4096


@emitter("vec.GroupAggDirect")
def _groupagg_direct(ctx, ins, args):
    (t,) = args
    keys = tuple(ins.param("keys"))
    aggs = tuple(ins.param("aggs"))
    mg = int(ins.param("max_groups"))
    domains = tuple(ins.param("key_domains"))
    nb = int(ins.param("num_buckets"))
    pred = ins.param("pred")
    if ctx.use_kernels and nb <= _KERNEL_MAX_BUCKETS:
        from ..kernels import ops as kops
        return [kops.grouped_select_agg(t, pred, keys, aggs, mg, domains, nb,
                                        interpret=ctx.interpret)]
    return [rt.group_agg_direct(t, keys, aggs, mg, domains, nb, pred=pred)]


@emitter("vec.DictEncode")
def _dictencode(ctx, ins, args):
    return [rt.dict_encode(args[0], ins.param("cols"), ins.param("modes"),
                           ins.param("tables"), ins.param("lows"),
                           ins.param("cards"))]


@emitter("vec.DictDecode")
def _dictdecode(ctx, ins, args):
    return [rt.dict_decode(args[0], ins.param("cols"), ins.param("tables"))]


@emitter("vec.MergeJoinSorted")
def _mergejoin(ctx, ins, args):
    return [rt.merge_join_sorted(args[0], args[1], ins.param("left_on"),
                                 ins.param("right_on"), int(ins.param("max_count")),
                                 key_domains=ins.param("key_domains"))]


@emitter("vec.HashJoinDirect")
def _hashjoin_direct(ctx, ins, args):
    nb = ins.param("num_buckets")
    return [rt.hash_join_direct(args[0], args[1], ins.param("left_on"),
                                ins.param("right_on"),
                                int(ins.param("max_count")),
                                key_domains=ins.param("key_domains"),
                                num_buckets=int(nb) if nb is not None else None)]


@emitter("vec.FusedJoinGroupAgg")
def _fused_join_group_agg(ctx, ins, args):
    left, right = args
    kw = dict(
        left_on=tuple(ins.param("left_on")),
        right_on=tuple(ins.param("right_on")),
        join_key_domains=tuple(ins.param("join_key_domains")),
        join_num_buckets=int(ins.param("join_num_buckets")),
        keys=tuple(ins.param("keys")),
        aggs=tuple(ins.param("aggs")),
        max_groups=int(ins.param("max_groups")),
        key_domains=tuple(ins.param("key_domains")),
        num_buckets=int(ins.param("num_buckets")),
        pred=ins.param("pred"),
    )
    if (ctx.use_kernels and kw["join_num_buckets"] <= _KERNEL_MAX_BUCKETS
            and kw["num_buckets"] <= _KERNEL_MAX_BUCKETS):
        from ..kernels import ops as kops
        return [kops.grouped_join_agg(left, right, interpret=ctx.interpret,
                                      **kw)]
    return [rt.fused_join_group_agg(left, right, **kw)]


@emitter("vec.MergeGroupedState")
def _merge_grouped_state(ctx, ins, args):
    kd = ins.param("key_domains")
    nb = ins.param("num_buckets")
    return [rt.merge_grouped_partials(
        args[0], args[1], tuple(ins.param("keys")), tuple(ins.param("aggs")),
        int(ins.param("max_groups")),
        key_domains=tuple(kd) if kd is not None else None,
        num_buckets=int(nb) if nb is not None else None)]


@emitter("vec.MergeScalarState")
def _merge_scalar_state(ctx, ins, args):
    return [rt.merge_scalar_partials(args[0], args[1],
                                     tuple(ins.param("aggs")))]


@emitter("vec.Compact")
def _compact(ctx, ins, args):
    return [rt.compact(args[0], ins.param("max_count"))]


@emitter("vec.TopKVec")
def _topkvec(ctx, ins, args):
    keys = ins.param("keys")
    asc = ins.param("ascending") or [True] * len(keys)
    return [rt.topk(args[0], keys, asc, int(ins.param("k")))]


@emitter("vec.LimitVec")
def _limitvec(ctx, ins, args):
    return [rt.limit(args[0], int(ins.param("k")))]


@emitter("vec.SplitVec")
def _splitvec(ctx, ins, args):
    return [rt.split(args[0], int(ins.param("n")))]


@emitter("vec.ConcatVec")
def _concatvec(ctx, ins, args):
    return [rt.concat(args[0])]


@emitter("rel.CombinePartials")
def _combinepartials(ctx, ins, args):
    return [rt.combine_partials(args[0], ins.param("aggs"))]


# ---------------------------------------------------------------------------
# control flow
# ---------------------------------------------------------------------------


def _split_value(v: Any, n: int) -> List[Any]:
    if isinstance(v, VecTable):
        return rt.split(v, n)
    arrs = jnp.split(v, n, axis=0)
    return list(arrs)


def _merge_value(chunks: List[Any]) -> Any:
    if isinstance(chunks[0], VecTable):
        return rt.concat(chunks)
    return jnp.concatenate(chunks, axis=0)


@emitter("cf.Split")
def _cf_split(ctx, ins, args):
    return [_split_value(args[0], int(ins.param("n")))]


@emitter("cf.Broadcast")
def _cf_broadcast(ctx, ins, args):
    return [[args[0]] * int(ins.param("n"))]


@emitter("cf.Merge")
def _cf_merge(ctx, ins, args):
    return [_merge_value(args[0])]


@emitter("cf.ConcurrentExecute")
def _cf_ce(ctx, ins, args):
    """Local lowering of ConcurrentExecute: unrolled per-chunk traces.

    On a single device the concurrency comes from XLA's own parallelism
    (JITQ analogue: thread-level parallelism inside one fused module).  The
    SPMD backend overrides this with a shard_map lowering.
    """
    p: Program = ins.param("P")
    n = len(args[0])
    per_worker = [[a[w] for a in args] for w in range(n)]
    results: List[List[Any]] = [[] for _ in p.results]
    for w in range(n):
        outs = evaluate_program(ctx, p, *per_worker[w])
        for i, o in enumerate(outs):
            results[i].append(o)
    return results


@emitter("cf.CombineChunks")
def _cf_combine(ctx, ins, args):
    (chunks,) = args
    op = ins.param("op")
    fn = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}[op]
    acc = chunks[0]
    for c in chunks[1:]:
        acc = jax.tree_util.tree_map(fn, acc, c)
    return [acc]


@emitter("cf.TakeChunk")
def _cf_take(ctx, ins, args):
    return [args[0][int(ins.param("i", 0))]]


@emitter("cf.Loop")
def _cf_loop(ctx, ins, args):
    p: Program = ins.param("P")
    n = int(ins.param("n"))
    state = list(args)
    if n <= 4:  # unroll small loops (lets XLA fuse across iterations)
        for _ in range(n):
            state = evaluate_program(ctx, p, *state)
        return state

    def body(carry, _):
        outs = evaluate_program(ctx, p, *carry)
        return tuple(outs), None

    final, _ = jax.lax.scan(body, tuple(state), None, length=n)
    return list(final)


@emitter("cf.While")
def _cf_while(ctx, ins, args):
    p: Program = ins.param("P")

    def cond(carry):
        outs = evaluate_program(ctx, p, *carry)
        return outs[0]

    def body(carry):
        outs = evaluate_program(ctx, p, *carry)
        return tuple(outs[1:])

    final = jax.lax.while_loop(cond, body, tuple(args))
    return list(final)


@emitter("cf.Cond")
def _cf_cond(ctx, ins, args):
    pred, rest = args[0], args[1:]
    pt, pe = ins.param("Pthen"), ins.param("Pelse")
    return list(jax.lax.cond(
        pred,
        lambda xs: tuple(evaluate_program(ctx, pt, *xs)),
        lambda xs: tuple(evaluate_program(ctx, pe, *xs)),
        tuple(rest),
    ))


@emitter("cf.Call")
def _cf_call(ctx, ins, args):
    return evaluate_program(ctx, ins.param("P"), *args)


# ---------------------------------------------------------------------------
# dataflow + linear algebra
# ---------------------------------------------------------------------------


@emitter("df.Source")
def _df_source(ctx, ins, args):
    return [ctx.sources[ins.param("name")]]


@emitter("df.Collect")
def _df_collect(ctx, ins, args):
    return [args[0]]


@emitter("la.Literal")
def _la_literal(ctx, ins, args):
    name = ins.param("name")
    if name is not None and name in ctx.sources:
        return [ctx.sources[name]]
    return [jnp.asarray(ins.param("value"))]


@emitter("la.MMMult")
def _la_mmmult(ctx, ins, args):
    return [args[0] @ args[1]]


@emitter("la.Transpose")
def _la_transpose(ctx, ins, args):
    return [args[0].T]


@emitter("la.Ewise")
def _la_ewise(ctx, ins, args):
    op = ins.param("op")
    if len(args) == 1:
        a = args[0]
        return [{"neg": lambda: -a, "abs": lambda: jnp.abs(a), "add": lambda: a,
                 "sqrt": lambda: jnp.sqrt(a), "square": lambda: a * a}[op]()]
    a, b = args
    return [{"add": lambda: a + b, "sub": lambda: a - b,
             "mul": lambda: a * b, "div": lambda: a / b}[op]()]


@emitter("la.ReduceSum")
def _la_reducesum(ctx, ins, args):
    return [jnp.sum(args[0], axis=int(ins.param("axis")))]


@emitter("la.CDist2")
def _la_cdist2(ctx, ins, args):
    x, c = args
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1, keepdims=True).T
    return [x2 - 2.0 * (x @ c.T) + c2]


@emitter("la.ArgMinRow")
def _la_argminrow(ctx, ins, args):
    return [jnp.argmin(args[0], axis=1).astype(jnp.int32)]


@emitter("la.SegSum")
def _la_segsum(ctx, ins, args):
    x, lab = args
    k = int(ins.param("k"))
    return [jax.ops.segment_sum(x, lab, num_segments=k)]


@emitter("la.SegCount")
def _la_segcount(ctx, ins, args):
    lab = args[0]
    k = int(ins.param("k"))
    return [jax.ops.segment_sum(jnp.ones_like(lab, dtype=jnp.float32), lab, num_segments=k)]


@emitter("la.KMeansStep")
def _la_kmeans_step(ctx, ins, args):
    x, c = args
    if ctx.use_kernels:
        from ..kernels import ops as kops
        sums, counts = kops.kmeans_step(x, c, interpret=ctx.interpret)
        return [sums, counts]
    d = _la_cdist2(ctx, ins, args)[0]
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    k = c.shape[0]
    sums = jax.ops.segment_sum(x, lab, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones_like(lab, dtype=jnp.float32), lab, num_segments=k)
    return [sums, counts]
