"""Local backend — the JITQ analogue.

Lowers a final-flavor CVM program into one ``jax.jit``-compiled callable:
tree-shaped data paths fuse inside XLA exactly like JITQ's pipeline JIT;
``ConcurrentExecute`` unrolls into per-chunk traces whose parallelism XLA
exploits on the host (thread-level).  ``compile`` returns an executable that
takes the source collections and returns the program results.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional

import jax

from ..core.program import Program
from .emit import EvalCtx, evaluate_program


@dataclass
class Compiled:
    program: Program
    fn: Callable[..., List[Any]]

    def __call__(self, sources: Optional[Mapping[str, Any]] = None, *args: Any) -> List[Any]:
        return self.fn(dict(sources or {}), *args)


class LocalBackend:
    name = "local"

    def __init__(self, use_kernels: bool = False, interpret: bool = True,
                 jit: bool = True) -> None:
        self.use_kernels = use_kernels
        self.interpret = interpret
        self.jit = jit

    def compile(self, program: Program) -> Compiled:
        def run(sources: Dict[str, Any], *args: Any) -> List[Any]:
            ctx = EvalCtx(sources=sources, use_kernels=self.use_kernels,
                          interpret=self.interpret)
            return evaluate_program(ctx, program, *args)

        fn = jax.jit(run) if self.jit else run
        return Compiled(program, fn)
