"""Local backend — the JITQ analogue.

Lowers a final-flavor CVM program into one ``jax.jit``-compiled callable:
tree-shaped data paths fuse inside XLA exactly like JITQ's pipeline JIT;
``ConcurrentExecute`` unrolls into per-chunk traces whose parallelism XLA
exploits on the host (thread-level).  ``compile`` returns an executable that
takes the source collections and returns the program results.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional

import jax

from ..core.program import Program
from .emit import EvalCtx, evaluate_program


@dataclass
class Compiled:
    program: Program
    fn: Callable[..., List[Any]]
    #: variant of ``fn`` that also returns the cardinality taps (jitted
    #: separately — the traced path must not slow the plain one down)
    traced_fn: Optional[Callable[..., Any]] = None

    def __call__(self, sources: Optional[Mapping[str, Any]] = None, *args: Any) -> List[Any]:
        return self.fn(dict(sources or {}), *args)

    def run_traced(self, sources: Optional[Mapping[str, Any]] = None,
                   *args: Any):
        """Execute and measure: ``(results, {tap key → TapRecord}, {})``.

        Cardinalities come back as scalar outputs of the jitted body
        (host-callback-free); per-op wall times are not observable inside a
        fused XLA module, hence the empty third element."""
        from ..obs.feedback import TapRecord

        outs, taps = self.traced_fn(dict(sources or {}), *args)
        cards = {
            k: TapRecord(int(occ), None if ri is None else int(ri), int(ro))
            for k, (occ, ri, ro) in taps.items()
        }
        return outs, cards, {}


class LocalBackend:
    name = "local"

    def __init__(self, use_kernels: bool = False, interpret: bool = True,
                 jit: bool = True) -> None:
        self.use_kernels = use_kernels
        self.interpret = interpret
        self.jit = jit

    def compile(self, program: Program) -> Compiled:
        def run(sources: Dict[str, Any], *args: Any) -> List[Any]:
            ctx = EvalCtx(sources=sources, use_kernels=self.use_kernels,
                          interpret=self.interpret)
            return evaluate_program(ctx, program, *args)

        def run_traced(sources: Dict[str, Any], *args: Any):
            ctx = EvalCtx(sources=sources, use_kernels=self.use_kernels,
                          interpret=self.interpret, taps={})
            outs = evaluate_program(ctx, program, *args)
            return outs, ctx.taps

        fn = jax.jit(run) if self.jit else run
        tfn = jax.jit(run_traced) if self.jit else run_traced
        return Compiled(program, fn, tfn)
