"""Numpy reference interpreter for CVM programs.

Value representation per type:

* relation (Bag/Set/Seq of tuples)  → ``dict[str, np.ndarray]`` (equal length)
* ``Single⟨tuple⟩``                 → ``dict[str, scalar]``
* ``Tensor`` / KDSeq                → ``np.ndarray``
* split ``Seq[n]⟨X⟩``               → ``list`` of n values
* ``Single⟨X⟩`` (non-tuple)         → the value itself

ConcurrentExecute runs workers sequentially — the interpreter defines
*semantics*, not performance.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..core import registry
from ..core.expr import AggSpec, evaluate
from ..core.program import Instruction, Program

_EVAL: Dict[str, Callable[..., List[Any]]] = {}


def impl(opcode: str):
    def deco(fn):
        _EVAL[opcode] = fn
        return fn
    return deco


def _rows_of(v: Any) -> int:
    """Cardinality of one interpreter value (see the value model above)."""
    if isinstance(v, dict):
        if not v:
            return 0
        first = next(iter(v.values()))
        return int(np.asarray(first).shape[0]) if np.ndim(first) >= 1 else 1
    if isinstance(v, (list, tuple)):
        return sum(_rows_of(c) for c in v)
    if np.ndim(v) >= 1:
        return int(np.asarray(v).shape[0])
    return 1


class Interpreter:
    def __init__(self, sources: Optional[Mapping[str, Any]] = None,
                 max_while_iters: int = 10_000, trace: bool = False) -> None:
        self.sources = dict(sources or {})
        self.max_while_iters = max_while_iters
        #: tracing state (``trace=True``): tap key → [occ, rows_in, rows_out]
        #: and tap key → accumulated wall seconds.  The interpreter is eager,
        #: so unlike the jitted backends it can time individual operators.
        self.taps: Optional[Dict[str, List[Any]]] = {} if trace else None
        self.walls: Dict[str, float] = {}

    def run(self, program: Program, *args: Any) -> List[Any]:
        if len(args) != len(program.inputs):
            raise ValueError(
                f"program {program.name} takes {len(program.inputs)} inputs, got {len(args)}"
            )
        env: Dict[str, Any] = {r.name: v for r, v in zip(program.inputs, args)}
        if self.taps is not None:
            return self._run_traced(program, env)
        for ins in program.body:
            fn = _EVAL.get(ins.opcode)
            if fn is None:
                raise NotImplementedError(f"interpreter: no impl for {ins.opcode}")
            outs = fn(self, ins, [env[r.name] for r in ins.inputs])
            if len(outs) != len(ins.outputs):
                raise RuntimeError(f"{ins.opcode}: impl returned {len(outs)} values")
            for r, v in zip(ins.outputs, outs):
                env[r.name] = v
        return [env[r.name] for r in program.results]

    def _run_traced(self, program: Program, env: Dict[str, Any]) -> List[Any]:
        """The measured twin of the main loop: a span per operator (nested
        program runs — ConcurrentExecute bodies — nest naturally), wall time
        and output cardinality per tapped op."""
        from ..obs.feedback import TAPPED_OPS, tap_key
        from ..obs.trace import get_tracer

        tracer = get_tracer()
        for i, ins in enumerate(program.body):
            fn = _EVAL.get(ins.opcode)
            if fn is None:
                raise NotImplementedError(f"interpreter: no impl for {ins.opcode}")
            ins_args = [env[r.name] for r in ins.inputs]
            reg = ins.outputs[0].name if ins.outputs else ""
            t0 = time.perf_counter()
            with tracer.span(ins.opcode, cat="execute.op",
                             program=program.name, register=reg) as sp:
                outs = fn(self, ins, ins_args)
            dur = time.perf_counter() - t0
            if len(outs) != len(ins.outputs):
                raise RuntimeError(f"{ins.opcode}: impl returned {len(outs)} values")
            if ins.opcode in TAPPED_OPS and ins.outputs:
                key = tap_key(program.name, i, ins.opcode, reg)
                rows_in = _rows_of(ins_args[0]) if ins_args else None
                rows_out = _rows_of(outs[0])
                entry = self.taps.get(key)
                if entry is None:
                    self.taps[key] = [1, rows_in, rows_out]
                else:
                    entry[0] += 1
                    entry[1] = (None if entry[1] is None or rows_in is None
                                else entry[1] + rows_in)
                    entry[2] += rows_out
                self.walls[key] = self.walls.get(key, 0.0) + dur
                sp.set(rows_in=rows_in, rows_out=rows_out)
            for r, v in zip(ins.outputs, outs):
                env[r.name] = v
        return [env[r.name] for r in program.results]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _ncols(table: Dict[str, np.ndarray]) -> int:
    return len(next(iter(table.values()))) if table else 0


def _mask_table(table: Dict[str, np.ndarray], mask: np.ndarray) -> Dict[str, np.ndarray]:
    return {k: v[mask] for k, v in table.items()}


_AGG_INIT = {"sum": 0.0, "count": 0, "min": np.inf, "max": -np.inf}


def _agg_np(fn: str, arr: np.ndarray) -> Any:
    if fn == "count":
        return np.int64(arr.shape[0])
    if arr.shape[0] == 0:
        return np.float64(_AGG_INIT[fn])
    return {"sum": np.sum, "min": np.min, "max": np.max}[fn](arr.astype(np.float64))


def _apply_aggs(table: Dict[str, np.ndarray], aggs: Sequence[AggSpec]) -> Dict[str, Any]:
    out = {}
    for a in aggs:
        col_vals = evaluate(a.expr, table, np)
        if np.isscalar(col_vals) or getattr(col_vals, "ndim", 1) == 0:
            col_vals = np.full(_ncols(table), col_vals)
        out[a.name] = _agg_np(a.fn, np.asarray(col_vals))
    return out


# ---------------------------------------------------------------------------
# relational flavor
# ---------------------------------------------------------------------------


@impl("rel.Scan")
def _scan(interp: Interpreter, ins: Instruction, args: List[Any]) -> List[Any]:
    return [interp.sources[ins.param("table")]]


@impl("rel.Select")
def _select(interp, ins, args):
    (t,) = args
    mask = np.asarray(evaluate(ins.param("pred"), t, np), dtype=bool)
    return [_mask_table(t, mask)]


@impl("rel.Proj")
def _proj(interp, ins, args):
    (t,) = args
    return [{n: t[n] for n in ins.param("names")}]


@impl("rel.ExProj")
def _exproj(interp, ins, args):
    (t,) = args
    if t and all(np.ndim(v) == 0 for v in t.values()):  # Single⟨tuple⟩
        return [{name: evaluate(e, t, np) for name, e in ins.param("exprs")}]
    out = {}
    n = _ncols(t)
    for name, e in ins.param("exprs"):
        v = evaluate(e, t, np)
        if np.isscalar(v) or getattr(v, "ndim", 1) == 0:
            v = np.full(n, v)
        out[name] = np.asarray(v)
    return [out]


@impl("rel.Aggr")
def _aggr(interp, ins, args):
    (t,) = args
    return [_apply_aggs(t, ins.param("aggs"))]


@impl("rel.GroupByAggr")
def _groupby(interp, ins, args):
    (t,) = args
    keys = list(ins.param("keys"))
    aggs = list(ins.param("aggs"))
    n = _ncols(t)
    if n == 0:
        out = {k: np.asarray([]) for k in keys}
        out.update({a.name: np.asarray([]) for a in aggs})
        return [out]
    key_arrays = [np.asarray(t[k]) for k in keys]
    # group ids via lexsort-stable unique over structured rows
    stacked = np.rec.fromarrays(key_arrays, names=[f"k{i}" for i in range(len(keys))])
    uniq, inverse = np.unique(stacked, return_inverse=True)
    out: Dict[str, np.ndarray] = {}
    for i, k in enumerate(keys):
        out[k] = np.asarray(uniq[f"k{i}"])
    for a in aggs:
        vals = evaluate(a.expr, t, np)
        if np.isscalar(vals) or getattr(vals, "ndim", 1) == 0:
            vals = np.full(n, vals)
        vals = np.asarray(vals)
        out[a.name] = np.asarray(
            [_agg_np(a.fn, vals[inverse == g]) for g in range(len(uniq))]
        )
    return [out]


@impl("vec.GroupAggDirect")
def _vec_groupagg_direct(interp, ins, args):
    """Reference semantics of the dense-bucket grouped aggregation: the
    (optional) fused predicate, then exactly rel.GroupByAggr — the bucket
    layout is a physical detail the oracle need not reproduce."""
    (t,) = args
    pred = ins.param("pred")
    if pred is not None:
        mask = np.asarray(evaluate(pred, t, np), dtype=bool)
        t = _mask_table(t, mask)
    return _groupby(interp, ins, [t])


@impl("vec.DictEncode")
def _vec_dictencode(interp, ins, args):
    """Reference semantics of the rank encoding: value→rank against the
    sorted dictionary, out-of-dictionary → sentinel rank ``card``."""
    (t,) = args
    out = dict(t)
    for c, mode, table, lo, card in zip(
            ins.param("cols"), ins.param("modes"), ins.param("tables"),
            ins.param("lows"), ins.param("cards")):
        a = np.asarray(t[c])
        tab = np.asarray(table)
        if mode == "remap":
            idx = a.astype(np.int64) - int(lo)
            ok = (idx >= 0) & (idx < tab.shape[0])
            ranks = tab[np.clip(idx, 0, tab.shape[0] - 1)]
            out[c] = np.where(ok, ranks, card).astype(np.int32)
        else:
            i = np.searchsorted(tab, a)
            ic = np.clip(i, 0, card - 1)
            out[c] = np.where(tab[ic] == a, ic, card).astype(np.int32)
    return [out]


@impl("vec.DictDecode")
def _vec_dictdecode(interp, ins, args):
    (t,) = args
    out = dict(t)
    for c, table in zip(ins.param("cols"), ins.param("tables")):
        tab = np.asarray(table)
        ranks = np.clip(np.asarray(t[c]).astype(np.int64), 0, tab.shape[0] - 1)
        out[c] = tab[ranks]
    return [out]


@impl("rel.Join")
def _join(interp, ins, args):
    l, r = args
    left_on = list(ins.param("left_on"))
    right_on = list(ins.param("right_on"))
    # hash-join in python (oracle-grade)
    index: Dict[Any, List[int]] = {}
    rkeys = list(zip(*[np.asarray(r[k]).tolist() for k in right_on])) if _ncols(r) else []
    for i, k in enumerate(rkeys):
        index.setdefault(k, []).append(i)
    lkeys = list(zip(*[np.asarray(l[k]).tolist() for k in left_on])) if _ncols(l) else []
    li, ri = [], []
    for i, k in enumerate(lkeys):
        for j in index.get(k, ()):
            li.append(i)
            ri.append(j)
    li = np.asarray(li, dtype=np.int64)
    ri = np.asarray(ri, dtype=np.int64)
    out = {k: np.asarray(v)[li] for k, v in l.items()}
    lnames = set(l.keys())
    for k, v in r.items():
        if k in right_on:
            continue
        name = k if k not in lnames else k + "_r"
        out[name] = np.asarray(v)[ri]
    return [out]


@impl("rel.OrderBy")
def _orderby(interp, ins, args):
    (t,) = args
    keys = list(ins.param("keys"))
    asc = list(ins.param("ascending", [True] * len(keys)))
    arrays = []
    for k, a in zip(reversed(keys), reversed(asc)):
        arr = np.asarray(t[k])
        arrays.append(arr if a else -arr if np.issubdtype(arr.dtype, np.number) else arr[::-1])
    order = np.lexsort(arrays)
    return [{k: np.asarray(v)[order] for k, v in t.items()}]


@impl("rel.Limit")
def _limit(interp, ins, args):
    (t,) = args
    k = int(ins.param("k"))
    return [{kk: np.asarray(v)[:k] for kk, v in t.items()}]


@impl("rel.Distinct")
def _distinct(interp, ins, args):
    (t,) = args
    names = list(t.keys())
    stacked = np.rec.fromarrays([np.asarray(t[n]) for n in names],
                                names=[f"c{i}" for i in range(len(names))])
    uniq = np.unique(stacked)
    return [{n: np.asarray(uniq[f"c{i}"]) for i, n in enumerate(names)}]


@impl("rel.CombinePartials")
def _combine_partials(interp, ins, args):
    (partials,) = args  # list of dicts
    aggs: Sequence[AggSpec] = ins.param("aggs")
    out = {}
    for a in aggs:
        vals = np.asarray([p[a.name] for p in partials])
        out[a.name] = _agg_np(a.fn, vals) if a.fn != "count" else np.int64(np.sum(vals))
    return [out]


# ---------------------------------------------------------------------------
# control flow
# ---------------------------------------------------------------------------


def _split_value(v: Any, n: int) -> List[Any]:
    if isinstance(v, dict):  # table: split each column
        cols = {k: np.array_split(np.asarray(a), n) for k, a in v.items()}
        return [{k: cols[k][i] for k in cols} for i in range(n)]
    return [np.ascontiguousarray(c) for c in np.array_split(np.asarray(v), n)]


def _merge_value(chunks: List[Any]) -> Any:
    if isinstance(chunks[0], dict):
        return {k: np.concatenate([np.asarray(c[k]) for c in chunks]) for k in chunks[0]}
    return np.concatenate([np.asarray(c) for c in chunks], axis=0)


@impl("cf.Split")
def _cf_split(interp, ins, args):
    return [_split_value(args[0], int(ins.param("n")))]


@impl("cf.Broadcast")
def _cf_broadcast(interp, ins, args):
    return [[args[0]] * int(ins.param("n"))]


@impl("cf.Merge")
def _cf_merge(interp, ins, args):
    return [_merge_value(args[0])]


@impl("cf.ConcurrentExecute")
def _cf_ce(interp, ins, args):
    p: Program = ins.param("P")
    n = len(args[0])
    results: List[List[Any]] = [[] for _ in p.results]
    for w in range(n):
        outs = interp.run(p, *[a[w] for a in args])
        for i, o in enumerate(outs):
            results[i].append(o)
    return results


@impl("mesh.MeshExecute")
def _mesh_exec(interp, ins, args):
    return _cf_ce(interp, ins, args)


@impl("cf.CombineChunks")
def _cf_combine(interp, ins, args):
    (chunks,) = args
    op = ins.param("op")
    fn = {"sum": np.add, "min": np.minimum, "max": np.maximum}[op]
    acc = np.asarray(chunks[0], dtype=np.float64)
    for c in chunks[1:]:
        acc = fn(acc, np.asarray(c, dtype=np.float64))
    return [acc]


@impl("cf.TakeChunk")
def _cf_take(interp, ins, args):
    return [args[0][int(ins.param("i", 0))]]


@impl("cf.Loop")
def _cf_loop(interp, ins, args):
    p: Program = ins.param("P")
    state = list(args)
    for _ in range(int(ins.param("n"))):
        state = interp.run(p, *state)
    return state


@impl("cf.While")
def _cf_while(interp, ins, args):
    p: Program = ins.param("P")
    state = list(args)
    for _ in range(interp.max_while_iters):
        outs = interp.run(p, *state)
        cond, state = outs[0], outs[1:]
        if not bool(cond):
            return state
    raise RuntimeError("While exceeded max iterations")


@impl("cf.Cond")
def _cf_cond(interp, ins, args):
    pred, rest = args[0], args[1:]
    p: Program = ins.param("Pthen") if bool(pred) else ins.param("Pelse")
    return interp.run(p, *rest)


@impl("cf.Call")
def _cf_call(interp, ins, args):
    return interp.run(ins.param("P"), *args)


# ---------------------------------------------------------------------------
# dataflow flavor
# ---------------------------------------------------------------------------


@impl("df.Source")
def _df_source(interp, ins, args):
    return [interp.sources[ins.param("name")]]


@impl("df.Literal")
def _df_literal(interp, ins, args):
    return [ins.param("value")]


@impl("df.Collect")
def _df_collect(interp, ins, args):
    return [args[0]]


@impl("df.Map")
def _df_map(interp, ins, args):
    p: Program = ins.param("P")
    (c,) = args
    if isinstance(c, dict):
        n = _ncols(c)
        items = [{k: v[i] for k, v in c.items()} for i in range(n)]
    else:
        items = list(c)
    outs = [interp.run(p, item)[0] for item in items]
    if outs and isinstance(outs[0], dict):
        return [{k: np.asarray([o[k] for o in outs]) for k in outs[0]}]
    return [np.asarray(outs)]


@impl("df.Reduce")
def _df_reduce(interp, ins, args):
    p: Program = ins.param("P")
    (c,) = args
    items = list(c) if not isinstance(c, dict) else [
        {k: v[i] for k, v in c.items()} for i in range(_ncols(c))
    ]
    acc = items[0]
    for it in items[1:]:
        acc = interp.run(p, acc, it)[0]
    return [acc]


# ---------------------------------------------------------------------------
# linear algebra flavor
# ---------------------------------------------------------------------------


@impl("la.Literal")
def _la_literal(interp, ins, args):
    name = ins.param("name")
    if name is not None and name in interp.sources:
        return [np.asarray(interp.sources[name])]
    return [np.asarray(ins.param("value"))]


@impl("la.MMMult")
def _la_mmmult(interp, ins, args):
    return [np.asarray(args[0]) @ np.asarray(args[1])]


@impl("la.Transpose")
def _la_transpose(interp, ins, args):
    return [np.asarray(args[0]).T]


@impl("la.Ewise")
def _la_ewise(interp, ins, args):
    op = ins.param("op")
    if len(args) == 1:
        a = np.asarray(args[0])
        return [{"neg": lambda: -a, "abs": lambda: np.abs(a), "add": lambda: a,
                 "sqrt": lambda: np.sqrt(a), "square": lambda: a * a}[op]()]
    a, b = np.asarray(args[0]), np.asarray(args[1])
    return [{"add": lambda: a + b, "sub": lambda: a - b, "mul": lambda: a * b,
             "div": lambda: a / b}[op]()]


@impl("la.ReduceSum")
def _la_reducesum(interp, ins, args):
    return [np.sum(np.asarray(args[0]), axis=int(ins.param("axis")))]


@impl("la.CDist2")
def _la_cdist2(interp, ins, args):
    x, c = np.asarray(args[0], dtype=np.float64), np.asarray(args[1], dtype=np.float64)
    x2 = np.sum(x * x, axis=1, keepdims=True)
    c2 = np.sum(c * c, axis=1, keepdims=True).T
    return [x2 - 2.0 * (x @ c.T) + c2]


@impl("la.ArgMinRow")
def _la_argminrow(interp, ins, args):
    return [np.argmin(np.asarray(args[0]), axis=1).astype(np.int32)]


@impl("la.SegSum")
def _la_segsum(interp, ins, args):
    x, lab = np.asarray(args[0], dtype=np.float64), np.asarray(args[1])
    k = int(ins.param("k"))
    out = np.zeros((k, x.shape[1]), dtype=np.float64)
    np.add.at(out, lab, x)
    return [out]


@impl("la.SegCount")
def _la_segcount(interp, ins, args):
    lab = np.asarray(args[0])
    k = int(ins.param("k"))
    return [np.bincount(lab, minlength=k).astype(np.float64)]


@impl("la.KMeansStep")
def _la_kmeans_step(interp, ins, args):
    x, c = np.asarray(args[0], dtype=np.float64), np.asarray(args[1], dtype=np.float64)
    d = _la_cdist2(interp, ins, [x, c])[0]
    lab = np.argmin(d, axis=1)
    k = c.shape[0]
    sums = np.zeros((k, x.shape[1]), dtype=np.float64)
    np.add.at(sums, lab, x)
    counts = np.bincount(lab, minlength=k).astype(np.float64)
    return [sums, counts]


# ---------------------------------------------------------------------------
# backend facade (so "interp" is a registered compile target like the rest)
# ---------------------------------------------------------------------------


class InterpCompiled:
    """Executable wrapper matching the backends' ``compiled(sources, *args)``
    convention; each call runs a fresh Interpreter over the program."""

    #: the eager interpreter emits real per-operator spans during a traced
    #: run, so the driver must not add synthetic annotations on top
    emits_op_spans = True

    def __init__(self, program: Program, max_while_iters: int = 10_000) -> None:
        self.program = program
        self.max_while_iters = max_while_iters

    def __call__(self, sources: Optional[Mapping[str, Any]] = None,
                 *args: Any) -> List[Any]:
        interp = Interpreter(sources=dict(sources or {}),
                             max_while_iters=self.max_while_iters)
        return interp.run(self.program, *args)

    def run_traced(self, sources: Optional[Mapping[str, Any]] = None,
                   *args: Any):
        """Execute and measure: ``(results, cards, per-op wall seconds)``."""
        from ..obs.feedback import TapRecord

        interp = Interpreter(sources=dict(sources or {}),
                             max_while_iters=self.max_while_iters, trace=True)
        outs = interp.run(self.program, *args)
        cards = {k: TapRecord(occ, ri, int(ro))
                 for k, (occ, ri, ro) in interp.taps.items()}
        return outs, cards, dict(interp.walls)


class InterpBackend:
    """The abstract machine as a backend: exact, slow, the oracle."""

    name = "interp"

    def __init__(self, max_while_iters: int = 10_000) -> None:
        self.max_while_iters = max_while_iters

    def compile(self, program: Program) -> InterpCompiled:
        return InterpCompiled(program, max_while_iters=self.max_while_iters)
