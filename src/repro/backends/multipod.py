"""Multi-pod / elastic backend — the Lambada analogue.

Lambada's trade is elasticity: pick the worker count per query, pay for
worker-seconds, survive workers vanishing.  On TPU the elastic unit is the
pod ("pod" mesh axis, DCN-connected).  This facade owns that lifecycle:

  * ``plan(workers)`` compiles the frontend program for a given worker
    count through the unified compilation driver (the program is
    re-planned, never re-written by hand);
  * ``on_resize(new_workers)`` re-plans after an ElasticEvent (pod loss /
    scale-up) — repeated plans for a topology hit the driver's structural
    plan cache, so re-planning a previously seen worker count is near-free;
  * state (for training jobs) moves across topologies via the placement-
    agnostic checkpoints in ``distributed.checkpoint``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

from ..core.passes.lower_vec import Catalog
from ..core.program import Program


@dataclass
class ElasticExecutor:
    """Plan-per-topology executor for CVM programs."""

    program_builder: Callable[[], Program]   # frontend program (re-buildable)
    catalog: Catalog
    axis: str = "workers"
    use_kernels: bool = False
    workers: int = 1
    cache: Optional[Any] = None   # PlanCache override; None → driver default
    optimize: Optional[str] = None  # "cost" → costed strategy search per plan
    store: Any = None             # PlanStore/path: re-plans survive restarts
    memory_budget: Optional[int] = None  # admission cap per plan (bytes)
    guard: bool = True            # fallback-ladder protection on each plan
    # hot-path memo so steady-state run() skips the rebuild+fingerprint of a
    # driver-cache lookup; the driver cache still provides cross-topology and
    # cross-executor reuse
    _current: Optional[Tuple[int, Any]] = field(default=None, repr=False)

    def plan(self, workers: int):
        """Compile for ``workers`` through the driver — no inline pass lists.

        The driver's structural plan cache replaces the per-executor plan
        table: the rebuilt frontend program fingerprints identically across
        calls (alpha-invariance), so a repeated worker count is a cache hit.
        """
        from ..compiler import compile as cvm_compile

        program = self.program_builder()
        return cvm_compile(
            program,
            target="multipod" if workers > 1 else "local",
            parallel=workers,
            catalog=self.catalog,
            axis=self.axis,
            use_kernels=self.use_kernels,
            cache=self.cache,
            optimize=self.optimize,
            store=self.store,
            memory_budget=self.memory_budget,
            guard=self.guard,
        )

    def run(self, sources, *args):
        if self._current is None or self._current[0] != self.workers:
            self._current = (self.workers, self.plan(self.workers))
        return self._current[1](sources, *args)

    def on_resize(self, new_workers: int) -> None:
        """Elastic event: pod lost or fleet grown — next run uses the new plan."""
        self.workers = new_workers
