"""Multi-pod / elastic backend — the Lambada analogue.

Lambada's trade is elasticity: pick the worker count per query, pay for
worker-seconds, survive workers vanishing.  On TPU the elastic unit is the
pod ("pod" mesh axis, DCN-connected).  This facade owns that lifecycle:

  * ``plan(workers)`` compiles the frontend program for a given worker
    count (re-running the parallelization rewrite — the program is
    re-planned, never re-written by hand);
  * ``on_resize(new_workers)`` re-plans after an ElasticEvent (pod loss /
    scale-up) — compiled plans are cached per worker count;
  * state (for training jobs) moves across topologies via the placement-
    agnostic checkpoints in ``distributed.checkpoint``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from ..core.passes import Parallelize
from ..core.passes.lower_vec import Catalog, LowerRelToVec
from ..core.program import Program
from ..launch.mesh import make_mesh
from .local import LocalBackend
from .spmd import SpmdBackend


@dataclass
class ElasticExecutor:
    """Plan-per-topology executor for CVM programs."""

    program_builder: Callable[[], Program]   # frontend program (re-buildable)
    catalog: Catalog
    axis: str = "workers"
    use_kernels: bool = False
    _plans: Dict[int, Any] = field(default_factory=dict)
    workers: int = 1

    def plan(self, workers: int):
        if workers in self._plans:
            return self._plans[workers]
        program = self.program_builder()
        if workers > 1:
            program = Parallelize(n=workers).apply(program)
        program = LowerRelToVec(self.catalog).apply(program)
        if workers > 1:
            mesh = make_mesh((workers,), (self.axis,))
            compiled = SpmdBackend(mesh, axis=self.axis,
                                   use_kernels=self.use_kernels).compile(program)
        else:
            compiled = LocalBackend(use_kernels=self.use_kernels).compile(program)
        self._plans[workers] = compiled
        return compiled

    def run(self, sources, *args):
        return self.plan(self.workers)(sources, *args)

    def on_resize(self, new_workers: int) -> None:
        """Elastic event: pod lost or fleet grown — next run uses the new plan."""
        self.workers = new_workers
