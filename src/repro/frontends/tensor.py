"""Tensor frontend: the LM training step planned *through* CVM.

The trainer does not hand-write its distribution: it builds the step as a
CVM program (paper Alg. 1 shape), lets the generic parallelization rewrite
introduce ``Split → ConcurrentExecute → pre-aggregation`` (Alg. 2), lets the
SPMD backend rewrite the combine into a ``mesh.AllReduce``, and only then
binds the plan to GSPMD:

    batch    ← tz.Source(batch)
    shards   ← cf.Split(n_data)(batch)                  # DP
    g, l     ← cf.ConcurrentExecute(grad_pipeline)(shards, ⊕params, ⊕opt)
    gsum     ← cf.CombineChunks(sum)(g)                 # pre-agg → AllReduce
    loss     ← cf.CombineChunks(sum)(l)
    params'  ← tz.OptUpdate(opt)(params, opt_state, gsum)

``lower_to_pjit`` reads that plan and emits the concrete jit: Split on the
batch → batch sharded over the data axes, Broadcast on params → replicated
over data (model-axis splits come from the weight-sharding table),
AllReduce-inside-MeshExecute → GSPMD's gradient psum.  The dry-run lowers
exactly this artifact.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax

from ..core import Builder, Program, verify
from ..core.ops.tensor import register_pipeline
from ..core.types import CollectionKind, CollectionType, TupleType, Atom, F32, Single
from ..models.api import Model, make_train_step
from ..train.optimizer import Optimizer

# custom collection kind: an opaque (but named) parameter/batch pytree —
# frontends may define their own collection types (paper §3.3)
PYTREE = CollectionKind("PyTree", abstract=False, ordered=True)


def pytree_type(tag: str) -> CollectionType:
    return CollectionType(PYTREE, TupleType(()), (("tag", tag),))


def plan_train_program(model: Model, n_data: int,
                       records: Optional[list] = None) -> Program:
    """Build the sequential step program and plan it via the ``pjit`` target.

    The Alg. 1 → Alg. 2 rewrite (split the batch, push the pipeline inside,
    pre-aggregate gradients) is the registered ``pjit`` target's lowering
    path, run through the unified compilation driver like every other
    frontend (``records`` collects the driver's per-pass timings).
    """
    cfg = model.cfg
    grad_name = f"grad_{cfg.arch}"
    register_pipeline(grad_name, None, overwrite=True)  # bound at lowering

    b = Builder(f"train_{cfg.arch}")
    params = b.input("params", pytree_type("params"))
    opt_state = b.input("opt", pytree_type("opt_state"))
    batch = b.input("batch", pytree_type("batch"))

    grads, loss = b.emit(
        "tz.Pipeline", [batch, params],
        {"fn": grad_name,
         "out_types": (pytree_type("grads"), Single(TupleType.of(loss=F32)))},
    )
    new_params, new_opt = b.emit(
        "tz.OptUpdate", [params, opt_state, grads], {"opt": "adamw"})
    program = b.finish(new_params, new_opt, loss)
    verify(program)

    from ..compiler import compile as cvm_compile

    res = cvm_compile(program, target="pjit", parallel=n_data,
                      parallelize_targets=[batch.name], cache=False,
                      store=False)
    if records is not None:
        records.extend(res.records)
    return res.program


class _PlanError(Exception):
    pass


def plan_summary(program: Program) -> Dict[str, Any]:
    """Extract the distribution decisions the rewrites made."""
    ops = [i.opcode for i in program.body]
    ce = next((i for i in program.body if i.opcode in
               ("cf.ConcurrentExecute", "mesh.MeshExecute")), None)
    if ce is None:
        raise _PlanError(f"no ConcurrentExecute in plan: {ops}")
    inner = ce.param("P")
    return {
        "n_workers": ce.inputs[0].type.attr("n"),
        "split": [i.inputs[0].name for i in program.body if i.opcode == "cf.Split"],
        "broadcast": [i.inputs[0].name for i in program.body if i.opcode == "cf.Broadcast"],
        "combines": [i.opcode for i in program.body
                     if i.opcode in ("cf.CombineChunks", "rel.CombinePartials")]
                    + [i.opcode for i in inner.body if i.opcode == "mesh.AllReduce"],
        "inner_ops": [i.opcode for i in inner.body],
    }


@dataclass
class PjitCompiled:
    """A compiled pjit plan: the program, its summary, and (when a model is
    bound) the jitted train step."""

    program: Program
    summary: Optional[Dict[str, Any]]
    fn: Optional[Any] = None

    def __call__(self, *args: Any) -> Any:
        # unlike the relational backends there is no sources dict: every
        # positional argument is a train-step argument (params, opt, batch)
        if self.fn is None:
            raise RuntimeError(
                "plan-only pjit compile: pass backend=PjitBackend(model=..., "
                "mesh=..., optimizer=..., batch_shapes=...) to bind a "
                "runnable train step")
        return self.fn(*args)


@dataclass
class PjitBackend:
    """Backend for the registered ``pjit`` target.

    Without a model binding it compiles *plans* (the distribution decisions
    only); bound to a model/mesh/optimizer it emits the concrete jitted
    train step.  The plan dictates: which inputs are data-split (→ batch
    specs over the dp axes), which are broadcast (→ replicated over dp,
    model-sharded per the weight table), and that gradients pre-aggregate
    across workers (→ GSPMD all-reduce, implicit in the replicated-param
    gradient).
    """

    name = "pjit"

    model: Optional[Model] = None
    mesh: Any = None
    optimizer: Optional[Optimizer] = None
    batch_shapes: Optional[Dict[str, Any]] = None
    microbatch: int = 1

    def compile(self, program: Program) -> PjitCompiled:
        try:
            summary = plan_summary(program)
        except _PlanError:
            summary = None
        if self.model is None:
            return PjitCompiled(program, summary)

        from ..models import sharding as shd

        if summary is None or not summary["split"]:
            raise _PlanError("plan has no data split")

        step, opt = make_train_step(self.model, self.optimizer,
                                    microbatch=self.microbatch)

        key_spec = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
        params_shapes = jax.eval_shape(self.model.init, key_spec)
        pspecs = shd.tree_param_specs(params_shapes, self.mesh)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        ospecs = shd.tree_opt_specs(opt_shapes, pspecs, self.mesh, zero1=True)
        bspecs = shd.batch_specs(
            {k: (v.shape, v.dtype) for k, v in self.batch_shapes.items()},
            self.mesh)

        jitted = jax.jit(
            step,
            in_shardings=(shd.named(self.mesh, pspecs),
                          shd.named(self.mesh, ospecs),
                          shd.named(self.mesh, bspecs)),
        )
        return PjitCompiled(program, summary, jitted)


def lower_to_pjit(program: Program, model: Model, mesh, optimizer: Optimizer,
                  batch_shapes: Dict[str, Any], microbatch: int = 1):
    """Bind the CVM plan to a concrete pjit'd train step.

    Routes through ``compile(program, target="pjit", backend=...)`` — the
    registered target's lowering path — so the LM trainer compiles via the
    unified driver like every other frontend.
    """
    from ..compiler import compile as cvm_compile

    be = PjitBackend(model=model, mesh=mesh, optimizer=optimizer,
                     batch_shapes=batch_shapes, microbatch=microbatch)
    res = cvm_compile(program, target="pjit", backend=be, cache=False,
                      store=False)
    compiled: PjitCompiled = res.executable
    return compiled.fn, compiled.summary
