"""Generic Python dataflow frontend.

The user-facing collection API shared by all backends (paper Fig. 1: one
Python frontend, three platforms).  ``Frame`` is an immutable logical plan
node; ``.program()`` translates the plan into a ``rel.*`` CVM program ("this
initial translation should be as thin as possible"), and ``Context.execute``
drives the standard rewriting pipeline for the chosen backend.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core import Builder, Program, verify
from ..core.expr import AggSpec, Col, Expr, col, const
from ..core.types import BAG, Atom, Bag, CollectionType, TupleType

_ids = itertools.count()


@dataclass(frozen=True)
class _Node:
    op: str
    params: Tuple[Tuple[str, Any], ...]
    children: Tuple["_Node", ...]
    uid: int = field(default_factory=lambda: next(_ids))


# -- aggregation helpers -----------------------------------------------------


@dataclass(frozen=True)
class AggExpr:
    fn: str
    expr: Expr
    name: Optional[str] = None

    def as_(self, name: str) -> "AggExpr":
        return AggExpr(self.fn, self.expr, name)


def sum_(e: Expr | str) -> AggExpr:
    return AggExpr("sum", col(e) if isinstance(e, str) else e)


def count_() -> AggExpr:
    return AggExpr("count", const(1))


def min_(e: Expr | str) -> AggExpr:
    return AggExpr("min", col(e) if isinstance(e, str) else e)


def max_(e: Expr | str) -> AggExpr:
    return AggExpr("max", col(e) if isinstance(e, str) else e)


def avg_(e: Expr | str) -> AggExpr:
    return AggExpr("avg", col(e) if isinstance(e, str) else e)


class Frame:
    """An immutable logical collection (lazy)."""

    def __init__(self, ctx: "Context", node: _Node, schema: TupleType) -> None:
        self._ctx = ctx
        self._node = node
        self.schema = schema

    # -- transformations ----------------------------------------------------
    def filter(self, pred: Expr) -> "Frame":
        return Frame(self._ctx, _Node("rel.Select", (("pred", pred),), (self._node,)),
                     self.schema)

    def select(self, *names: str) -> "Frame":
        return Frame(self._ctx, _Node("rel.Proj", (("names", tuple(names)),), (self._node,)),
                     self.schema.project(names))

    def with_columns(self, **exprs: Expr) -> "Frame":
        all_exprs = tuple((n, col(n)) for n in self.schema.names if n not in exprs)
        all_exprs += tuple(exprs.items())
        fields = tuple((n, e.infer(self.schema)) for n, e in all_exprs)
        return Frame(self._ctx, _Node("rel.ExProj", (("exprs", all_exprs),), (self._node,)),
                     TupleType(fields))

    def project(self, **exprs: Expr) -> "Frame":
        items = tuple(exprs.items())
        fields = tuple((n, e.infer(self.schema)) for n, e in items)
        return Frame(self._ctx, _Node("rel.ExProj", (("exprs", items),), (self._node,)),
                     TupleType(fields))

    def join(self, other: "Frame", left_on: str | Sequence[str],
             right_on: str | Sequence[str]) -> "Frame":
        from ..core.ops.relational import join_schema

        lo = (left_on,) if isinstance(left_on, str) else tuple(left_on)
        ro = (right_on,) if isinstance(right_on, str) else tuple(right_on)
        schema = join_schema(self.schema, other.schema, lo, ro)
        return Frame(
            self._ctx,
            _Node("rel.Join", (("left_on", lo), ("right_on", ro)),
                  (self._node, other._node)),
            schema,
        )

    def order_by(self, *keys: str, ascending: Optional[Sequence[bool]] = None) -> "Frame":
        asc = tuple(ascending or (True,) * len(keys))
        return Frame(self._ctx,
                     _Node("rel.OrderBy", (("keys", tuple(keys)), ("ascending", asc)),
                           (self._node,)),
                     self.schema)

    def limit(self, k: int) -> "Frame":
        return Frame(self._ctx, _Node("rel.Limit", (("k", k),), (self._node,)), self.schema)

    # -- aggregations ---------------------------------------------------------
    def _desugar(self, aggs: Sequence[AggExpr]) -> Tuple[Tuple[AggSpec, ...],
                                                         Optional[Tuple[Tuple[str, Expr], ...]]]:
        """avg → sum/count + a finalize ExProj; returns (specs, finalize)."""
        specs: List[AggSpec] = []
        finalize: List[Tuple[str, Expr]] = []
        needs_finalize = False
        for a in aggs:
            name = a.name or f"{a.fn}_{next(_ids)}"
            if a.fn == "avg":
                needs_finalize = True
                s, c = f"__{name}_sum", f"__{name}_cnt"
                specs.append(AggSpec("sum", a.expr, s))
                specs.append(AggSpec("count", a.expr, c))
                finalize.append((name, col(s) / col(c)))
            else:
                specs.append(AggSpec(a.fn, a.expr, name))
                finalize.append((name, col(name)))
        return tuple(specs), (tuple(finalize) if needs_finalize else None)

    def agg(self, *aggs: AggExpr) -> "Frame":
        specs, finalize = self._desugar(aggs)
        node = _Node("rel.Aggr", (("aggs", specs),), (self._node,))
        schema = TupleType(tuple((s.name, s.result_atom(self.schema)) for s in specs))
        out = Frame(self._ctx, node, schema)
        if finalize:
            fields = tuple((n, e.infer(schema)) for n, e in finalize)
            out = Frame(self._ctx, _Node("rel.ExProj", (("exprs", finalize),), (node,)),
                        TupleType(fields))
        return out

    def group_by(self, *keys: str, max_groups: Optional[int] = None) -> "GroupBy":
        return GroupBy(self, keys, max_groups)

    # -- plumbing -------------------------------------------------------------
    def program(self, name: str = "query") -> Program:
        b = Builder(name)
        memo: Dict[int, Any] = {}

        def build(node: _Node):
            if node.uid in memo:
                return memo[node.uid]
            child_regs = [build(c) for c in node.children]
            outs = b.emit(node.op, child_regs, dict(node.params))
            memo[node.uid] = outs[0]
            return outs[0]

        result = build(self._node)
        p = b.finish(result)
        verify(p)
        return p

    def collect(self, parallel: Optional[int] = None, use_kernels: bool = False,
                backend: Optional[Any] = None,
                target: str = "local",
                optimize: Optional[str] = None,
                strategy: Any = None) -> Dict[str, np.ndarray]:
        return self._ctx.execute(self, parallel=parallel, use_kernels=use_kernels,
                                 backend=backend, target=target,
                                 optimize=optimize, strategy=strategy)


class GroupBy:
    def __init__(self, frame: Frame, keys: Sequence[str], max_groups: Optional[int]) -> None:
        self.frame = frame
        self.keys = tuple(keys)
        self.max_groups = max_groups

    def agg(self, *aggs: AggExpr) -> Frame:
        specs, finalize = self.frame._desugar(aggs)
        params: Tuple[Tuple[str, Any], ...] = (("keys", self.keys), ("aggs", specs))
        if self.max_groups:
            params += (("max_groups", self.max_groups),)
        node = _Node("rel.GroupByAggr", params, (self.frame._node,))
        fields = tuple((k, self.frame.schema.field(k)) for k in self.keys)
        fields += tuple((s.name, s.result_atom(self.frame.schema)) for s in specs)
        schema = TupleType(fields)
        out = Frame(self.frame._ctx, node, schema)
        if finalize:
            keep = tuple((k, col(k)) for k in self.keys)
            exprs = keep + finalize
            f2 = tuple((n, e.infer(schema)) for n, e in exprs)
            out = Frame(self.frame._ctx, _Node("rel.ExProj", (("exprs", exprs),), (node,)),
                        TupleType(f2))
        return out


class Context:
    """Holds named tables (numpy columns) and drives compilation.

    ``pad_to`` rounds physical capacities up so worker counts divide them.
    """

    def __init__(self, pad_to: int = 256) -> None:
        self.tables: Dict[str, Dict[str, np.ndarray]] = {}
        self.schemas: Dict[str, TupleType] = {}
        self.pad_to = pad_to
        self._stats = None  # lazily computed Statistics; reset on register

    # -- catalog ---------------------------------------------------------------
    def register(self, name: str, data: Mapping[str, np.ndarray],
                 schema: Optional[TupleType] = None) -> None:
        data = {k: np.asarray(v) for k, v in data.items()}
        # object arrays of python strings (pandas-style) → native unicode
        data = {k: v.astype(str) if v.dtype.kind == "O" else v
                for k, v in data.items()}
        if schema is None:
            schema = TupleType(tuple((k, _infer_atom(v)) for k, v in data.items()))
        self.tables[name] = data
        self.schemas[name] = schema
        self._stats = None

    def table(self, name: str) -> Frame:
        schema = self.schemas[name]
        node = _Node("rel.Scan", (("table", name), ("schema", schema), ("kind", BAG)), ())
        return Frame(self, node, schema)

    # -- compilation -------------------------------------------------------------
    def capacity(self, name: str) -> int:
        n = len(next(iter(self.tables[name].values())))
        p = self.pad_to
        return max(p, ((n + p - 1) // p) * p)

    def _has_strings(self) -> bool:
        return any(np.asarray(v).dtype.kind in ("U", "S")
                   for cols in self.tables.values() for v in cols.values())

    def statistics(self):
        """Exact table statistics from the registered columns (cached).

        These feed the driver's cost-based plan selection via
        ``Catalog.stats`` → ``CompileOptions``.  When any registered column
        holds strings, a session-global string :class:`Dictionary` is built
        over the union of all string values: physical string columns are
        its i32 rank codes (globally consistent, so cross-table joins and
        order-by compare correctly on codes), and per-column dictionaries
        are expressed in that code space.
        """
        if self._stats is None:
            from ..compiler.stats import (Dictionary, Statistics,
                                          stats_from_columns)

            svals: set = set()
            for cols in self.tables.values():
                for v in cols.values():
                    a = np.asarray(v)
                    if a.dtype.kind in ("U", "S"):
                        svals.update(str(x) for x in np.unique(a))
            gd = Dictionary.make(sorted(svals)) if svals else None
            self._stats = Statistics.make(
                {name: stats_from_columns(cols, gd)
                 for name, cols in self.tables.items()}, gd)
        return self._stats

    def catalog(self, with_stats: bool = True):
        """The lowering catalog; ``with_stats=False`` skips the (memoized
        but O(n log n) per column) exact-statistics computation for compiles
        that will never consult them."""
        from ..core.passes.lower_vec import Catalog
        return Catalog(capacities={t: self.capacity(t) for t in self.tables},
                       stats=self.statistics() if with_stats else None)

    def compile(self, frame: Frame, parallel: Optional[int] = None,
                use_kernels: bool = False, fuse: bool = True, backend: Any = None,
                target: str = "local", cache: Any = None,
                optimize: Optional[str] = None, strategy: Any = None,
                store: Any = None, memory_budget: Optional[int] = None,
                guard: bool = True, stream_table: Optional[str] = None,
                batch_rows: Optional[int] = None):
        """Compile through the unified driver — the single entry point for
        every target's declarative lowering path (and the plan cache)."""
        from ..compiler import compile as cvm_compile

        return cvm_compile(
            frame.program(),
            target=target,
            parallel=parallel,
            # statistics feed both the costed search and forced physical
            # strategies (a forced groupby=direct needs key-domain bounds);
            # string tables always need them — the vec lowering remaps
            # string-literal predicates through the global dictionary
            catalog=self.catalog(
                with_stats=optimize is not None or strategy is not None
                or self._has_strings()),
            use_kernels=use_kernels,
            fuse=fuse,
            backend=backend,
            cache=cache,
            optimize=optimize,
            strategy=strategy,
            store=store,
            memory_budget=memory_budget,
            guard=guard,
            stream_table=stream_table,
            batch_rows=batch_rows,
        )

    def _physical_columns(self, name: str) -> Dict[str, np.ndarray]:
        """Columns in their physical dtypes: string columns become i32
        global-dictionary rank codes (the documented str→i32 adaptation —
        rank order is lexicographic order, so comparisons, sorts, and
        joins on codes agree with the same operations on the strings)."""
        data = self.tables[name]
        if not any(np.asarray(v).dtype.kind in ("U", "S")
                   for v in data.values()):
            return data
        gd = self.statistics().global_dict
        gvals = np.asarray(gd.values)
        out = {}
        for k, v in data.items():
            a = np.asarray(v)
            out[k] = (np.searchsorted(gvals, a).astype(np.int32)
                      if a.dtype.kind in ("U", "S") else a)
        return out

    def sources(self) -> Dict[str, Any]:
        from ..relational.runtime import VecTable

        return {
            name: VecTable.from_numpy(self._physical_columns(name),
                                      self.capacity(name))
            for name, data in self.tables.items()
        }

    def execute(self, frame: Frame, parallel: Optional[int] = None,
                use_kernels: bool = False, backend: Any = None,
                target: str = "local",
                optimize: Optional[str] = None,
                strategy: Any = None, stream_table: Optional[str] = None,
                batch_rows: Optional[int] = None) -> Dict[str, np.ndarray]:
        from ..compiler import get_target

        compiled = self.compile(frame, parallel=parallel, use_kernels=use_kernels,
                                backend=backend, target=target,
                                optimize=optimize, strategy=strategy,
                                stream_table=stream_table,
                                batch_rows=batch_rows)
        src = (self.tables if get_target(target).source_kind == "numpy"
               else self.sources())
        (out,) = compiled(src)
        return self._decode_output(frame, _to_numpy(out))

    def _decode_output(self, frame: Frame,
                       out: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Decode i32 global-code columns back to strings at the session
        boundary.  Schema-driven: a column the frame types as ``str`` whose
        physical array is integral came out of the vec pipeline as codes;
        the interp target returns the raw strings already (non-integer
        dtype) and is left alone."""
        if not self._has_strings():
            return out
        gd = self.statistics().global_dict
        gvals = np.asarray(gd.values)
        schema = frame.schema
        names = set(schema.names)
        for k, arr in list(out.items()):
            if (k in names
                    and getattr(schema.field(k), "domain", None) == "str"
                    and np.issubdtype(np.asarray(arr).dtype, np.integer)):
                out[k] = gvals[np.clip(np.asarray(arr), 0, len(gvals) - 1)]
        return out


def _infer_atom(v: np.ndarray) -> Atom:
    from ..core.types import BOOL, F32, F64, I32, I64, STR

    if v.dtype.kind in ("U", "S"):
        return STR
    if v.dtype == np.bool_:
        return BOOL
    if v.dtype in (np.int8, np.int16, np.int32):
        return I32
    if v.dtype == np.int64:
        return I64
    if v.dtype == np.float32:
        return F32
    if v.dtype == np.float64:
        return F64
    raise TypeError(f"unsupported column dtype {v.dtype}")


def _to_numpy(out: Any) -> Dict[str, np.ndarray]:
    from ..relational.runtime import VecTable

    if isinstance(out, VecTable):
        return out.to_numpy()
    if isinstance(out, dict):
        return {k: np.asarray(v) for k, v in out.items()}
    return {"result": np.asarray(out)}
