"""Frontends: thin translations from user-facing APIs into CVM IR flavors.

* ``dataflow`` — the generic Python collection frontend (the one frontend
  the paper's three systems share); produces ``rel.*``/``cf.*`` programs.
* ``sql``      — a small SQL subset parsed onto the dataflow frontend.
* ``linalg``   — matrices/vectors; produces ``la.*`` programs.
* ``ml``       — k-means & co on top of the LA flavor.
* ``tensor``   — LM training/serving step-graphs (``tz.*`` flavor).
"""
