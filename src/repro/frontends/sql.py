"""A small SQL frontend — third frontend over the same CVM IR.

Grammar (enough for analytics demos; the paper's point is that adding a
frontend is a thin translation, not a new engine)::

    SELECT item [, item]*
    FROM table [JOIN table ON col = col]
    [WHERE pred]
    [GROUP BY col [, col]*]
    [ORDER BY col [ASC|DESC] [, ...]]
    [LIMIT n]

    item := expr [AS name] | agg(expr) [AS name]    agg ∈ sum,count,min,max,avg
    expr := literal | col | expr (+,-,*,/) expr | expr cmp expr
            | expr AND/OR expr | NOT expr | (expr) | col BETWEEN a AND b

Produces a ``dataflow.Frame`` — i.e. compiles through exactly the same
rewritings and backends as the Python frontend.
"""

from __future__ import annotations

import re
from typing import Any, List, Optional, Tuple

from ..core.expr import BinOp, Const, Expr, UnOp, col, const
from .dataflow import AggExpr, Context, Frame

_TOKEN = re.compile(r"""
    \s*(?:
      (?P<num>\d+\.\d+|\d+)
    | (?P<id>[A-Za-z_][A-Za-z_0-9]*)
    | (?P<op><=|>=|<>|!=|[=<>(),*+\-/])
    )""", re.X)

_KEYWORDS = {"select", "from", "where", "group", "order", "by", "limit", "as",
             "and", "or", "not", "between", "asc", "desc", "join", "on",
             "sum", "count", "min", "max", "avg"}


def tokenize(sql: str) -> List[str]:
    out, i = [], 0
    while i < len(sql):
        m = _TOKEN.match(sql, i)
        if m is None:
            if sql[i:].strip() == "":
                break
            raise SyntaxError(f"bad SQL at: {sql[i:i+20]!r}")
        i = m.end()
        tok = m.group("num") or m.group("id") or m.group("op")
        if m.group("id") and tok.lower() in _KEYWORDS:
            tok = tok.lower()
        out.append(tok)
    return out


class Parser:
    def __init__(self, toks: List[str]) -> None:
        self.toks = toks
        self.i = 0

    def peek(self) -> Optional[str]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        t = self.peek()
        if t is None:
            raise SyntaxError("unexpected end of query")
        self.i += 1
        return t

    def expect(self, tok: str) -> None:
        t = self.next()
        if t != tok:
            raise SyntaxError(f"expected {tok!r}, got {t!r}")

    def accept(self, tok: str) -> bool:
        if self.peek() == tok:
            self.i += 1
            return True
        return False

    # -- expressions (precedence climbing) ---------------------------------
    def expr(self) -> Expr:
        return self._or()

    def _or(self) -> Expr:
        e = self._and()
        while self.accept("or"):
            e = e | self._and()
        return e

    def _and(self) -> Expr:
        e = self._not()
        while self.accept("and"):
            e = e & self._not()
        return e

    def _not(self) -> Expr:
        if self.accept("not"):
            return ~self._not()
        return self._cmp()

    def _cmp(self) -> Expr:
        e = self._add()
        t = self.peek()
        if t == "between":
            self.next()
            lo = self._add()
            self.expect("and")
            hi = self._add()
            return (e >= lo) & (e <= hi)
        if t in ("=", "<>", "!=", "<", "<=", ">", ">="):
            self.next()
            rhs = self._add()
            return {"=": e.eq, "<>": e.ne, "!=": e.ne, "<": e.__lt__,
                    "<=": e.__le__, ">": e.__gt__, ">=": e.__ge__}[t](rhs)
        return e

    def _add(self) -> Expr:
        e = self._mul()
        while self.peek() in ("+", "-"):
            op = self.next()
            rhs = self._mul()
            e = e + rhs if op == "+" else e - rhs
        return e

    def _mul(self) -> Expr:
        e = self._atom()
        while self.peek() in ("*", "/"):
            op = self.next()
            rhs = self._atom()
            e = e * rhs if op == "*" else e / rhs
        return e

    def _atom(self) -> Expr:
        t = self.next()
        if t == "(":
            e = self.expr()
            self.expect(")")
            return e
        if t == "-":
            return const(0) - self._atom()
        if re.fullmatch(r"\d+\.\d+", t):
            return const(float(t))
        if re.fullmatch(r"\d+", t):
            return const(int(t))
        return col(t)

    # -- select list ---------------------------------------------------------
    def select_item(self):
        t = self.peek()
        if t in ("sum", "count", "min", "max", "avg"):
            fn = self.next()
            self.expect("(")
            if fn == "count" and self.accept("*"):
                inner: Optional[Expr] = None
            else:
                inner = self.expr()
            self.expect(")")
            name = None
            if self.accept("as"):
                name = self.next()
            if fn == "count":
                agg = AggExpr("count", const(1), name)
            else:
                agg = AggExpr(fn, inner, name)
            return ("agg", agg)
        e = self.expr()
        name = None
        if self.accept("as"):
            name = self.next()
        return ("expr", e, name)


def parse(sql: str, ctx: Context) -> Frame:
    p = Parser(tokenize(sql))
    p.expect("select")
    items = [p.select_item()]
    while p.accept(","):
        items.append(p.select_item())

    p.expect("from")
    frame = ctx.table(p.next())
    if p.accept("join"):
        right = ctx.table(p.next())
        p.expect("on")
        lk = p.next()
        p.expect("=")
        rk = p.next()
        if frame.schema.has_field(lk):
            frame = frame.join(right, left_on=lk, right_on=rk)
        else:
            frame = frame.join(right, left_on=rk, right_on=lk)

    if p.accept("where"):
        frame = frame.filter(p.expr())

    group_cols: List[str] = []
    if p.accept("group"):
        p.expect("by")
        group_cols.append(p.next())
        while p.accept(","):
            group_cols.append(p.next())

    aggs = [it[1] for it in items if it[0] == "agg"]
    plain = [(it[1], it[2]) for it in items if it[0] == "expr"]

    if aggs and group_cols:
        named = tuple(a if a.name else a.as_(f"{a.fn}_{i}") for i, a in enumerate(aggs))
        frame = frame.group_by(*group_cols, max_groups=4096).agg(*named)
    elif aggs:
        named = tuple(a if a.name else a.as_(f"{a.fn}_{i}") for i, a in enumerate(aggs))
        frame = frame.agg(*named)
    elif plain:
        exprs = {}
        for i, (e, name) in enumerate(plain):
            from ..core.expr import Col
            exprs[name or (e.name if isinstance(e, Col) else f"col_{i}")] = e
        frame = frame.project(**exprs)

    if p.accept("order"):
        p.expect("by")
        keys, asc = [], []
        while True:
            keys.append(p.next())
            if p.accept("desc"):
                asc.append(False)
            elif p.accept("asc"):
                asc.append(True)
            else:
                asc.append(True)
            if not p.accept(","):
                break
        frame = frame.order_by(*keys, ascending=asc)

    if p.accept("limit"):
        frame = frame.limit(int(p.next()))

    if p.peek() is not None:
        raise SyntaxError(f"trailing tokens: {p.toks[p.i:]}")
    return frame


def query(ctx: Context, sql: str, target: str = "local",
          parallel: Optional[int] = None, optimize: Optional[str] = None):
    """Parse + execute through the unified compilation driver.

    ``target``/``parallel`` select the registered lowering path, so a SQL
    query reaches every backend the Python frontend does.
    ``optimize="cost"`` lets the driver choose between the target's
    alternative physical lowerings using the context's table statistics.
    """
    return parse(sql, ctx).collect(target=target, parallel=parallel,
                                   optimize=optimize)
