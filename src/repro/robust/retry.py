"""Retry, backoff, timeout, and straggler primitives.

Generalizes the policy that lived inline in ``distributed/fault.py``'s
``StepRunner`` (bounded retries + an EWMA straggler detector) into
reusable pieces:

* :class:`RetryPolicy` / :func:`call_with_retry` — bounded retries with
  exponential backoff around flaky effects (plan-store I/O, worker
  subprocess launches).  Every retry bumps ``robust.retry.<name>``.
* :class:`Ewma` / :class:`StragglerDetector` — the moving-average step
  timer; a step slower than ``factor``× the EWMA is a straggler (the hook
  where a real deployment triggers backup workers or re-sharding).
* :class:`Deadline` — absolute per-request deadlines on the monotonic
  clock, the primitive behind load shedding in ``launch/serve.py``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple, TypeVar

from ..obs.trace import get_tracer

__all__ = [
    "RetryPolicy", "call_with_retry", "Ewma", "StragglerDetector", "Deadline",
]

T = TypeVar("T")


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff."""

    max_retries: int = 3
    backoff_s: float = 0.02
    backoff_factor: float = 2.0
    max_backoff_s: float = 1.0
    #: exception types worth retrying; anything else propagates immediately
    retry_on: Tuple[type, ...] = (Exception,)

    def backoff(self, attempt: int) -> float:
        return min(self.backoff_s * self.backoff_factor ** attempt,
                   self.max_backoff_s)


def call_with_retry(fn: Callable[[], T], policy: Optional[RetryPolicy] = None,
                    *, name: str = "call",
                    on_failure: Optional[Callable[[int, Exception], None]] = None,
                    sleep: Callable[[float], None] = time.sleep) -> T:
    """Call ``fn`` under ``policy``; re-raise the last error when exhausted."""
    policy = policy or RetryPolicy()
    attempts = policy.max_retries + 1
    for attempt in range(attempts):
        try:
            return fn()
        except policy.retry_on as e:
            tracer = get_tracer()
            tracer.counter(f"robust.retry.{name}")
            tracer.event(f"robust.retry.{name}", attempt=attempt,
                         error=f"{type(e).__name__}: {e}")
            if on_failure is not None:
                on_failure(attempt, e)
            if attempt + 1 >= attempts:
                raise
            sleep(policy.backoff(attempt))
    raise AssertionError("unreachable")  # pragma: no cover


# ---------------------------------------------------------------------------
# EWMA / stragglers
# ---------------------------------------------------------------------------


@dataclass
class Ewma:
    """Exponential moving average (first observation seeds the value)."""

    alpha: float = 0.2
    value: Optional[float] = None
    n: int = 0

    def update(self, x: float) -> float:
        self.value = (x if self.value is None
                      else (1 - self.alpha) * self.value + self.alpha * x)
        self.n += 1
        return self.value


@dataclass
class StragglerDetector:
    """Flags observations slower than ``factor``× the running EWMA.

    The detector *observes first, updates second*: a straggler is judged
    against the history that preceded it, and still folds into the
    average (one slow step raises the bar rather than being forgotten).
    """

    factor: float = 3.0
    alpha: float = 0.2
    ewma: Ewma = field(default_factory=Ewma)
    stragglers: int = 0

    def __post_init__(self) -> None:
        self.ewma.alpha = self.alpha

    def observe(self, seconds: float) -> bool:
        straggler = (self.ewma.value is not None
                     and seconds > self.factor * self.ewma.value)
        if straggler:
            self.stragglers += 1
            get_tracer().counter("robust.straggler")
        self.ewma.update(seconds)
        return straggler


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Deadline:
    """An absolute point on the monotonic clock a request must beat."""

    at: float

    @staticmethod
    def after(seconds: float,
              clock: Callable[[], float] = time.monotonic) -> "Deadline":
        return Deadline(clock() + seconds)

    def remaining(self, clock: Callable[[], float] = time.monotonic) -> float:
        return self.at - clock()

    def expired(self, clock: Callable[[], float] = time.monotonic) -> bool:
        return clock() >= self.at
