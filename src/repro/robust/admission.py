"""Resource admission: bound a plan's peak bytes *before* it executes.

XLA allocates from static shapes, so a lowered vec program's working set is
knowable at admission time: every register type carries its padded capacity
(``Vec[max_count]``, ``ArrayN[n]``, tensor shapes) and the expensive
operators declare their scratch (``vec.GroupAggDirect`` allocates a
``num_buckets`` dense table; exchanges buffer a full shard).  The estimate
is the max over instructions of

    live inputs + outputs + operator scratch

with concurrently-executing nested bodies (``cf.ConcurrentExecute``,
``mesh.MeshExecute``) multiplied by their chunk count.  It is deliberately
an over-approximation of the *allocation* high-water mark — the admission
question is "can this plan OOM the device", not "what will the allocator
do" — and deliberately cheap: one walk of the lowered program.

:func:`admit` compares the estimate against a byte budget
(``CompileOptions.memory_budget`` or the ``REPRO_MEM_BUDGET_BYTES``
environment default) and raises :class:`AdmissionError` when over.  The
driver treats that like any other plan failure: degrade down the fallback
ladder (``groupby=sorted`` drops the bucket table, interp escapes static
padding altogether) rather than letting the device OOM.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Optional, Tuple

from ..core.program import Instruction, Program, Register
from ..core.types import CollectionType, is_coll, item_nbytes

__all__ = ["AdmissionError", "ResourceEstimate", "estimate_peak_bytes",
           "admit", "default_budget"]

#: assumed element count for collections with no static capacity attr —
#: abstract (pre-lowering) programs stay admissible by construction
DEFAULT_ROWS = 1024


class AdmissionError(RuntimeError):
    """The plan's estimated peak working set exceeds the byte budget."""

    def __init__(self, message: str, estimate: "ResourceEstimate",
                 budget: int) -> None:
        super().__init__(message)
        self.estimate = estimate
        self.budget = budget


@dataclass(frozen=True)
class ResourceEstimate:
    """Peak-bytes estimate for one lowered program."""

    peak_bytes: int
    #: the instruction at the high-water mark, e.g. ``vec.GroupAggDirect``
    peak_site: str
    #: per-site footprints, largest first: (opcode, bytes)
    breakdown: Tuple[Tuple[str, int], ...] = ()

    def render(self) -> str:
        top = ", ".join(f"{op}={b:,}B" for op, b in self.breakdown[:4])
        return (f"peak ≈ {self.peak_bytes:,} bytes at {self.peak_site}"
                + (f" ({top})" if top else ""))


# ---------------------------------------------------------------------------
# block footprints from static types
# ---------------------------------------------------------------------------


def _type_bytes(t: Any) -> int:
    """Padded bytes of one value of type ``t`` (static capacities)."""
    if not is_coll(t):
        return item_nbytes(t, 8)
    assert isinstance(t, CollectionType)
    kind = t.kind.name
    if kind == "Single":
        return item_nbytes(t.item, 8)
    if kind == "ArrayN":
        n = int(t.attr("n") or 1)
        return n * _type_bytes(t.item)
    if kind in ("Tensor", "KDSeq"):
        shape = t.attr("shape") or ()
        count = 1
        for s in shape:
            count *= int(s) if int(s) > 0 else DEFAULT_ROWS
        return count * item_nbytes(t.item, 8)
    # Vec / Seq / Bag / Set / HTab / Stream: padded capacity × element
    cap = t.attr("max_count")
    count = int(cap) if cap else DEFAULT_ROWS
    return count * _type_bytes(t.item) if is_coll(t.item) \
        else count * item_nbytes(t.item, 8)


def _reg_bytes(reg: Register) -> int:
    return _type_bytes(reg.type)


def _scratch_bytes(ins: Instruction) -> int:
    """Operator-private allocations beyond inputs and outputs."""
    op = ins.opcode
    if op == "vec.GroupAggDirect":
        # the dense bucket table: one accumulator row per bucket, shaped
        # like the output element (keys + aggregates)
        n_buckets = int(ins.param("num_buckets") or 0)
        out = ins.outputs[0].type
        bpr = item_nbytes(out.item, 8) if is_coll(out) else 8
        return n_buckets * bpr
    if op == "vec.HashJoinDirect":
        # the direct table: one int32 build-row index per join bucket
        # (plus the out-of-domain spill slot)
        nb = ins.param("num_buckets")
        domains = ins.param("key_domains")
        if domains is not None:
            nb = 1
            for lo, hi in domains:
                nb *= int(hi) - int(lo) + 1
        return (int(nb or 0) + 1) * 4
    if op == "vec.FusedJoinGroupAgg":
        # direct join table + the dense group-bucket accumulator rows
        nbj = int(ins.param("join_num_buckets") or 0)
        nbg = int(ins.param("num_buckets") or 0)
        out = ins.outputs[0].type
        bpr = item_nbytes(out.item, 8) if is_coll(out) else 8
        return (nbj + 1) * 4 + nbg * bpr
    if op in ("vec.DictEncode", "vec.DictDecode"):
        # the static dictionary tables shipped with the instruction (remap
        # rank tables / sorted value tables) plus the re-encoded key
        # columns: one i32 per row per encoded column
        table_bytes = 0
        for t in (ins.param("tables") or ()):
            size = getattr(t, "size", None)
            itemsize = getattr(getattr(t, "dtype", None), "itemsize", 4)
            table_bytes += int(size if size is not None else len(t)) * itemsize
        n_cols = len(tuple(ins.param("cols") or ()))
        t0 = ins.inputs[0].type if ins.inputs else None
        rows = int(t0.attr("max_count") or 0) if t0 is not None and is_coll(t0) else 0
        return table_bytes + n_cols * rows * 4
    if op == "vec.SortByKey":
        # permutation indices + a gathered copy of the block
        return sum(_reg_bytes(r) for r in ins.inputs)
    if op == "mesh.ExchangeByKey":
        # send + receive buffers, each a full shard block
        return 2 * sum(_reg_bytes(r) for r in ins.inputs)
    if op == "mesh.AllGatherVec":
        n = int(ins.param("n", 1) or 1)
        return n * sum(_reg_bytes(r) for r in ins.inputs)
    return 0


def _chunk_count(ins: Instruction) -> int:
    """How many copies of a nested body run concurrently."""
    n = ins.param("n")
    if n:
        return int(n)
    if ins.inputs:
        t = ins.inputs[0].type
        if is_coll(t):
            seq_n = t.attr("n")
            if seq_n:
                return int(seq_n)
    return 1


def _program_peak(program: Program) -> Tuple[int, str, list]:
    peak, site, sites = 0, "(empty)", []
    for ins in program.body:
        nested = [p for p in
                  (ins.param("P"), ins.param("Pthen"), ins.param("Pelse"))
                  if p is not None]
        if ins.opcode in ("cf.ConcurrentExecute", "mesh.MeshExecute"):
            inner_peak = max((_program_peak(p)[0] for p in nested), default=0)
            footprint = (_chunk_count(ins) * inner_peak
                         + sum(_reg_bytes(r) for r in ins.inputs)
                         + sum(_reg_bytes(r) for r in ins.outputs))
        elif nested:  # cf.Loop / cf.While / cf.Cond / cf.Call: one body live
            footprint = max(_program_peak(p)[0] for p in nested)
        else:
            footprint = (sum(_reg_bytes(r) for r in ins.inputs)
                         + sum(_reg_bytes(r) for r in ins.outputs)
                         + _scratch_bytes(ins))
        sites.append((ins.opcode, footprint))
        if footprint > peak:
            peak, site = footprint, ins.opcode
    return peak, site, sites


def estimate_peak_bytes(program: Program) -> ResourceEstimate:
    """Estimate the peak working set of a (lowered) program."""
    peak, site, sites = _program_peak(program)
    sites.sort(key=lambda kv: -kv[1])
    return ResourceEstimate(peak_bytes=int(peak), peak_site=site,
                            breakdown=tuple(sites[:8]))


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------


def default_budget() -> Optional[int]:
    """The ``REPRO_MEM_BUDGET_BYTES`` environment default (None → no cap)."""
    raw = os.environ.get("REPRO_MEM_BUDGET_BYTES", "").strip()
    if not raw:
        return None
    try:
        budget = int(float(raw))
    except ValueError:
        raise ValueError(
            f"REPRO_MEM_BUDGET_BYTES must be a byte count, got {raw!r}"
        ) from None
    return budget if budget > 0 else None


def admit(program: Program, budget: Optional[int] = None,
          *, name: str = "") -> ResourceEstimate:
    """Admit ``program`` under ``budget`` bytes or raise AdmissionError.

    ``budget=None`` falls back to :func:`default_budget`; no budget at all
    admits everything (the estimate is still returned for provenance).
    """
    from ..obs.trace import get_tracer

    budget = default_budget() if budget is None else int(budget)
    est = estimate_peak_bytes(program)
    if budget is not None and est.peak_bytes > budget:
        get_tracer().counter("robust.admission.reject")
        raise AdmissionError(
            f"plan {name or program.name!r} rejected by resource admission: "
            f"{est.render()} > budget {budget:,} bytes", est, budget)
    get_tracer().counter("robust.admission.admit")
    return est
