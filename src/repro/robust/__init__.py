"""Guarded compilation & execution: the CVM safety ladder.

The paper's promise — one logical program lowered through many IR flavors
to many platforms — is only production-credible if a bad plan on one
platform degrades gracefully instead of taking the query down.  This
package is that safety layer (see docs/robustness.md):

* :mod:`repro.robust.inject` — a deterministic, seeded fault-injection
  registry with named points wired into the driver's pass loop, PlanStore
  I/O, backend compile/execute, spmd shard execution, and the serve step,
  so chaos tests reproduce exactly;
* :mod:`repro.robust.fallback` — the fallback ladder the compilation
  driver walks when a chosen plan fails verification, lowering, backend
  compile, or its first traced execution (progressively safer strategy
  variants, then the always-correct interp tier), plus poison-plan
  bookkeeping so a crashing plan is never replayed from cache;
* :mod:`repro.robust.admission` — resource admission: estimate a plan's
  peak working set from the statistics catalog *before* execution and
  degrade-or-reject plans over a configurable byte budget instead of
  letting XLA OOM;
* :mod:`repro.robust.retry` — retry/backoff/timeout policies and the EWMA
  straggler detector (generalizing ``distributed/fault.py``), used around
  store I/O and subprocess launches, and the deadline primitives behind
  load shedding in ``launch/serve.py``.
"""

from .admission import (  # noqa: F401
    AdmissionError,
    ResourceEstimate,
    admit,
    default_budget,
    estimate_peak_bytes,
)
from .fallback import (  # noqa: F401
    DegradedWarning,
    SAFE_VARIANTS,
    degrade,
    fallback_ladder,
)
from .inject import (  # noqa: F401
    FaultRule,
    InjectedFault,
    InjectionPoint,
    clear_faults,
    inject,
    maybe_inject,
    register_point,
    registered_points,
)
from .retry import (  # noqa: F401
    Deadline,
    Ewma,
    RetryPolicy,
    StragglerDetector,
    call_with_retry,
)
