"""The fallback ladder: progressively safer plans, ending at interp.

When the cost-chosen candidate fails — verification breaks in a pass,
backend compile raises, or the first traced execution crashes — the driver
does not fail the query.  It walks a ladder of progressively *safer*
strategy bindings (Tupleware's conservative-plan fallback) and, when no
strategy on the requested target survives, re-targets the program at the
reference interpreter (Flare's always-correct unfused tier).

The ladder is derived from :data:`SAFE_VARIANTS`: each rung forces one more
strategy choice to its conservative variant, in order of how adventurous
the adventurous variant is —

    as chosen
      → encode=raw              (no dictionary rank tables; a crashing
                                 encoded plan keeps its direct tier first)
      → groupby=sorted          (no dense-bucket allocation)
      → join=sorted              (no direct-table join scratch)
      → fuse=unfused            (no fused Pallas kernels)
      → grouped-recombine=gather (no mesh exchange collective)
      → target=interp            (reference semantics, off the fast path)

Rungs that would not change the failing plan are skipped, so the ladder
never retries the identical strategy.  Every step emits a structured
:class:`DegradedWarning` plus ``robust.fallback.*`` counters through
``repro.obs`` — degraded service is loud, never silent.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

from ..obs.trace import DegradedWarning, get_tracer, warn_event

__all__ = ["DegradedWarning", "SAFE_VARIANTS", "INTERP_RUNG",
           "fallback_ladder", "degrade"]

#: choice name → conservative variant, in ladder order: each successive
#: rung of the fallback chain forces one more of these
SAFE_VARIANTS: Tuple[Tuple[str, str], ...] = (
    ("encode", "raw"),
    ("groupby", "sorted"),
    ("join", "sorted"),
    ("fuse", "unfused"),
    ("grouped-recombine", "gather"),
)

#: the terminal rung: re-target at the reference interpreter
INTERP_RUNG = "interp"


def fallback_ladder(chosen: Mapping[str, str],
                    choice_names: Optional[Any] = None,
                    ) -> Iterator[Tuple[str, Optional[Dict[str, str]]]]:
    """Yield ``(rung_name, strategy)`` pairs, safest last.

    ``chosen`` is the strategy that just failed; ``choice_names`` restricts
    the ladder to choices the target actually declares (None → all of
    :data:`SAFE_VARIANTS`).  Each yielded strategy forces one more safe
    variant on top of the previous rung; rungs that would re-lower the
    identical strategy are skipped.  The final yield is
    ``(INTERP_RUNG, None)`` — the caller re-targets at interp.
    """
    names = (set(choice_names) if choice_names is not None
             else {k for k, _ in SAFE_VARIANTS})
    previous: Dict[str, str] = dict(chosen)
    for name, safe in SAFE_VARIANTS:
        if name not in names:
            continue
        # a choice absent from the failing strategy was at its default —
        # forcing the safe label would re-lower the identical plan
        if previous.get(name, safe) == safe:
            continue  # already at (or below) this rung — nothing new to try
        forced = dict(previous)
        forced[name] = safe
        previous = forced
        yield f"{name}={safe}", dict(forced)
    yield INTERP_RUNG, None


def degrade(rung: str, *, program: str, target: str, reason: str,
            error: Optional[BaseException] = None, **fields: Any) -> None:
    """Record one step down the ladder: warning + counters + trace event.

    Emits a :class:`DegradedWarning` (so callers can filter degraded
    service), bumps ``robust.fallback.step`` and the per-rung
    ``robust.fallback.<rung>`` counter, and attaches the triggering error.
    """
    tracer = get_tracer()
    tracer.counter("robust.fallback.step")
    tracer.counter(f"robust.fallback.{rung}")
    if error is not None:
        fields = dict(fields, error=f"{type(error).__name__}: {error}")
    warn_event("robust.fallback", category=DegradedWarning, rung=rung,
               program=program, target=target, reason=reason, **fields)
