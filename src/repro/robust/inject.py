"""Deterministic, seeded fault injection for chaos testing.

A small registry of *named injection points* is wired into the stack at
the places production failures actually surface: the driver's pass loop,
PlanStore I/O, backend compile, (first) execution, spmd shard bodies, and
the serve wave step.  Each wired site costs one module-level list check
when no fault is armed — the hot path stays free.

Chaos tests arm points with :func:`inject`::

    with inject("backend.compile", mode="raise", seed=7):
        compile(program, target="local")   # backend compile raises

Three modes:

* ``raise``   — the site raises :class:`InjectedFault`;
* ``corrupt`` — the site's payload is deterministically mangled (the pass
  loop truncates the rewritten program so verification fails; the plan
  store scribbles the record text so the JSON parse fails) — sites without
  a corruptor treat ``corrupt`` as ``raise``;
* ``delay``   — the site sleeps ``delay_s`` (straggler / slow-step
  simulation for timeout and load-shedding paths).

Firing is decided by a ``random.Random(seed)`` stream per armed rule, so a
chaos run replays *exactly*: ``rate=1.0, times=1`` means "fail the first
arrival, then behave"; ``rate<1`` with a fixed seed yields the same firing
sequence every run.  Every firing bumps the ``robust.inject.<point>``
counter and records a trace event when tracing is on.
"""

from __future__ import annotations

import random
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from ..obs.trace import get_tracer

__all__ = [
    "InjectedFault", "InjectionPoint", "FaultRule",
    "register_point", "registered_points",
    "inject", "maybe_inject", "clear_faults",
]


class InjectedFault(RuntimeError):
    """The exception raised by an armed ``raise``-mode injection point."""


@dataclass(frozen=True)
class InjectionPoint:
    """One named place in the stack where faults can be injected."""

    name: str
    modes: Tuple[str, ...]
    description: str = ""


_POINTS: Dict[str, InjectionPoint] = {}


def register_point(name: str, modes: Tuple[str, ...] = ("raise", "delay"),
                   description: str = "") -> InjectionPoint:
    point = InjectionPoint(name, tuple(modes), description)
    _POINTS[name] = point
    return point


def registered_points() -> Dict[str, InjectionPoint]:
    """The injection-point catalog (see docs/robustness.md)."""
    return dict(sorted(_POINTS.items()))


# ---------------------------------------------------------------------------
# the canonical catalog — registered here, wired at the named sites
# ---------------------------------------------------------------------------

register_point(
    "driver.pass", ("raise", "corrupt", "delay"),
    "compiler/driver.py run_passes: after each rewrite pass; corrupt "
    "truncates the rewritten program so verification fails")
register_point(
    "store.load", ("raise", "corrupt", "delay"),
    "compiler/store.py PlanStore.load_plan: record read; corrupt mangles "
    "the JSON text (exercises quarantine)")
register_point(
    "store.save", ("raise", "delay"),
    "compiler/store.py PlanStore.save_plan: atomic record write")
register_point(
    "backend.compile", ("raise", "delay"),
    "compiler/driver.py: the target backend's compile() of the lowered "
    "program")
register_point(
    "backend.execute", ("raise", "delay"),
    "compiler/driver.py CompileResult.__call__: executable dispatch (all "
    "four backends route through it)")
register_point(
    "spmd.shard", ("raise", "delay"),
    "backends/spmd.py evaluate_spmd_program: per-shard body evaluation "
    "(fires during jit tracing of the first call)")
register_point(
    "serve.step", ("raise", "delay"),
    "launch/serve.py serve_loop: before each decode wave (slow-step / "
    "load-shedding simulation)")
register_point(
    "stream.batch", ("raise", "delay"),
    "launch/serve.py StreamConsumer.process: before a micro-batch is folded "
    "into the incremental state (kills the consumer mid-batch)")
register_point(
    "stream.snapshot", ("raise", "delay"),
    "launch/serve.py StreamConsumer.snapshot: before the CheckpointManager "
    "save (kills the consumer mid-snapshot; the atomic rename means the "
    "previous snapshot survives)")
register_point(
    "stream.restore", ("raise", "delay"),
    "launch/serve.py StreamConsumer.restore: before the checkpoint load "
    "(a recovery that itself fails)")


# ---------------------------------------------------------------------------
# armed rules
# ---------------------------------------------------------------------------


@dataclass
class FaultRule:
    """One armed fault: where, how, and (seeded) when it fires."""

    point: str
    mode: str = "raise"
    rate: float = 1.0
    times: Optional[int] = 1          # max firings; None → unlimited
    delay_s: float = 0.05
    seed: int = 0
    fired: int = 0
    _rng: random.Random = field(init=False, repr=False)

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)

    def should_fire(self) -> bool:
        if self.times is not None and self.fired >= self.times:
            return False
        # consume the stream even when the draw loses, so firing sequences
        # replay exactly for a given (seed, arrival order)
        return self._rng.random() < self.rate


#: armed rules — empty list means every wired site is one truthiness check
_ACTIVE: List[FaultRule] = []


def clear_faults() -> None:
    _ACTIVE.clear()


@contextmanager
def inject(point: str, mode: str = "raise", *, rate: float = 1.0,
           times: Optional[int] = 1, delay_s: float = 0.05,
           seed: int = 0) -> Iterator[FaultRule]:
    """Arm one fault rule for the scope of the ``with`` block."""
    reg = _POINTS.get(point)
    if reg is None:
        raise KeyError(f"unknown injection point {point!r}; registered: "
                       f"{sorted(_POINTS)}")
    if mode not in reg.modes:
        raise ValueError(f"injection point {point!r} supports modes "
                         f"{reg.modes}, not {mode!r}")
    rule = FaultRule(point=point, mode=mode, rate=rate, times=times,
                     delay_s=delay_s, seed=seed)
    _ACTIVE.append(rule)
    try:
        yield rule
    finally:
        try:
            _ACTIVE.remove(rule)
        except ValueError:  # pragma: no cover - cleared mid-scope
            pass


def maybe_inject(point: str, payload: Any = None,
                 corrupt: Optional[Callable[[Any, FaultRule], Any]] = None,
                 **attrs: Any) -> Any:
    """The wired-site entry: fire any armed rule for ``point``.

    Returns ``payload`` (possibly corrupted).  ``corrupt`` is the site's
    deterministic payload mangler; a ``corrupt``-mode rule at a site
    without one degenerates to ``raise`` so no armed fault is ever a
    silent no-op.
    """
    if not _ACTIVE:  # the hot path: one list truthiness check
        return payload
    for rule in list(_ACTIVE):
        if rule.point != point or not rule.should_fire():
            continue
        rule.fired += 1
        tracer = get_tracer()
        tracer.counter(f"robust.inject.{point}")
        tracer.event(f"robust.inject.{point}", mode=rule.mode,
                     seed=rule.seed, fired=rule.fired, **attrs)
        if rule.mode == "delay":
            time.sleep(rule.delay_s)
            continue
        if rule.mode == "corrupt" and corrupt is not None:
            payload = corrupt(payload, rule)
            continue
        raise InjectedFault(
            f"injected fault at {point} (mode={rule.mode}, seed={rule.seed}, "
            f"firing {rule.fired})")
    return payload
