"""Paper Fig. 2 (right): k-means, one iteration.

CVM pipeline (fusion rewrite → la.KMeansStep → XLA) vs the numpy oracle
(scikit-learn stand-in).  The paper's point: plan analysis + JIT matches
hand-written code; here the fused single-pass step is the same rewrite.
"""

import time

import numpy as np


def bench(n: int = 1 << 17, d: int = 5, k: int = 16, reps: int = 3):
    from repro.backends.local import LocalBackend
    from repro.core import Builder
    from repro.core.passes import FuseKMeansStep
    from repro.core.types import F32, Tensor

    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, d)).astype(np.float32)
    C = rng.normal(size=(k, d)).astype(np.float32)

    b = Builder("kmeans")
    xr = b.input("X", Tensor(F32, (n, d)))
    cr = b.input("C", Tensor(F32, (k, d)))
    dist = b.emit1("la.CDist2", [xr, cr])
    lab = b.emit1("la.ArgMinRow", [dist])
    sums = b.emit1("la.SegSum", [xr, lab], {"k": k})
    counts = b.emit1("la.SegCount", [lab], {"k": k})
    program = FuseKMeansStep().apply(b.finish(sums, counts))
    compiled = LocalBackend().compile(program)

    compiled({}, X, C)
    t0 = time.time()
    for _ in range(reps):
        s, c = compiled({}, X, C)
    cvm_us = (time.time() - t0) / reps * 1e6

    def np_step(x, cc):
        d2 = (x * x).sum(1)[:, None] - 2 * x @ cc.T + (cc * cc).sum(1)[None]
        labf = np.argmin(d2, axis=1)
        sums = np.zeros((k, d), np.float64)
        np.add.at(sums, labf, x)
        return sums, np.bincount(labf, minlength=k)

    np_step(X, C)
    t0 = time.time()
    for _ in range(reps):
        np_step(X, C)
    np_us = (time.time() - t0) / reps * 1e6

    fused = "la.KMeansStep" in program.opcodes()
    return [(f"fig2_kmeans_n{n}", cvm_us,
             f"numpy_us={np_us:.0f};speedup={np_us/cvm_us:.2f};fused={fused}")]


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
