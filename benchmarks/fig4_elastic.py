"""Paper Fig. 4: serverless elasticity (Lambada analogue).

The serverless trade: spin up as many workers as the latency target needs
and pay worker-seconds.  Here the elastic axis is the mesh worker count —
the same query is re-planned at 1/2/4/8 workers; we report latency and the
worker-seconds cost model, plus an elastic *shrink* event (8 → 4 workers,
i.e. losing half the fleet) that re-plans without touching the frontend
program — the CVM portability claim in miniature.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time


def bench(sf: float = 0.02, reps: int = 3):
    from repro.backends.spmd import SpmdBackend
    from repro.core.passes import Parallelize
    from repro.core.passes.lower_vec import LowerRelToVec
    from repro.launch.mesh import make_mesh
    from repro.relational import tpch

    tables = tpch.generate(sf=sf, seed=0)
    ctx = tpch.make_context(tables, pad_to=8 * 128)
    frame = tpch.QUERIES["q6"](ctx)
    sources = ctx.sources()

    rows = []
    base_us = None
    for workers in [1, 2, 4, 8]:
        program = frame.program("q6")
        if workers > 1:
            program = Parallelize(n=workers).apply(program)
        program = LowerRelToVec(ctx.catalog()).apply(program)
        if workers > 1:
            mesh = make_mesh((workers,), ("workers",))
            compiled = SpmdBackend(mesh).compile(program)
        else:
            from repro.backends.local import LocalBackend
            compiled = LocalBackend().compile(program)
        compiled(sources)
        t0 = time.time()
        for _ in range(reps):
            compiled(sources)
        us = (time.time() - t0) / reps * 1e6
        base_us = base_us or us
        cost = us * workers / 1e6  # worker-seconds (the Fig. 4 cost axis)
        rows.append((f"fig4_elastic_q6_w{workers}", us,
                     f"worker_seconds={cost:.4f};scaling_eff={base_us/(us*workers):.2f}"))

    # elastic shrink event: the 8-worker plan's mesh loses a pod → re-plan at 4
    t0 = time.time()
    program = Parallelize(n=4).apply(frame.program("q6"))
    program = LowerRelToVec(ctx.catalog()).apply(program)
    compiled = SpmdBackend(make_mesh((4,), ("workers",))).compile(program)
    compiled(sources)
    replan_us = (time.time() - t0) * 1e6
    rows.append(("fig4_elastic_replan_8to4", replan_us, "event=worker_loss;replanned=yes"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
