"""Paper Fig. 4: serverless elasticity (Lambada analogue).

The serverless trade: spin up as many workers as the latency target needs
and pay worker-seconds.  Here the elastic axis is the mesh worker count —
the same query is re-planned at 1/2/4/8 workers; we report latency and the
worker-seconds cost model, plus an elastic *shrink* event (8 → 4 workers,
i.e. losing half the fleet) that re-plans without touching the frontend
program — the CVM portability claim in miniature.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time


def bench(sf: float = 0.02, reps: int = 3):
    from repro.backends.multipod import ElasticExecutor
    from repro.relational import tpch

    tables = tpch.generate(sf=sf, seed=0)
    ctx = tpch.make_context(tables, pad_to=8 * 128)
    frame = tpch.QUERIES["q6"](ctx)
    sources = ctx.sources()

    # the elastic facade: one frontend program, plans per topology through
    # the unified driver (repeat topologies hit the structural plan cache)
    ex = ElasticExecutor(program_builder=lambda: frame.program("q6"),
                         catalog=ctx.catalog())

    rows = []
    base_us = None
    for workers in [1, 2, 4, 8]:
        ex.on_resize(workers)
        compiled = ex.plan(workers)
        compiled(sources)
        t0 = time.time()
        for _ in range(reps):
            compiled(sources)
        us = (time.time() - t0) / reps * 1e6
        base_us = base_us or us
        cost = us * workers / 1e6  # worker-seconds (the Fig. 4 cost axis)
        rows.append((f"fig4_elastic_q6_w{workers}", us,
                     f"worker_seconds={cost:.4f};scaling_eff={base_us/(us*workers):.2f}"))

    # elastic shrink event: the 8-worker fleet loses half its pods → re-plan
    # at 4; the topology was seen before, so the re-plan is a cache hit
    t0 = time.time()
    ex.on_resize(4)
    replanned = ex.plan(4)
    replanned(sources)
    replan_us = (time.time() - t0) * 1e6
    rows.append(("fig4_elastic_replan_8to4", replan_us,
                 f"event=worker_loss;replanned=yes;cache_hit={replanned.cache_hit}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
