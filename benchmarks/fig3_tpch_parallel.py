"""Paper Fig. 3: distributed TPC-H (Modularis analogue).

Runs the parallelization rewrite + SPMD mesh backend over 8 host devices
(stand-ins for cluster nodes) and compares against the sequential local
plan.  Run standalone — it must own the process to set the device count.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np


def bench(sf: float = 0.02, reps: int = 3, workers: int = 8):
    from repro.compiler import compile as cvm_compile
    from repro.launch.mesh import make_mesh
    from repro.relational import tpch

    tables = tpch.generate(sf=sf, seed=0)
    ctx = tpch.make_context(tables, pad_to=workers * 128)
    mesh = make_mesh((workers,), ("workers",))

    rows = []
    for qname in ["q1", "q4", "q6", "q12", "q14", "q19"]:
        frame = tpch.QUERIES[qname](ctx)

        seq_c = ctx.compile(frame)
        sources = ctx.sources()
        seq_c(sources)
        t0 = time.time()
        for _ in range(reps):
            seq_c(sources)
        seq_us = (time.time() - t0) / reps * 1e6

        par_c = cvm_compile(frame.program(qname), target="spmd",
                            parallel=workers, catalog=ctx.catalog(), mesh=mesh)
        par_c(sources)
        t0 = time.time()
        for _ in range(reps):
            par_c(sources)
        par_us = (time.time() - t0) / reps * 1e6

        n_coll = sum(1 for o in par_c.program.opcodes() if o.startswith("mesh.All"))
        rows.append((f"fig3_tpch_{qname}_w{workers}", par_us,
                     f"sequential_us={seq_us:.0f};speedup={seq_us/par_us:.2f};collectives={n_coll}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
