"""Paper Fig. 2 (left): TPC-H on a single node.

Compares the CVM-compiled plans (JITQ analogue: fused XLA pipelines) against
a straightforward numpy executor (the interpreter oracle) per query.
Emits ``name,us_per_call,derived`` CSV rows.
"""

import time

import numpy as np


def bench(sf: float = 0.01, reps: int = 3):
    from repro.relational import tpch

    tables = tpch.generate(sf=sf, seed=0)
    ctx = tpch.make_context(tables)
    rows = []
    for qname in sorted(tpch.QUERIES):
        frame = tpch.QUERIES[qname](ctx)
        compiled = ctx.compile(frame)
        sources = ctx.sources()
        compiled(sources)  # compile/warm-up
        t0 = time.time()
        for _ in range(reps):
            out = compiled(sources)
        jax_us = (time.time() - t0) / reps * 1e6

        t0 = time.time()
        for _ in range(reps):
            tpch.REFERENCES[qname](tables)
        np_us = (time.time() - t0) / reps * 1e6
        rows.append((f"fig2_tpch_{qname}", jax_us, f"numpy_ref_us={np_us:.0f};speedup={np_us/jax_us:.2f}"))
    return rows


def main():
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
