"""Generate the data-driven sections of EXPERIMENTS.md from artifacts.

Usage: PYTHONPATH=src:. python benchmarks/gen_experiments.py
Reads artifacts/dryrun (optimized) and artifacts/dryrun_baseline and prints
the §Dry-run and §Roofline tables (markdown) to stdout.
"""

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

ARCH_ORDER = ["starcoder2-15b", "glm4-9b", "qwen2-1.5b", "granite-34b",
              "moonshot-v1-16b-a3b", "mixtral-8x7b", "zamba2-7b",
              "whisper-base", "qwen2-vl-7b", "rwkv6-1.6b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    out = {}
    for p in Path(d).glob("*.json"):
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_e(x):
    return f"{x:.2e}" if isinstance(x, (int, float)) else str(x)


def dryrun_table(arts):
    lines = ["| arch | shape | 16×16 | GiB/dev | 2×16×16 | GiB/dev |",
             "|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = arts.get((a, s, "16x16"))
            r2 = arts.get((a, s, "2x16x16"))
            def cell(r):
                if r is None:
                    return "—", ""
                if "skipped" in r:
                    return "SKIP", ""
                return "OK", f"{r.get('device_mem_gib', 0):.2f}"
            c1, g1 = cell(r1)
            c2, g2 = cell(r2)
            lines.append(f"| {a} | {s} | {c1} | {g1} | {c2} | {g2} |")
    return "\n".join(lines)


def roofline_table(arts):
    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
             "| MODEL_FLOPS | useful | roofline |",
             "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = arts.get((a, s, "16x16"))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {a} | {s} | SKIP: {r['skipped']} | | | | | | |")
                continue
            if "t_compute_s" not in r:
                continue
            lines.append(
                f"| {a} | {s} | {fmt_e(r['t_compute_s'])} | {fmt_e(r['t_memory_s'])} "
                f"| {fmt_e(r['t_collective_s'])} | {r['dominant']} "
                f"| {fmt_e(r['model_flops_global'])} | {r['useful_fraction']:.3f} "
                f"| {r['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def compare_table(base, opt, cells):
    lines = ["| cell | metric | baseline | optimized | Δ |", "|---|---|---|---|---|"]
    for (a, s) in cells:
        b = base.get((a, s, "16x16"))
        o = opt.get((a, s, "16x16"))
        if not b or not o or "skipped" in b or "skipped" in o:
            continue
        for key, label in [("device_mem_gib", "GiB/device"),
                           ("t_memory_s", "t_memory"),
                           ("t_collective_s", "t_collective"),
                           ("t_compute_s", "t_compute"),
                           ("roofline_fraction", "roofline frac")]:
            if key not in b or key not in o:
                continue
            bv, ov = b[key], o[key]
            if bv == 0:
                continue
            delta = (ov - bv) / bv * 100
            lines.append(f"| {a}×{s} | {label} | {fmt_e(bv)} | {fmt_e(ov)} | {delta:+.1f}% |")
    return "\n".join(lines)


def perf_steps_table():
    d = ROOT / "artifacts" / "perf_steps"
    if not d.exists():
        return "(perf_steps artifacts not generated)"
    lines = ["| cell | step | GiB/dev | t_compute | t_memory | t_collective | roofline |",
             "|---|---|---|---|---|---|---|"]
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        arch, shape, step = p.stem.split("__")
        if "error" in r:
            lines.append(f"| {arch}×{shape} | {step} | ERROR | | | | |")
            continue
        lines.append(
            f"| {arch}×{shape} | {step} | {r.get('device_mem_gib','')} "
            f"| {r.get('t_compute_s', 0):.3e} | {r.get('t_memory_s', 0):.3e} "
            f"| {r.get('t_collective_s', 0):.3e} "
            f"| {r.get('roofline_fraction', 0):.4f} |")
    return "\n".join(lines)


def inject():
    """Replace the placeholder comments in EXPERIMENTS.md with live tables."""
    opt = load(ROOT / "artifacts" / "dryrun")
    base = load(ROOT / "artifacts" / "dryrun_baseline")
    md = (ROOT / "EXPERIMENTS.md").read_text()
    cells = [(a, s) for a in ARCH_ORDER for s in SHAPE_ORDER]
    md = md.replace("<!-- DRYRUN_TABLE -->", dryrun_table(opt))
    md = md.replace("<!-- ROOFLINE_TABLE -->", roofline_table(opt))
    md = md.replace("<!-- PERF_STEPWISE -->",
                    perf_steps_table() + "\n\n#### baseline → optimized, all cells\n\n"
                    + compare_table(base, opt, cells))
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated")


def main():
    import sys
    if "--inject" in sys.argv:
        inject()
        return
    opt = load(ROOT / "artifacts" / "dryrun")
    base = load(ROOT / "artifacts" / "dryrun_baseline")
    print("## §Dry-run (optimized configuration)\n")
    print(dryrun_table(opt))
    print("\n## §Roofline (single-pod 16×16, loop-corrected)\n")
    print(roofline_table(opt))
    print("\n## baseline vs optimized (all cells)\n")
    cells = [(a, s) for a in ARCH_ORDER for s in SHAPE_ORDER]
    print(compare_table(base, opt, cells))


if __name__ == "__main__":
    main()
