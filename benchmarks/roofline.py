"""Roofline aggregation: artifacts/dryrun/*.json → §Roofline table.

Per (arch × shape) on the single-pod mesh: the three terms in seconds, the
dominant bottleneck, MODEL_FLOPS, useful fraction, roofline fraction, and a
one-line "what would move the dominant term".  Also emits bench CSV rows.
"""

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

HINTS = {
    ("compute",): "raise per-chip math: bf16-cast matmuls, fewer f32 casts, larger per-device tiles",
    ("memory",): "cut bytes: fuse attention (Pallas), bf16 activations, fewer remat passes, larger microbatch",
    ("collective",): "cut comm: overlap psum with compute, reduce-scatter grads (ZeRO), avoid KV-head replication",
}


def load(mesh: str = "16x16"):
    rows = []
    for p in sorted(ARTIFACTS.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        rows.append(rec)
    return rows


def table(mesh: str = "16x16"):
    rows = load(mesh)
    out = []
    for r in rows:
        if "skipped" in r:
            out.append((r["arch"], r["shape"], "SKIP", r["skipped"]))
            continue
        if "t_compute_s" not in r:
            continue
        dom = r["dominant"]
        out.append((
            r["arch"], r["shape"],
            f"{r['t_compute_s']:.3e}", f"{r['t_memory_s']:.3e}",
            f"{r['t_collective_s']:.3e}", dom,
            f"{r['model_flops_global']:.3e}", f"{r['useful_fraction']:.3f}",
            f"{r['roofline_fraction']:.4f}", f"{r.get('device_mem_gib', 0):.2f}",
            HINTS[(dom,)],
        ))
    return out


def markdown(mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | dominant | "
           "MODEL_FLOPS | useful | roofline | GiB/dev | to improve |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for row in table(mesh):
        if row[2] == "SKIP":
            lines.append(f"| {row[0]} | {row[1]} | SKIP — {row[3]} |" + " |" * 8)
        else:
            lines.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(lines)


def streaming_peak_gbps(nbytes: int = 1 << 26) -> float:
    """Measured streaming-copy bandwidth of this host (GB/s) — the roof a
    memory-bound kernel pass is judged against.  A device-to-device copy of
    ``nbytes`` (best of 5) counts read+write bytes."""
    import time

    import jax
    import jax.numpy as jnp

    src = jnp.zeros(nbytes // 4, jnp.float32)
    copy = jax.jit(lambda a: a + 0.0)
    jax.block_until_ready(copy(src))
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(copy(src))
        best = min(best, time.perf_counter() - t0)
    return 2.0 * nbytes / best / 1e9


def kernel_roofline(bytes_moved: int, wall_s: float,
                    peak_gbps: float) -> dict:
    """One kernel pass against the streaming roof: achieved GB/s, the
    measured peak, and the fraction of roof attained.  A memory-bound fused
    pipeline should land within an order of magnitude of the roof; far
    below means the pass is compute- (or overhead-) bound, not streaming."""
    achieved = bytes_moved / wall_s / 1e9 if wall_s > 0 else 0.0
    return {
        "bytes_moved": int(bytes_moved),
        "wall_s": float(wall_s),
        "achieved_gbps": achieved,
        "peak_gbps": float(peak_gbps),
        "roofline_fraction": achieved / peak_gbps if peak_gbps else 0.0,
    }


def main():
    rows = load()
    for r in rows:
        if "skipped" in r or "t_compute_s" not in r:
            continue
        tmax = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        print(f"roofline_{r['arch']}_{r['shape']},{tmax*1e6:.1f},"
              f"dominant={r['dominant']};roofline_frac={r['roofline_fraction']:.4f};"
              f"useful={r['useful_fraction']:.3f}")


if __name__ == "__main__":
    main()
