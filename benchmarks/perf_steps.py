"""Stepwise perf attribution on the three hillclimb cells.

Runs each cell under four configurations (subprocesses — env toggles must
precede jax init):

  base  : paper-faithful (f32 attention, repeat-KV decode, no donation)
  +A    : + buffer donation                        (memory capacity)
  +AB   : + bf16 attention matmuls                 (compute/memory terms)
  +ABC  : + grouped-head decode (no KV repeat)     (collective term)

Also reports the compilation driver's per-pass instrumentation
(``CompileResult.explain()``) for a representative analytics query on each
in-process target, including the plan-cache effect of a repeated compile.

Results → artifacts/perf_steps/<cell>__<step>.json,
artifacts/perf_steps/compile_passes__<target>.json (pass records + the
cost-model decision records when the costed search ran), BENCH_5.json at
the repo root (grouped-aggregation strategy trajectory: us/call for the
sorted vs direct physical tiers at low and high NDV, plus the costed
driver's decision), and markdown tables on stdout.

Usage: PYTHONPATH=src:. python benchmarks/perf_steps.py [--compile-only]
(--compile-only runs just the compile-pass/cost report — the artifact CI
uploads per PR; --groupby-bench runs just the BENCH_5.json group-by
strategy benchmark; --trace runs traced executions of the same cells →
artifacts/perf_steps/trace__<cell>.json Chrome traces + BENCH_6.json with
the per-op runtime breakdown, cardinality-miss stats, and the <5%
tracing-disabled overhead guard; --robust-bench measures the guarded
compile/execute path with no faults armed vs guard=False → BENCH_7.json
with its own <5% overhead guard plus the fault-recovery wall time;
--join-bench runs the BENCH_8.json join-strategy benchmark: sorted vs
hash direct-table joins at low and high NDV, the costed decisions, and
the fused select→join→group pipeline vs its unfused plan with a
streaming-bandwidth roofline check; --dict-bench runs the BENCH_9.json
dictionary-encoding benchmark: string and sparse-integer group-by/join
keys through the dict-encoded direct tiers vs the sorted tiers, the
costed encode=raw|dict decisions, and oracle checks in both directions;
--stream-bench runs the BENCH_10.json streaming benchmark: sustained
micro-batch fold throughput, the checkpointed-vs-bare snapshot overhead
ratio with its <1.10 guard, and the recovery-time-to-caught-up after an
injected mid-batch kill with an exactly-once oracle check.)
"""

import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "perf_steps"

CELLS = [
    ("mixtral-8x7b", "train_4k"),
    ("qwen2-1.5b", "decode_32k"),
    ("granite-34b", "train_4k"),
]

STEPS = {
    # base..ABC keep the replicated grad accumulator (pre-ZeRO-2 semantics)
    "base": {"REPRO_NO_DONATE": "1", "REPRO_ATTN_F32": "1",
             "REPRO_DECODE_REPEAT": "1", "REPRO_NO_ZERO2": "1"},
    "A_donate": {"REPRO_ATTN_F32": "1", "REPRO_DECODE_REPEAT": "1",
                 "REPRO_NO_ZERO2": "1"},
    "AB_bf16attn": {"REPRO_DECODE_REPEAT": "1", "REPRO_NO_ZERO2": "1"},
    "ABC_groupdecode": {"REPRO_NO_ZERO2": "1"},
    # D: mask-based cache write (decode cells; no-op for train)
    "D_maskwrite": {"REPRO_NO_ZERO2": "1"},
    # E: + ZeRO-2 sharded gradient accumulator (train cells)
    "E_zero2accum": {},
}

SCRIPT = """
import os
{env_lines}
import json, sys
from repro.launch.dryrun import run_cell
rec = run_cell("{arch}", "{shape}", multi_pod=False, save=False, verbose=False,
               probes={probes})
print("REC" + json.dumps(rec, default=str))
"""


def run(arch, shape, step, env_over, probes=True):
    env_lines = "\n".join(f'os.environ["{k}"] = "{v}"' for k, v in env_over.items())
    code = SCRIPT.format(env_lines=env_lines, arch=arch, shape=shape,
                         probes=probes)
    from repro.launch.hermetic import subprocess_env

    env = subprocess_env(ROOT)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=3000, env=env)
    if proc.returncode != 0:
        return {"error": proc.stderr.strip().splitlines()[-1] if proc.stderr else "?"}
    line = [l for l in proc.stdout.splitlines() if l.startswith("REC")][0]
    return json.loads(line[3:])


def compile_pass_report():
    """Per-pass compile timings from the unified driver (in-process)."""
    # this is the first jax init in the parent process; without a platform
    # pin, containers with libtpu but no TPU hang in TPU init
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np
    from repro.compiler import PlanCache, compile as cvm_compile
    from repro.core.expr import col
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(0)
    n = 65_536
    ctx = Context(pad_to=1024)
    ctx.register("sales", {
        "region": rng.integers(0, 16, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    q = (ctx.table("sales")
         .filter(col("year") >= 2020)
         .group_by("region", max_groups=16)
         .agg(sum_("amount").as_("rev"), count_().as_("n")))
    program = q.program("sales_by_region")

    cache = PlanCache()
    for target in ("interp", "local"):
        # optimize="cost": the driver's costed strategy search runs (and is
        # reported) wherever the target declares Choice points
        res = cvm_compile(program, target=target, parallel=4,
                          catalog=ctx.catalog(), cache=cache, optimize="cost")
        payload = {"records": res.explain_records(),
                   "strategy": dict(res.strategy),
                   "decision": (res.decision.records()
                                if res.decision is not None else None)}
        (OUT / f"compile_passes__{target}.json").write_text(
            json.dumps(payload, indent=2))
        print(res.explain())
        print()

    t0 = time.perf_counter()
    res = cvm_compile(program, target="local", parallel=4,
                      catalog=ctx.catalog(), cache=cache, optimize="cost")
    lookup_ms = (time.perf_counter() - t0) * 1e3
    print(f"[perf] repeated compile: cache_hit={res.cache_hit} "
          f"lookup={lookup_ms:.3f} ms (first compile {res.total_s * 1e3:.2f} ms)")


def _groupby_cells():
    """The two grouped-aggregation cells shared by the BENCH_5 strategy
    benchmark and the BENCH_6 traced-execution report: a TPC-H Q1-style
    low-NDV grouping (two small-domain keys, selective filter) and a
    high-NDV grouping whose key domain (2^20) ≫ rows (2^13)."""
    import numpy as np
    from repro.core.expr import col
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(5)
    n = 1 << 17
    ctx = Context(pad_to=1024)
    ctx.register("lineitem", {
        "rf": rng.integers(0, 3, n).astype(np.int32),
        "ls": rng.integers(0, 2, n).astype(np.int32),
        "qty": rng.integers(1, 50, n).astype(np.int32),
        "price": rng.gamma(2.0, 100.0, n).astype(np.float32),
        "ship": rng.integers(0, 2500, n).astype(np.int32),
    })
    # high-NDV cell: the dense bucket table dwarfs one pass over the rows,
    # so sorted should hold this side of the crossover
    m = 1 << 13
    ctx.register("orders", {
        "okey": rng.integers(0, 1 << 20, m).astype(np.int32),
        "total": rng.gamma(2.0, 100.0, m).astype(np.float32),
    })
    cells = {
        "low_ndv_q1": (n, ctx.table("lineitem")
                       .filter(col("ship") <= 2000)
                       .group_by("rf", "ls", max_groups=8)
                       .agg(sum_("qty").as_("sum_qty"),
                            sum_("price").as_("rev"), count_().as_("cnt"))),
        "high_ndv": (m, ctx.table("orders")
                     .group_by("okey", max_groups=m)
                     .agg(sum_("total").as_("rev"), count_().as_("cnt"))),
    }
    return ctx, cells


def groupby_bench_report(reps: int = 20):
    """Forced sorted-vs-direct grouped-aggregation wall times → BENCH_5.json.

    Two cells (see :func:`_groupby_cells`): the sort-free tier must win the
    low-NDV side, the sorted tier should hold the high-NDV side.  Also
    records what ``optimize="cost"`` actually picked per cell, so future PRs
    have a perf + decision trajectory to compare against.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax
    from repro.compiler import PlanCache

    ctx, cells = _groupby_cells()
    sources = ctx.sources()
    record = {"bench": "groupby_sorted_vs_direct", "reps": reps}
    for cell, (rows, q) in cells.items():
        entry = {"rows": rows}
        for label in ("sorted", "direct"):
            res = ctx.compile(q, strategy={"groupby": label}, cache=PlanCache())
            jax.block_until_ready(res(sources))  # compile + warm
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(res(sources))
            entry[label + "_us"] = (time.perf_counter() - t0) / reps * 1e6
        entry["speedup_direct"] = entry["sorted_us"] / entry["direct_us"]
        decided = ctx.compile(q, optimize="cost", cache=PlanCache())
        entry["decision"] = dict(decided.strategy).get("groupby")
        record[cell] = entry
        print(f"[perf] groupby {cell}: sorted {entry['sorted_us']:.0f} us, "
              f"direct {entry['direct_us']:.0f} us "
              f"({entry['speedup_direct']:.2f}x), "
              f"cost picks {entry['decision']}", flush=True)

    (ROOT / "BENCH_5.json").write_text(json.dumps(record, indent=2))
    print(f"[perf] wrote {ROOT / 'BENCH_5.json'}")


def _join_cells():
    """The three join cells for BENCH_8: a PK-FK probe join with a dense
    2^15 build domain (hash should win), a sparse full-2^20-domain join
    with a small build side (the direct-table build dwarfs the small sort —
    sorted should hold), and the TPC-H Q3/Q12 select→join→group shape for
    whole-pipeline fusion.  The build side carries payload columns the Q3
    query never reads — the unfused plan must materialize them through the
    join, the fused op must not."""
    import numpy as np
    from repro.core.expr import col
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(9)
    n, m = 1 << 17, 1 << 15
    ns, ms = 1 << 14, 1 << 11
    ctx = Context(pad_to=1024)
    ctx.register("lineitem", {
        "okey": rng.integers(0, m, n).astype(np.int32),
        "qty": rng.integers(1, 50, n).astype(np.int32),
        "price": rng.gamma(2.0, 100.0, n).astype(np.float32),
        "ship": rng.integers(0, 2500, n).astype(np.int32),
    })
    ctx.register("orders", {
        "okey2": np.arange(m).astype(np.int32),
        "seg": rng.integers(0, 8, m).astype(np.int32),
        "pay1": rng.normal(size=m).astype(np.float32),
        "pay2": rng.normal(size=m).astype(np.float32),
        "pay3": rng.normal(size=m).astype(np.float32),
        "pay4": rng.normal(size=m).astype(np.float32),
    })
    ctx.register("sparse_probe", {
        "k": (rng.integers(0, ms, ns) * 512).astype(np.int32),
        "x": rng.normal(size=ns).astype(np.float32),
    })
    ctx.register("sparse_build", {
        "bk": (np.arange(ms) * 512).astype(np.int32),
        "y": rng.normal(size=ms).astype(np.float32),
    })
    join_low = ctx.table("lineitem").join(
        ctx.table("orders"), left_on=("okey",), right_on=("okey2",))
    join_high = ctx.table("sparse_probe").join(
        ctx.table("sparse_build"), left_on=("k",), right_on=("bk",))
    q3 = (ctx.table("lineitem").filter(col("ship") <= 2000)
          .join(ctx.table("orders"), left_on=("okey",), right_on=("okey2",))
          .group_by("seg", max_groups=8)
          .agg(sum_("price").as_("rev"), count_().as_("cnt")))
    return ctx, {"low_ndv": (n, join_low), "high_ndv": (ns, join_high)}, q3


def join_bench_report(reps: int = 15):
    """Forced sorted-vs-hash join wall times + whole-pipeline fusion →
    BENCH_8.json.

    Low NDV (dense 2^15 build domain): the direct-table probe must beat the
    sort+searchsorted tier and ``optimize="cost"`` must pick it.  High NDV
    (sparse ~2^19 domain, 2^13 build rows): the table build dwarfs the small
    sort, sorted must win and cost must keep it.  The Q3-shaped pipeline
    compares the fused ``vec.FusedJoinGroupAgg`` (jit and Pallas-kernel
    paths) against the unfused select→join→group plan, oracle-checked
    against interp.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np
    import jax
    from repro.compiler import PlanCache
    from benchmarks.roofline import kernel_roofline, streaming_peak_gbps

    ctx, cells, q3 = _join_cells()
    sources = ctx.sources()

    def best_wall_us(res):
        # best-of-N: robust to scheduler noise on shared CPU runners, and
        # the systematic tier differences are what the bench is after
        jax.block_until_ready(res(sources))  # compile + warm
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(res(sources))
            walls.append(time.perf_counter() - t0)
        return float(min(walls) * 1e6)

    record = {"bench": "join_sorted_vs_hash", "reps": reps}
    for cell, (rows, q) in cells.items():
        entry = {"rows": rows}
        for label in ("sorted", "hash"):
            res = ctx.compile(q, strategy={"join": label}, cache=PlanCache())
            entry[label + "_us"] = best_wall_us(res)
            entry[label + "_ops"] = sorted(set(res.program.opcodes()))
        entry["speedup_hash"] = entry["sorted_us"] / entry["hash_us"]
        decided = ctx.compile(q, optimize="cost", cache=PlanCache())
        entry["decision"] = dict(decided.strategy).get("join")
        record[cell] = entry
        print(f"[perf] join {cell}: sorted {entry['sorted_us']:.0f} us, "
              f"hash {entry['hash_us']:.0f} us "
              f"({entry['speedup_hash']:.2f}x), "
              f"cost picks {entry['decision']}", flush=True)

    # whole-pipeline fusion on the Q3 shape: fused vs unfused, same strategy
    strat = {"join": "hash", "groupby": "direct"}
    fused = ctx.compile(q3, strategy=strat, cache=PlanCache())
    unfused = ctx.compile(q3, strategy=strat, fuse=False, cache=PlanCache())
    kernel = ctx.compile(q3, strategy=strat, use_kernels=True,
                         cache=PlanCache())
    assert "vec.FusedJoinGroupAgg" in fused.program.opcodes()
    assert "vec.HashJoinDirect" in unfused.program.opcodes()
    entry = {
        "fused_us": best_wall_us(fused),
        "unfused_us": best_wall_us(unfused),
        "fused_kernel_us": best_wall_us(kernel),
        "fused_ops": sorted(set(fused.program.opcodes())),
    }
    entry["speedup_fused"] = entry["unfused_us"] / entry["fused_us"]

    # oracle check: fused results must be bit-for-bit the interp answer's
    # groups (float sums compared to 1e-4)
    want = ctx.execute(q3, target="interp")
    ow = np.argsort(np.asarray(want["seg"]).ravel())
    oracle_ok = True
    for res in (fused, unfused, kernel):
        (out,) = res(sources)
        got = out.to_numpy()
        og = np.argsort(got["seg"])
        oracle_ok &= bool(np.allclose(
            got["rev"][og], np.asarray(want["rev"]).ravel()[ow], rtol=1e-4))
        oracle_ok &= bool(np.array_equal(
            got["cnt"][og], np.asarray(want["cnt"]).ravel()[ow]))
    entry["oracle_ok"] = oracle_ok

    # roofline: the fused kernel reads each probe column once and the dense
    # build tables once — compare achieved streaming bandwidth against a
    # measured copy peak
    n = cells["low_ndv"][0]
    probe_bytes = 4 * 4 * n                      # okey, qty, price, ship
    table_bytes = (1 << 15) * 4 * 2              # seg table + present
    entry["roofline"] = kernel_roofline(
        bytes_moved=probe_bytes + table_bytes,
        wall_s=entry["fused_kernel_us"] / 1e6,
        peak_gbps=streaming_peak_gbps())
    record["q3_fusion"] = entry
    print(f"[perf] q3 fusion: unfused {entry['unfused_us']:.0f} us, "
          f"fused {entry['fused_us']:.0f} us "
          f"({entry['speedup_fused']:.2f}x), kernel "
          f"{entry['fused_kernel_us']:.0f} us, oracle_ok={oracle_ok}",
          flush=True)

    (ROOT / "BENCH_8.json").write_text(json.dumps(record, indent=2))
    print(f"[perf] wrote {ROOT / 'BENCH_8.json'}")
    return (record["low_ndv"]["decision"] == "hash"
            and record["low_ndv"]["speedup_hash"] >= 2.0
            and record["high_ndv"]["decision"] == "sorted"
            and record["high_ndv"]["speedup_hash"] < 2.0
            and entry["speedup_fused"] > 1.0 and oracle_ok)


def _dict_cells():
    """The four dictionary-encoding cells for BENCH_9.

    A: Q1-shaped group-by on a low-cardinality *string* key (64 cities over
       2^17 rows) — dictionary ranks unlock the sort-free direct tier and
       the costed search must pick it.
    B: the same shape with every key distinct (~2^21 keys) — over
       ``DICT_MAX_CARD``, so no per-column dictionary exists, *and* over
       ``MAX_DIRECT_BUCKETS`` even as global codes, so the direct tier
       stays off and cost must keep sorted/raw.  (Below 2^20 distinct
       strings the global-code domain is itself direct-eligible — the
       encoding moves the sorted handoff from 2^20 raw span to 2^20
       *distinct values*.)
    C: sparse integer keys (512 distinct over a ~1.5e9 span) — the raw span
       overflows ``MAX_DIRECT_BUCKETS`` but the ``vec.DictEncode`` sandwich
       shrinks it to 512 ranks.
    D: a Q3-shaped string join (2^17 probe rows against 2^14 build keys)
       followed by a small group-by — ranks make the direct-table join
       available on string keys.

    Each cell gets its own :class:`Context` so each builds its own global
    string dictionary.
    """
    import numpy as np
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(31)
    n = 1 << 17
    cells = {}

    # A — low-cardinality strings
    card_a = 64
    cities = np.array([f"city-{i:03d}" for i in range(card_a)])
    ctx_a = Context(pad_to=1024)
    ctx_a.register("sales", {
        "city": cities[rng.integers(0, card_a, n)],
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
    })
    q_a = (ctx_a.table("sales").group_by("city", max_groups=card_a)
           .agg(sum_("amount").as_("rev"), count_().as_("n")))
    cells["low_card_string"] = (ctx_a, n, card_a, q_a)

    # B — high-cardinality strings (> MAX_DIRECT_BUCKETS even as codes)
    nb = 1 << 21
    card_b = nb
    users = np.char.add("user-", np.arange(nb).astype(str))
    ctx_b = Context(pad_to=1024)
    ctx_b.register("sales", {
        "city": users,
        "amount": rng.gamma(2.0, 50.0, nb).astype(np.float32),
    })
    q_b = (ctx_b.table("sales").group_by("city", max_groups=nb)
           .agg(sum_("amount").as_("rev"), count_().as_("n")))
    cells["high_card_string"] = (ctx_b, nb, card_b, q_b)

    # C — sparse integer keys
    card_c = 512
    domain = rng.integers(0, 1_500_000_000, card_c).astype(np.int32)
    ctx_c = Context(pad_to=1024)
    ctx_c.register("sales", {
        "city": domain[rng.integers(0, card_c, n)],
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
    })
    q_c = (ctx_c.table("sales").group_by("city", max_groups=card_c)
           .agg(sum_("amount").as_("rev"), count_().as_("n")))
    cells["sparse_int"] = (ctx_c, n, card_c, q_c)

    # D — Q3-shaped string join
    m = 1 << 14
    skus = np.array([f"sku-{i:05d}" for i in range(m)])
    ctx_d = Context(pad_to=1024)
    ctx_d.register("lineitem", {
        "sku": skus[rng.integers(0, m, n)],
        "qty": rng.integers(1, 50, n).astype(np.int32),
        "price": rng.gamma(2.0, 100.0, n).astype(np.float32),
    })
    ctx_d.register("parts", {
        "psku": skus,
        "seg": rng.integers(0, 8, m).astype(np.int32),
    })
    q_d = (ctx_d.table("lineitem")
           .join(ctx_d.table("parts"), left_on=("sku",), right_on=("psku",))
           .group_by("seg", max_groups=8)
           .agg(sum_("price").as_("rev"), count_().as_("cnt")))
    cells["string_join"] = (ctx_d, n, m, q_d)
    return cells


def dict_bench_report(reps: int = 15):
    """Dictionary-encoded direct tiers vs sorted on string/sparse keys →
    BENCH_9.json.

    Per cell: forced ``encode=raw`` sorted tier vs forced dict-encoded
    direct tier wall times (best-of-N), what ``optimize="cost"`` actually
    chose, and an oracle check of both physical plans.  Cells A/C/D check
    against the interp oracle; cell B's ~70k-group aggregation is
    intractable for the O(groups×rows) reference interpreter, so it checks
    against a vectorized numpy oracle (recorded as ``oracle: "numpy"``).
    The dict-direct plan of cell A also gets a streaming-bandwidth
    roofline.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import warnings

    import numpy as np
    import jax
    from repro.compiler import PlanCache
    from benchmarks.roofline import kernel_roofline, streaming_peak_gbps

    cells = _dict_cells()

    def best_wall_us(ctx, res):
        sources = ctx.sources()
        jax.block_until_ready(res(sources))  # compile + warm
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(res(sources))
            walls.append(time.perf_counter() - t0)
        return float(min(walls) * 1e6)

    def numpy_oracle(ctx, table="sales", key="city", val="amount"):
        cols = ctx.tables[table]
        keys, inv = np.unique(cols[key], return_inverse=True)
        rev = np.zeros(len(keys), np.float64)
        np.add.at(rev, inv, cols[val].astype(np.float64))
        cnt = np.bincount(inv, minlength=len(keys))
        return {"city": keys, "rev": rev, "n": cnt}

    def oracle_matches(want, got, int_cols=("n", "cnt")):
        ow = np.argsort(np.asarray(want["city" if "city" in want else "seg"]
                                   ).ravel())
        og = np.argsort(np.asarray(got["city" if "city" in got else "seg"]
                                   ).ravel())
        ok = True
        for k in want:
            w = np.asarray(want[k]).ravel()[ow]
            g = np.asarray(got[k]).ravel()[og]
            if k in int_cols or g.dtype.kind in ("U", "S", "O", "i"):
                ok &= bool(np.array_equal(g.astype(w.dtype), w))
            else:
                ok &= bool(np.allclose(g, w, rtol=1e-3))
        return ok

    record = {"bench": "dict_encoding", "reps": reps,
              "peak_gbps": streaming_peak_gbps()}
    groupby_cells = ("low_card_string", "high_card_string", "sparse_int")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for cell in groupby_cells:
            ctx, rows, card, q = cells[cell]
            entry = {"rows": rows, "key_cardinality": card}
            raw = ctx.compile(q, strategy={"groupby": "sorted",
                                           "encode": "raw"},
                              cache=PlanCache())
            dct = ctx.compile(q, strategy={"groupby": "direct",
                                           "encode": "dict"},
                              cache=PlanCache())
            entry["sorted_raw_us"] = best_wall_us(ctx, raw)
            entry["direct_dict_us"] = best_wall_us(ctx, dct)
            entry["direct_dict_ops"] = sorted(set(dct.program.opcodes()))
            entry["speedup_dict"] = (entry["sorted_raw_us"]
                                     / entry["direct_dict_us"])
            decided = ctx.compile(q, optimize="cost", cache=PlanCache())
            entry["decision"] = {k: v for k, v in dict(decided.strategy
                                                       ).items()
                                 if k in ("groupby", "encode")}
            if cell == "high_card_string":
                entry["oracle"] = "numpy"
                want = numpy_oracle(ctx)
            else:
                entry["oracle"] = "interp"
                want = ctx.execute(q, target="interp")
            for label, strat in (("sorted_raw", {"groupby": "sorted",
                                                 "encode": "raw"}),
                                 ("direct_dict", {"groupby": "direct",
                                                  "encode": "dict"})):
                got = ctx.execute(q, target="local", strategy=strat)
                entry[f"oracle_ok_{label}"] = oracle_matches(want, got)
            if cell == "low_card_string":
                # dict-direct moves the i32 code column + f32 values once,
                # plus the compacted card-sized bucket epilogue
                entry["roofline"] = kernel_roofline(
                    bytes_moved=rows * 8 + card * 12,
                    wall_s=entry["direct_dict_us"] / 1e6,
                    peak_gbps=record["peak_gbps"])
            record[cell] = entry
            print(f"[perf] dict {cell}: sorted/raw "
                  f"{entry['sorted_raw_us']:.0f} us, direct/dict "
                  f"{entry['direct_dict_us']:.0f} us "
                  f"({entry['speedup_dict']:.2f}x), cost picks "
                  f"{entry['decision']}", flush=True)

        # D — the string join
        ctx, rows, m, q = cells["string_join"]
        entry = {"rows": rows, "build_keys": m}
        raw = ctx.compile(q, strategy={"join": "sorted", "encode": "raw"},
                          cache=PlanCache())
        dct = ctx.compile(q, strategy={"join": "hash", "encode": "dict"},
                          cache=PlanCache())
        entry["sorted_raw_us"] = best_wall_us(ctx, raw)
        entry["hash_dict_us"] = best_wall_us(ctx, dct)
        entry["hash_dict_ops"] = sorted(set(dct.program.opcodes()))
        entry["speedup_dict"] = entry["sorted_raw_us"] / entry["hash_dict_us"]
        decided = ctx.compile(q, optimize="cost", cache=PlanCache())
        entry["decision"] = {k: v for k, v in dict(decided.strategy).items()
                             if k in ("join", "encode")}
        entry["oracle"] = "interp"
        want = ctx.execute(q, target="interp")
        for label, strat in (("sorted_raw", {"join": "sorted",
                                             "encode": "raw"}),
                             ("hash_dict", {"join": "hash",
                                            "encode": "dict"})):
            got = ctx.execute(q, target="local", strategy=strat)
            entry[f"oracle_ok_{label}"] = oracle_matches(want, got)
        record["string_join"] = entry
        print(f"[perf] dict string_join: sorted/raw "
              f"{entry['sorted_raw_us']:.0f} us, hash/dict "
              f"{entry['hash_dict_us']:.0f} us "
              f"({entry['speedup_dict']:.2f}x), cost picks "
              f"{entry['decision']}", flush=True)

    (ROOT / "BENCH_9.json").write_text(json.dumps(record, indent=2))
    print(f"[perf] wrote {ROOT / 'BENCH_9.json'}")
    low = record["low_card_string"]
    high = record["high_card_string"]
    oracle_ok = all(v for c in ("low_card_string", "high_card_string",
                                "sparse_int", "string_join")
                    for k, v in record[c].items()
                    if k.startswith("oracle_ok_"))
    return (low["decision"] == {"groupby": "direct", "encode": "dict"}
            and low["speedup_dict"] >= 2.0
            and high["decision"].get("groupby") == "sorted"
            and high["decision"].get("encode", "raw") == "raw"
            and oracle_ok)


def _stream_cell():
    """The streaming cell for BENCH_10: a Q1-shaped filtered group-by over
    2^18 rows delivered as 8192-row micro-batches."""
    import numpy as np
    from repro.core.expr import col
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(13)
    n = 1 << 18
    ctx = Context(pad_to=1024)
    ctx.register("sales", {
        "region": rng.integers(0, 8, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    q = (ctx.table("sales").filter(col("year") >= 2020)
         .group_by("region", max_groups=8)
         .agg(sum_("amount").as_("rev"), count_().as_("n")))
    return ctx, n, q


def stream_bench_report(reps: int = 7):
    """Streaming-target trajectory → BENCH_10.json.

    Three numbers the streaming story stands on:

    * **sustained throughput** — rows/s folding the stream as sequenced
      micro-batches through :class:`StreamConsumer` (best-of-N, fold chain
      synced before the clock stops);
    * **snapshot overhead** — the same fold with a durable
      ``CheckpointManager`` snapshot every ``snapshot_every`` batches vs
      no checkpointing at all; the ratio must stay **< 1.10** (durability
      may not tax steady-state throughput more than 10%);
    * **recovery time to caught-up** — a ``stream.batch`` kill mid-stream,
      then the measured wall from failure to the consumer having restored
      and replayed the uncommitted suffix (``stream.recovery_s``), plus an
      exactly-once oracle check of the final answer.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import shutil
    import tempfile

    import numpy as np
    import jax
    from repro.compiler import PlanCache
    from repro.distributed.checkpoint import CheckpointManager
    from repro.frontends.dataflow import _to_numpy
    from repro.launch.serve import StreamConsumer, microbatches, stream_loop
    from repro.obs import tracing
    from repro.robust.inject import inject

    batch_rows, snapshot_every = 8192, 16
    ctx, n, q = _stream_cell()
    res = ctx.compile(q, target="stream", stream_table="sales",
                      batch_rows=batch_rows, cache=PlanCache())
    batches = microbatches(ctx.tables["sales"], batch_rows)
    sources = ctx.sources()

    def fold_wall(ckpt_dir=None):
        c = StreamConsumer(
            res, sources,
            checkpoint=(CheckpointManager(ckpt_dir, n_shards=1, keep=2)
                        if ckpt_dir else None),
            snapshot_every=snapshot_every)
        t0 = time.perf_counter()
        for mb in batches:
            c.process(mb)
        c.snapshot()
        jax.block_until_ready(c.results())  # the fold chain is async
        return time.perf_counter() - t0, c

    fold_wall()  # warm the jitted segments
    fold_wall()
    base_s = min(fold_wall()[0] for _ in range(reps))
    ckpt_walls = []
    snapshots = 0
    for _ in range(reps):
        d = tempfile.mkdtemp(prefix="stream_bench_ckpt_")
        try:
            wall, c = fold_wall(d)
            ckpt_walls.append(wall)
            snapshots = c.stats.snapshots
        finally:
            shutil.rmtree(d, ignore_errors=True)
    ckpt_s = min(ckpt_walls)

    # recovery: kill the first fold, measure failure → caught-up
    d = tempfile.mkdtemp(prefix="stream_bench_recover_")
    try:
        c = StreamConsumer(res, sources,
                           checkpoint=CheckpointManager(d, n_shards=1,
                                                        keep=2),
                           snapshot_every=snapshot_every)
        with tracing() as tr:
            with inject("stream.batch", rate=1.0, times=1, seed=0):
                out = stream_loop(batches, c, max_recoveries=3)
        recovery_s = tr.histograms["stream.recovery_s"][0]
    finally:
        shutil.rmtree(d, ignore_errors=True)
    want = ctx.execute(q, target="interp")
    got = _to_numpy(out[0])
    ow = np.argsort(np.asarray(want["region"]).ravel())
    og = np.argsort(np.asarray(got["region"]).ravel())
    oracle_ok = all(
        bool(np.allclose(np.asarray(got[k]).ravel()[og],
                         np.asarray(want[k]).ravel()[ow], rtol=1e-4))
        for k in want)

    record = {
        "bench": "stream", "reps": reps, "rows": n,
        "batch_rows": batch_rows, "n_batches": len(batches),
        "snapshot_every": snapshot_every, "snapshots": snapshots,
        "base_wall_s": base_s, "checkpointed_wall_s": ckpt_s,
        "snapshot_overhead_ratio": ckpt_s / base_s,
        "snapshot_overhead_guard": "<1.10",
        "throughput_rows_per_s": n / base_s,
        "batch_fold_ms": base_s / len(batches) * 1e3,
        "recovery_s": recovery_s,
        "recovery_restores": c.stats.restores,
        "recovery_replayed": c.stats.replayed,
        "oracle_ok_recovered": oracle_ok,
    }
    (ROOT / "BENCH_10.json").write_text(json.dumps(record, indent=2))
    print(f"[perf] stream: {n} rows in {len(batches)}x{batch_rows} batches, "
          f"{record['throughput_rows_per_s'] / 1e6:.2f} Mrows/s, snapshot "
          f"overhead {record['snapshot_overhead_ratio']:.3f}x, recovery "
          f"{recovery_s * 1e3:.1f} ms, oracle_ok={oracle_ok}", flush=True)
    print(f"[perf] wrote {ROOT / 'BENCH_10.json'}")
    return (record["snapshot_overhead_ratio"] < 1.10
            and recovery_s < 60.0 and oracle_ok)


def trace_report(reps: int = 30):
    """Traced executions → Chrome traces + BENCH_6.json.

    Per cell: a ``trace__<cell>.json`` Chrome trace (compile-pass spans
    nested under the compile span, the execute span, per-operator
    cardinality annotations), the jit path's estimate-vs-actual cardinality
    records, and the eager interpreter's per-operator wall-time breakdown.
    Plus the overhead guard: with tracing *disabled*, the instrumented
    ``CompileResult.__call__`` on the low-NDV Q1-style hot path must stay
    within 5% of calling the bare executable (the BENCH_5 measurement
    convention).
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import statistics
    import jax
    from repro.compiler import PlanCache
    from repro.obs import tracing, write_chrome_trace

    ctx, cells = _groupby_cells()
    sources = ctx.sources()
    record = {"bench": "traced_execution", "reps": reps}

    for cell, (rows, q) in cells.items():
        with tracing() as tr:
            res = ctx.compile(q, optimize="cost", cache=PlanCache())
            jax.block_until_ready(res(sources))
        trace_path = OUT / f"trace__{cell}.json"
        write_chrome_trace(trace_path, tr)
        prof = res.profile
        entry = {
            "rows": rows,
            "strategy": dict(res.strategy),
            "wall_s": prof.wall_s,
            "worst_cardinality_miss": prof.worst_miss,
            "operators": prof.records(),
        }
        # the eager oracle can time individual operators — the per-op
        # runtime breakdown the jitted path cannot observe from inside XLA
        with tracing():
            ires = ctx.compile(q, target="interp", cache=PlanCache())
            ires(ctx.tables)
        entry["interp_op_wall_s"] = {o["op"]: o["wall_s"]
                                     for o in ires.profile.records()}
        record[cell] = entry
        print(f"[perf] trace {cell}: {prof.wall_s * 1e3:.1f} ms, "
              f"worst miss {prof.worst_miss * 100:.0f}%, "
              f"{len(prof.observations)} op(s) → {trace_path.name}", flush=True)

    # overhead guard: tracing disabled, wrapped call vs bare executable
    q = cells["low_ndv_q1"][1]
    res = ctx.compile(q, cache=PlanCache())
    jax.block_until_ready(res(sources))  # warm

    def median_call(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return statistics.median(ts)

    direct_s = median_call(lambda: res.executable(sources))
    wrapped_s = median_call(lambda: res(sources))
    ratio = wrapped_s / direct_s
    ok = ratio < 1.05
    record["overhead_guard"] = {
        "cell": "low_ndv_q1", "direct_us": direct_s * 1e6,
        "wrapped_us": wrapped_s * 1e6, "ratio": ratio,
        "threshold": 1.05, "pass": ok,
    }
    print(f"[perf] tracing-disabled overhead: direct {direct_s * 1e6:.0f} us, "
          f"wrapped {wrapped_s * 1e6:.0f} us → ratio {ratio:.3f} "
          f"({'PASS' if ok else 'FAIL'} < 1.05)", flush=True)

    (ROOT / "BENCH_6.json").write_text(json.dumps(record, indent=2))
    print(f"[perf] wrote {ROOT / 'BENCH_6.json'}")


def robust_bench_report(reps: int = 30):
    """Guarded-execution overhead with no faults armed → BENCH_7.json.

    The robustness layer must be free when nothing fails: on the low-NDV
    Q1-style hot path, a ``guard=True`` (default) compile+execute must stay
    within 5% of ``guard=False`` — the armed exec guard is one attribute
    check per call and every unarmed injection site is one list-truthiness
    check.  Also records, informationally, the wall time to *recover* from
    an injected backend-compile fault through the fallback ladder.
    """
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import statistics
    import warnings
    import jax
    from repro.compiler import PlanCache
    from repro.robust.inject import inject

    ctx, cells = _groupby_cells()
    sources = ctx.sources()
    q = cells["low_ndv_q1"][1]
    record = {"bench": "guarded_execution_overhead", "reps": reps,
              "cell": "low_ndv_q1"}

    def median_call(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return statistics.median(ts)

    def median_compile(**kw):
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            ctx.compile(q, cache=PlanCache(), **kw)
            ts.append(time.perf_counter() - t0)
        return statistics.median(ts)

    guarded = ctx.compile(q, cache=PlanCache())            # guard defaults on
    unguarded = ctx.compile(q, cache=PlanCache(), guard=False)
    jax.block_until_ready(guarded(sources))                # warm + disarm
    jax.block_until_ready(unguarded(sources))
    guarded_s = median_call(lambda: guarded(sources))
    unguarded_s = median_call(lambda: unguarded(sources))
    ratio = guarded_s / unguarded_s
    ok = ratio < 1.05
    record["overhead_guard"] = {
        "guarded_us": guarded_s * 1e6, "unguarded_us": unguarded_s * 1e6,
        "ratio": ratio, "threshold": 1.05, "pass": ok,
    }
    record["compile_overhead"] = {
        "guarded_ms": median_compile() * 1e3,
        "unguarded_ms": median_compile(guard=False) * 1e3,
    }
    print(f"[perf] guards-enabled no-fault overhead: guarded "
          f"{guarded_s * 1e6:.0f} us, unguarded {unguarded_s * 1e6:.0f} us "
          f"→ ratio {ratio:.3f} ({'PASS' if ok else 'FAIL'} < 1.05)",
          flush=True)

    # informational: how long one trip down the fallback ladder costs
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        t0 = time.perf_counter()
        with inject("backend.compile", mode="raise", times=1):
            res = ctx.compile(q, cache=PlanCache())
        jax.block_until_ready(res(sources))
        recover_s = time.perf_counter() - t0
    record["fault_recovery"] = {
        "point": "backend.compile", "wall_s": recover_s,
        "degraded": list(res.degraded),
    }
    print(f"[perf] fallback recovery (backend.compile fault): "
          f"{recover_s * 1e3:.0f} ms via {' → '.join(res.degraded)}",
          flush=True)

    (ROOT / "BENCH_7.json").write_text(json.dumps(record, indent=2))
    print(f"[perf] wrote {ROOT / 'BENCH_7.json'}")
    return ok


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    if "--robust-bench" in sys.argv:
        if not robust_bench_report():
            sys.exit(1)
        return
    if "--trace" in sys.argv:
        trace_report()
        return
    if "--groupby-bench" in sys.argv:
        groupby_bench_report()
        return
    if "--join-bench" in sys.argv:
        if not join_bench_report():
            sys.exit(1)
        return
    if "--dict-bench" in sys.argv:
        if not dict_bench_report():
            sys.exit(1)
        return
    if "--stream-bench" in sys.argv:
        if not stream_bench_report():
            sys.exit(1)
        return
    compile_pass_report()
    if "--compile-only" in sys.argv:
        return
    groupby_bench_report()
    for arch, shape in CELLS:
        for step, env_over in STEPS.items():
            out = OUT / f"{arch}__{shape}__{step}.json"
            if out.exists():
                print(f"[perf] {arch}×{shape} {step}: cached", flush=True)
                continue
            rec = run(arch, shape, step, env_over)
            out.write_text(json.dumps(rec, indent=2, default=str))
            keys = ("device_mem_gib", "t_compute_s", "t_memory_s", "t_collective_s",
                    "roofline_fraction")
            vals = {k: rec.get(k) for k in keys}
            print(f"[perf] {arch}×{shape} {step}: {vals}", flush=True)

    # markdown table
    print("\n| cell | step | GiB/dev | t_compute | t_memory | t_collective | roofline |")
    print("|---|---|---|---|---|---|---|")
    for arch, shape in CELLS:
        for step in STEPS:
            f = OUT / f"{arch}__{shape}__{step}.json"
            if not f.exists():
                continue
            r = json.loads(f.read_text())
            if "error" in r:
                print(f"| {arch}×{shape} | {step} | ERROR |  |  |  |  |")
                continue
            print(f"| {arch}×{shape} | {step} | {r.get('device_mem_gib','')} "
                  f"| {r.get('t_compute_s', 0):.3e} | {r.get('t_memory_s', 0):.3e} "
                  f"| {r.get('t_collective_s', 0):.3e} "
                  f"| {r.get('roofline_fraction', 0):.4f} |")


if __name__ == "__main__":
    main()
