"""Benchmark orchestrator — one function per paper figure/table.

Each figure runs in its own subprocess (fig3/fig4 need their own
``XLA_FLAGS`` device counts, which jax locks at first init).  Prints
``name,us_per_call,derived`` CSV.

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

import argparse
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.launch.hermetic import subprocess_env  # noqa: E402

FIGS = [
    ("fig2_tpch_single", "benchmarks.fig2_tpch_single"),
    ("fig2_kmeans", "benchmarks.fig2_kmeans"),
    ("fig3_tpch_parallel", "benchmarks.fig3_tpch_parallel"),
    ("fig4_elastic", "benchmarks.fig4_elastic"),
    ("roofline", "benchmarks.roofline"),
]


def run_fig(module: str, timeout: int = 1800) -> str:
    env = subprocess_env(ROOT, extra_pythonpath=[ROOT])
    proc = subprocess.run([sys.executable, "-m", module], capture_output=True,
                          text=True, timeout=timeout, env=env, cwd=str(ROOT))
    if proc.returncode != 0:
        return f"{module},ERROR,{proc.stderr.strip().splitlines()[-1] if proc.stderr else 'unknown'}"
    return proc.stdout.strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for name, module in FIGS:
        if args.only and args.only not in name:
            continue
        out = run_fig(module)
        for line in out.splitlines():
            if line and "," in line:
                print(line)


if __name__ == "__main__":
    main()
