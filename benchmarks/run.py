"""Benchmark orchestrator — one function per paper figure/table.

Each figure runs in its own subprocess (fig3/fig4 need their own
``XLA_FLAGS`` device counts, which jax locks at first init).  Prints
``name,us_per_call,derived`` CSV.

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--explain]

``--explain`` prints the cost-based plan-selection decision for a TPC-H
grouped aggregation on the spmd target at low and high group cardinality:
candidates considered, estimated vs measured cost, and the winner.
"""

import argparse
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.launch.hermetic import subprocess_env  # noqa: E402

FIGS = [
    ("fig2_tpch_single", "benchmarks.fig2_tpch_single"),
    ("fig2_kmeans", "benchmarks.fig2_kmeans"),
    ("fig3_tpch_parallel", "benchmarks.fig3_tpch_parallel"),
    ("fig4_elastic", "benchmarks.fig4_elastic"),
    ("roofline", "benchmarks.roofline"),
]

EXPLAIN_SCRIPT = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.compiler import compile as cvm_compile
from repro.frontends.dataflow import count_, sum_
from repro.relational import tpch

tables = tpch.generate(sf=0.01, seed=0)
ctx = tpch.make_context(tables, pad_to=1024)

# low group cardinality: Q1's (returnflag, linestatus) — 6 groups
low = tpch.q1(ctx)
# high group cardinality: per-order grouping — ~#orders groups
high = (ctx.table("lineitem")
        .group_by("l_orderkey", max_groups=ctx.capacity("orders"))
        .agg(sum_("l_quantity").as_("qty"), count_().as_("n")))

for name, frame in [("q1 (low NDV)", low), ("per-order (high NDV)", high)]:
    res = cvm_compile(frame.program(), target="spmd", parallel=8,
                      catalog=ctx.catalog(), optimize="cost", cache=False)
    print(f"=== {name} ===")
    print(res.explain())
    print()
'''


def run_fig(module: str, timeout: int = 1800) -> str:
    env = subprocess_env(ROOT, extra_pythonpath=[ROOT])
    proc = subprocess.run([sys.executable, "-m", module], capture_output=True,
                          text=True, timeout=timeout, env=env, cwd=str(ROOT))
    if proc.returncode != 0:
        return f"{module},ERROR,{proc.stderr.strip().splitlines()[-1] if proc.stderr else 'unknown'}"
    return proc.stdout.strip()


def run_explain(timeout: int = 1800) -> str:
    env = subprocess_env(ROOT, extra_pythonpath=[ROOT])
    proc = subprocess.run([sys.executable, "-c", EXPLAIN_SCRIPT],
                          capture_output=True, text=True, timeout=timeout,
                          env=env, cwd=str(ROOT))
    if proc.returncode != 0:
        return "explain ERROR: " + (proc.stderr.strip().splitlines()[-1]
                                    if proc.stderr else "unknown")
    return proc.stdout.strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--explain", action="store_true",
                    help="print the cost-model plan decisions instead of "
                         "running the figures")
    args = ap.parse_args()

    if args.explain:
        print(run_explain())
        return

    print("name,us_per_call,derived")
    for name, module in FIGS:
        if args.only and args.only not in name:
            continue
        out = run_fig(module)
        for line in out.splitlines():
            if line and "," in line:
                print(line)


if __name__ == "__main__":
    main()
