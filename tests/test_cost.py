"""Cost-based plan selection: statistics, cost search, store, pjit target.

Covers the optimizer subsystem's contracts:
  * table statistics propagate through rewritten programs — estimates
    survive ``Parallelize``, ``FuseSelectAgg``, and ``LowerToMesh``;
  * the plan-cache key covers the statistics (and therefore the chosen
    strategy): changed stats can never serve a stale plan;
  * ``optimize="cost"`` on the spmd target picks exchange-by-key at high
    group cardinality and gather-then-aggregate at low cardinality, both
    plans agree with the interp oracle, and ``explain()`` shows the
    decision (subprocess: spmd owns an 8-device host platform);
  * plan metadata persists to the on-disk store and a fresh process-alike
    (new cache, same store) re-plans from the stored strategy;
  * the tensor frontend's pjit binding is a registered target.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.compiler import (
    PlanCache,
    PlanStore,
    Statistics,
    TableStats,
    compile as cvm_compile,
    estimate_cost,
    get_target,
    propagate,
)
from repro.core.expr import col
from repro.core.passes import FuseSelectAgg, LowerToMesh, Parallelize
from repro.core.passes.lower_vec import Catalog, LowerRelToVec
from repro.frontends.dataflow import Context, count_, sum_
from repro.launch.hermetic import subprocess_env

ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture()
def sales_ctx():
    rng = np.random.default_rng(3)
    n = 4096
    ctx = Context(pad_to=512)
    ctx.register("sales", {
        "k": rng.integers(0, 1024, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    return ctx


def grouped_query(ctx, max_groups=1024):
    return (ctx.table("sales")
            .group_by("k", max_groups=max_groups)
            .agg(sum_("amount").as_("rev"), count_().as_("n")))


def scalar_query(ctx):
    return (ctx.table("sales")
            .filter(col("year") >= 2020)
            .agg(sum_("amount").as_("rev")))


# ---------------------------------------------------------------------------
# statistics propagation
# ---------------------------------------------------------------------------


class TestStatsPropagation:
    def test_context_statistics_are_exact(self, sales_ctx):
        ts = sales_ctx.statistics().table("sales")
        assert ts.rows == 4096
        assert 900 < ts.ndv_of("k") <= 1024  # exact distinct count of the draw
        assert ts.ndv_of("year") == 8
        assert ts.bytes_per_row == 12.0  # i32 + f32 + i32
        assert sales_ctx.catalog().stats is sales_ctx.statistics()

    def test_stats_survive_parallelize_and_lowering(self, sales_ctx):
        stats = sales_ctx.statistics()
        catalog = sales_ctx.catalog()
        ndv_k = stats.table("sales").ndv_of("k")
        program = grouped_query(sales_ctx).program()

        program = Parallelize(n=4).apply(program)
        env = propagate(program, stats)
        # the final (recombine) grouped aggregation still estimates from the
        # base-table NDV, through Split/ConcurrentExecute/Merge
        final = env.get(program, program.results[0])
        assert final.rows == pytest.approx(ndv_k, rel=0.01)

        program = LowerRelToVec(catalog).apply(program)
        env = propagate(program, stats)
        final = env.get(program, program.results[0])
        assert final.rows == pytest.approx(ndv_k, rel=0.01)

        program = LowerToMesh("workers").apply(program)
        env = propagate(program, stats)
        final = env.get(program, program.results[0])
        assert final.rows == pytest.approx(ndv_k, rel=0.01)
        assert "mesh.MeshExecute" in program.opcodes()

    def test_stats_survive_fusion(self, sales_ctx):
        stats = sales_ctx.statistics()
        program = scalar_query(sales_ctx).program()
        program = LowerRelToVec(sales_ctx.catalog()).apply(program)
        program = FuseSelectAgg().apply(program)
        assert "vec.FusedSelectAgg" in program.opcodes()
        env = propagate(program, stats)
        final = env.get(program, program.results[0])
        assert final.rows == 1.0  # scalar aggregate

    def test_cost_scales_with_stats(self, sales_ctx):
        program = LowerRelToVec(sales_ctx.catalog()).apply(
            grouped_query(sales_ctx).program())
        small = Statistics.make({"sales": TableStats.make(512, 12.0, {"k": 4})})
        big = Statistics.make(
            {"sales": TableStats.make(1 << 20, 12.0, {"k": 1 << 16})})
        assert estimate_cost(program, big) > estimate_cost(program, small)


# ---------------------------------------------------------------------------
# cost-keyed plan cache
# ---------------------------------------------------------------------------


class TestCostKeyedCache:
    def test_different_stats_never_hit_stale_plan(self, sales_ctx):
        cache = PlanCache()
        q = grouped_query(sales_ctx)
        program = q.program()
        caps = {"sales": sales_ctx.capacity("sales")}
        lo = Catalog(capacities=caps, stats=Statistics.make(
            {"sales": TableStats.make(4096, 12.0, {"k": 4})}))
        hi = Catalog(capacities=caps, stats=Statistics.make(
            {"sales": TableStats.make(4096, 12.0, {"k": 4096})}))

        r1 = cvm_compile(program, target="local", parallel=4, catalog=lo,
                         optimize="cost", cache=cache)
        r2 = cvm_compile(program, target="local", parallel=4, catalog=hi,
                         optimize="cost", cache=cache)
        r3 = cvm_compile(program, target="local", parallel=4, catalog=lo,
                         optimize="cost", cache=cache)
        assert not r1.cache_hit
        assert not r2.cache_hit  # changed stats → different key → re-planned
        assert r3.cache_hit      # same stats → same plan served

    def test_forced_strategy_is_part_of_the_key(self, sales_ctx):
        cache = PlanCache()
        q = scalar_query(sales_ctx)
        r1 = sales_ctx.compile(q, cache=cache, strategy={"fuse": "fused"})
        r2 = sales_ctx.compile(q, cache=cache, strategy={"fuse": "unfused"})
        assert not r2.cache_hit
        assert dict(r1.strategy)["fuse"] == "fused"
        assert dict(r2.strategy)["fuse"] == "unfused"
        assert "vec.FusedSelectAgg" in r1.program.opcodes()
        assert "vec.FusedSelectAgg" not in r2.program.opcodes()

    def test_unknown_strategy_rejected(self, sales_ctx):
        q = scalar_query(sales_ctx)
        with pytest.raises(ValueError, match="no strategy choice"):
            sales_ctx.compile(q, strategy={"grouped_recombine": "exchange"})
        with pytest.raises(ValueError, match="no variant"):
            sales_ctx.compile(q, strategy={"fuse": "mega"})
        with pytest.raises(ValueError, match="mapping"):
            sales_ctx.compile(q, strategy="fused")

    def test_cost_mode_prefers_fusion(self, sales_ctx):
        res = sales_ctx.compile(scalar_query(sales_ctx), optimize="cost",
                                cache=PlanCache())
        assert dict(res.strategy)["fuse"] == "fused"
        assert res.decision is not None
        assert res.decision.source == "search"
        labels = [c.label() for c in res.decision.candidates]
        assert any("unfused" in l for l in labels)
        assert "cost search" in res.explain()


# ---------------------------------------------------------------------------
# plan-store persistence
# ---------------------------------------------------------------------------


class TestPlanStore:
    def test_replan_from_store_skips_search(self, sales_ctx, tmp_path):
        store = PlanStore(tmp_path / "plans")
        q = grouped_query(sales_ctx)
        program = q.program()
        kw = dict(target="local", parallel=4, catalog=sales_ctx.catalog(),
                  optimize="cost", store=store)

        r1 = cvm_compile(program, cache=PlanCache(), **kw)
        assert r1.decision.source == "search"
        assert len(store) == 1

        # "restart": fresh in-memory cache, same store directory
        r2 = cvm_compile(program, cache=PlanCache(), **kw)
        assert not r2.cache_hit
        assert r2.decision.source == "store"
        assert r2.strategy == r1.strategy

    def test_store_record_contents(self, sales_ctx, tmp_path):
        store = PlanStore(tmp_path / "plans")
        cvm_compile(grouped_query(sales_ctx).program(), target="local",
                    parallel=4, catalog=sales_ctx.catalog(), optimize="cost",
                    cache=PlanCache(), store=store)
        (rec_path,) = [p for p in Path(store.root).glob("*.json")
                       if p.name != "calibration.json"]
        rec = json.loads(rec_path.read_text())
        assert rec["target"] == "local"
        assert rec["fingerprint"]
        assert dict(rec["strategy"])  # the chosen strategy is recorded
        assert rec["records"]         # pass records (PassRecord history)
        calib = store.load_calibration()
        assert calib.n >= 1 and calib.scale > 0

    def test_corrupt_record_is_ignored(self, sales_ctx, tmp_path):
        store = PlanStore(tmp_path / "plans")
        q = grouped_query(sales_ctx).program()
        kw = dict(target="local", parallel=4, catalog=sales_ctx.catalog(),
                  optimize="cost", store=store)
        cvm_compile(q, cache=PlanCache(), **kw)
        for p in Path(store.root).glob("*.json"):
            p.write_text("{corrupt")
        r = cvm_compile(q, cache=PlanCache(), **kw)
        assert r.decision.source == "search"  # fell back to a fresh search


# ---------------------------------------------------------------------------
# pjit target
# ---------------------------------------------------------------------------


class TestPjitTarget:
    def test_registered(self):
        tgt = get_target("pjit")
        assert tgt.flavors == ("tz", "cf", "mesh")
        assert [s.name for s in tgt.lowering_path] == ["canonicalize",
                                                       "parallelize"]

    def test_plan_only_compile_via_driver(self):
        from repro.core import Builder
        from repro.core.ops.tensor import register_pipeline
        from repro.core.types import F32, Single, TupleType
        from repro.frontends.tensor import pytree_type

        register_pipeline("grad_cost_test", None, overwrite=True)
        b = Builder("train_cost_test")
        params = b.input("params", pytree_type("params"))
        opt_state = b.input("opt", pytree_type("opt_state"))
        batch = b.input("batch", pytree_type("batch"))
        grads, loss = b.emit(
            "tz.Pipeline", [batch, params],
            {"fn": "grad_cost_test",
             "out_types": (pytree_type("grads"),
                           Single(TupleType.of(loss=F32)))})
        new_params, new_opt = b.emit(
            "tz.OptUpdate", [params, opt_state, grads], {"opt": "adamw"})
        program = b.finish(new_params, new_opt, loss)

        res = cvm_compile(program, target="pjit", parallel=4,
                          parallelize_targets=[batch.name], cache=False,
                          store=False)
        assert "cf.ConcurrentExecute" in res.program.opcodes()
        assert res.executable.summary["n_workers"] == 4
        with pytest.raises(RuntimeError, match="plan-only"):
            res.executable()


# ---------------------------------------------------------------------------
# the acceptance scenario: spmd cost-based choice (own device fleet)
# ---------------------------------------------------------------------------

SPMD_COST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np

    from repro.compiler import (PlanCache, Statistics, TableStats,
                                compile as cvm_compile)
    from repro.core.passes.lower_vec import Catalog
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(5)
    n = 8192
    ctx = Context(pad_to=1024)
    ctx.register("sales", {
        "k": rng.integers(0, 2048, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
    })
    caps = {"sales": ctx.capacity("sales")}

    def query(max_groups):
        return (ctx.table("sales").group_by("k", max_groups=max_groups)
                .agg(sum_("amount").as_("rev"), count_().as_("n")))

    out = {}

    # synthetic stats: high key cardinality -> exchange must win
    hi = Catalog(capacities=caps, stats=Statistics.make(
        {"sales": TableStats.make(8192, 8.0, {"k": 2048})}))
    res_hi = cvm_compile(query(2048).program(), target="spmd", parallel=8,
                         catalog=hi, optimize="cost", cache=False)
    out["hi_strategy"] = dict(res_hi.strategy)
    out["hi_mesh_ops"] = [o for o in res_hi.program.opcodes()
                          if o.startswith("mesh.")]
    out["hi_explain"] = res_hi.explain()

    # synthetic stats: low key cardinality -> gather must win
    lo = Catalog(capacities=caps, stats=Statistics.make(
        {"sales": TableStats.make(8192, 8.0, {"k": 4})}))
    res_lo = cvm_compile(query(8).program(), target="spmd", parallel=8,
                         catalog=lo, optimize="cost", cache=False)
    out["lo_strategy"] = dict(res_lo.strategy)
    out["lo_mesh_ops"] = [o for o in res_lo.program.opcodes()
                          if o.startswith("mesh.")]

    # both physical plans agree with the interp oracle
    want = ctx.execute(query(2048), target="interp")
    o_w = np.argsort(np.asarray(want["k"]).ravel())
    for label in ("gather", "exchange"):
        res = cvm_compile(query(2048).program(), target="spmd", parallel=8,
                          catalog=hi, strategy={"grouped-recombine": label},
                          cache=False)
        (got_t,) = res(ctx.sources())
        got = got_t.to_numpy()
        o_g = np.argsort(got["k"])
        np.testing.assert_allclose(
            got["rev"][o_g], np.asarray(want["rev"]).ravel()[o_w], rtol=1e-4)
        np.testing.assert_array_equal(
            got["n"][o_g], np.asarray(want["n"]).ravel()[o_w])
        out[label + "_ok"] = True
        out[label + "_mesh_ops"] = [o for o in res.program.opcodes()
                                    if o.startswith("mesh.")]
    print("RESULTS" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmd_cost_results():
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_COST_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


class TestSpmdCostChoice:
    def test_high_cardinality_selects_exchange(self, spmd_cost_results):
        r = spmd_cost_results
        assert r["hi_strategy"]["grouped-recombine"] == "exchange"
        assert "mesh.ExchangeByKey" in r["hi_mesh_ops"]

    def test_low_cardinality_selects_gather(self, spmd_cost_results):
        r = spmd_cost_results
        assert r["lo_strategy"]["grouped-recombine"] == "gather"
        assert "mesh.ExchangeByKey" not in r["lo_mesh_ops"]

    def test_both_plans_match_interp(self, spmd_cost_results):
        assert spmd_cost_results["gather_ok"]
        assert spmd_cost_results["exchange_ok"]
        # the exchange plan really recombines inside the mesh, not by gather
        assert "mesh.ExchangeByKey" in spmd_cost_results["exchange_mesh_ops"]

    def test_explain_shows_candidates_and_decision(self, spmd_cost_results):
        text = spmd_cost_results["hi_explain"]
        assert "cost search" in text
        assert "grouped-recombine=gather" in text
        assert "grouped-recombine=exchange" in text
        assert "winner" in text


# ---------------------------------------------------------------------------
# predicate-aware selectivity: estimates track the predicate, not 0.5
# ---------------------------------------------------------------------------


class TestPredicateSelectivity:
    """``selectivity_of`` replaces the flat DEFAULT_SELECTIVITY=0.5 with
    min/max pruning against the catalog domains — the estimated select
    cardinality now tracks the predicate, and the estimate-vs-actual miss
    reported by ``explain()`` shrinks accordingly."""

    def test_range_predicate_estimate_tracks_domain(self, sales_ctx):
        # year is uniform over [2018, 2025]: `year >= 2019` keeps 7/8 of
        # the rows — far from the flat 0.5 a default guess would give
        q = (sales_ctx.table("sales").filter(col("year") >= 2019)
             .agg(sum_("amount").as_("rev")))
        program = LowerRelToVec(sales_ctx.catalog()).apply(q.program())
        env = propagate(program, sales_ctx.statistics())
        sel = next(i for i in program.body
                   if i.opcode == "vec.MaskSelect")
        est = env.get(program, sel.outputs[0]).rows
        assert est == pytest.approx(4096 * 7 / 8, rel=0.02)

    def test_out_of_domain_predicate_estimates_empty(self, sales_ctx):
        q = (sales_ctx.table("sales").filter(col("year") >= 2030)
             .agg(sum_("amount").as_("rev")))
        program = LowerRelToVec(sales_ctx.catalog()).apply(q.program())
        env = propagate(program, sales_ctx.statistics())
        sel = next(i for i in program.body
                   if i.opcode == "vec.MaskSelect")
        # min/max pruning drives the selectivity to 0; RegStats.scaled
        # floors the estimate at one row so downstream terms never divide
        # by zero
        assert env.get(program, sel.outputs[0]).rows == 1.0

    def test_explain_miss_shrinks_vs_default_guess(self, sales_ctx):
        from repro.obs import tracing

        q = (sales_ctx.table("sales").filter(col("year") >= 2019)
             .group_by("k", max_groups=1024)
             .agg(sum_("amount").as_("rev"), count_().as_("n")))
        with tracing():
            res = sales_ctx.compile(q, target="local",
                                    strategy={"fuse": "unfused"},
                                    cache=PlanCache())
            res(sales_ctx.sources())
        obs = next(o for o in res.profile.observations
                   if o.opcode == "vec.MaskSelect")
        # the flat 0.5 guess would miss by ~75% here; the domain-pruned
        # estimate lands within a few percent of the measured rows
        flat_miss = abs(obs.rows_out - 0.5 * 4096) / (0.5 * 4096)
        assert flat_miss > 0.5
        assert abs(obs.rel_miss) < 0.1
        # and the decision surface shows the same numbers
        assert "est rows" in res.explain()
        assert "actual rows" in res.explain()
