"""Chaos suite: fault injection, fallback chains, poison plans, admission,
and load shedding.

Every test arms a seeded fault at one registered injection point
(``repro.robust.inject``) and asserts the stack *degrades instead of
failing*: relational queries land on interp-oracle-correct results through
the fallback ladder (with a loud ``DegradedWarning``), crashed plans are
poisoned in the store so they are never replayed, over-budget plans are
degraded or rejected before the backend allocates, and the serve loop sheds
load under slow-step injection without deadlocking.

``REPRO_CHAOS_SEED`` selects the injection seed (CI runs two); setting
``REPRO_CHAOS_TRACE_DIR`` writes one Chrome trace per test for artifact
upload.
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import textwrap
import time
import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.compiler import PlanCache, compile as cvm_compile
from repro.compiler.store import PlanStore
from repro.core.expr import col
from repro.frontends.dataflow import Context, count_, sum_, _to_numpy
from repro.launch.hermetic import subprocess_env
from repro.launch.serve import AdmissionQueue, Request, serve_loop
from repro.obs import DegradedWarning, tracing, write_chrome_trace
from repro.robust.admission import (AdmissionError, admit,
                                    estimate_peak_bytes)
from repro.robust.fallback import SAFE_VARIANTS, fallback_ladder
from repro.robust.inject import (FaultRule, InjectedFault, inject,
                                 maybe_inject, registered_points)
from repro.robust.retry import (Deadline, RetryPolicy, StragglerDetector,
                                call_with_retry)

ROOT = Path(__file__).resolve().parents[1]

#: the chaos seed CI sweeps (two fixed values); every armed rule uses it
CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))


@pytest.fixture(autouse=True)
def _chaos_trace(request):
    """Per-test Chrome trace when ``REPRO_CHAOS_TRACE_DIR`` is set (the CI
    chaos lane uploads these as artifacts)."""
    trace_dir = os.environ.get("REPRO_CHAOS_TRACE_DIR")
    if not trace_dir:
        yield
        return
    with tracing() as tr:
        yield
    out = Path(trace_dir)
    out.mkdir(parents=True, exist_ok=True)
    name = re.sub(r"[^\w.-]+", "_", request.node.name)
    write_chrome_trace(str(out / f"{name}.json"), tr)


def make_sales_ctx() -> Context:
    rng = np.random.default_rng(7)
    n = 2048
    ctx = Context(pad_to=256)
    ctx.register("sales", {
        "region": rng.integers(0, 6, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    return ctx


def sales_query(ctx: Context):
    return (ctx.table("sales")
            .filter(col("year") >= 2020)
            .group_by("region", max_groups=8)
            .agg(sum_("amount").as_("rev"), count_().as_("n")))


def run_compiled(ctx: Context, result) -> dict:
    (out,) = result(ctx.sources())
    return _to_numpy(out)


def assert_matches_oracle(got: dict, oracle: dict) -> None:
    assert set(got) == set(oracle)
    order_got = np.argsort(np.asarray(got["region"]).ravel())
    order_want = np.argsort(np.asarray(oracle["region"]).ravel())
    for k in oracle:
        np.testing.assert_allclose(
            np.asarray(got[k]).ravel()[order_got],
            np.asarray(oracle[k]).ravel()[order_want], rtol=1e-4)


@pytest.fixture()
def sales():
    ctx = make_sales_ctx()
    oracle = ctx.execute(sales_query(ctx), target="interp")
    return ctx, oracle


# ---------------------------------------------------------------------------
# the injection registry itself
# ---------------------------------------------------------------------------


class TestInjectionRegistry:
    def test_catalog_covers_the_stack(self):
        points = registered_points()
        for name in ["driver.pass", "store.load", "store.save",
                     "backend.compile", "backend.execute", "spmd.shard",
                     "serve.step", "stream.batch", "stream.snapshot",
                     "stream.restore"]:
            assert name in points, sorted(points)

    def test_unknown_point_and_mode_rejected(self):
        with pytest.raises(KeyError, match="unknown injection point"):
            with inject("no.such.point"):
                pass
        with pytest.raises(ValueError, match="modes"):
            with inject("backend.compile", mode="corrupt"):
                pass

    def test_unarmed_site_is_passthrough(self):
        payload = object()
        assert maybe_inject("backend.execute", payload) is payload

    def test_firing_sequence_replays_for_a_seed(self):
        def sequence(seed):
            fired = []
            with inject("backend.execute", rate=0.5, times=None, seed=seed):
                for i in range(32):
                    try:
                        maybe_inject("backend.execute")
                        fired.append(False)
                    except InjectedFault:
                        fired.append(True)
            return fired

        assert sequence(CHAOS_SEED) == sequence(CHAOS_SEED)
        assert any(sequence(CHAOS_SEED))
        assert not all(sequence(CHAOS_SEED))

    def test_times_bounds_firings(self):
        with inject("backend.execute", times=2) as rule:
            for _ in range(2):
                with pytest.raises(InjectedFault):
                    maybe_inject("backend.execute")
            maybe_inject("backend.execute")  # budget spent: no fire
        assert rule.fired == 2

    def test_corrupt_without_corruptor_degenerates_to_raise(self):
        with inject("driver.pass", mode="corrupt"):
            with pytest.raises(InjectedFault):
                maybe_inject("driver.pass", "payload")


# ---------------------------------------------------------------------------
# fallback chain: every fault lands on oracle-correct results, loudly
# ---------------------------------------------------------------------------


class TestFallbackChain:
    @pytest.mark.parametrize("point,mode", [
        ("driver.pass", "raise"),
        ("driver.pass", "corrupt"),
        ("backend.compile", "raise"),
        ("backend.execute", "raise"),
    ])
    def test_fault_degrades_to_oracle_correct(self, sales, point, mode):
        ctx, oracle = sales
        with tracing() as tr:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                with inject(point, mode=mode, times=1, seed=CHAOS_SEED):
                    result = ctx.compile(sales_query(ctx), target="local",
                                         cache=PlanCache())
                    got = run_compiled(ctx, result)
        assert_matches_oracle(got, oracle)
        degraded = [w for w in caught
                    if issubclass(w.category, DegradedWarning)]
        assert degraded, "fallback must be loud, not silent"
        assert result.degraded, result.explain()
        assert "DEGRADED" in result.explain()
        assert tr.counters.get("robust.fallback.step", 0) >= 1
        assert tr.counters.get("robust.fallback.recovered", 0) >= 1
        assert tr.counters.get(f"robust.inject.{point}", 0) >= 1

    def test_exec_guard_disarms_after_recovery(self, sales):
        ctx, oracle = sales
        with inject("backend.execute", times=1, seed=CHAOS_SEED):
            result = ctx.compile(sales_query(ctx), target="local",
                                 cache=PlanCache())
            run_compiled(ctx, result)
        # the surviving plan is spliced in: the second call must dispatch
        # straight to it, without warnings or further ladder walks
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            got = run_compiled(ctx, result)
        assert_matches_oracle(got, oracle)
        assert not [w for w in caught
                    if issubclass(w.category, DegradedWarning)]

    def test_metrics_carry_degradation(self, sales):
        ctx, _ = sales
        with inject("backend.compile", times=1, seed=CHAOS_SEED):
            result = ctx.compile(sales_query(ctx), target="local",
                                 cache=PlanCache())
        assert result.metrics()["degraded"] == list(result.degraded)

    def test_guard_off_raises(self, sales):
        ctx, _ = sales
        with inject("backend.compile", times=1, seed=CHAOS_SEED):
            with pytest.raises(InjectedFault):
                ctx.compile(sales_query(ctx), target="local",
                            cache=PlanCache(), guard=False)

    def test_invalid_inputs_still_raise_under_guard(self, sales):
        """The guard protects against *plan* failures, not caller bugs."""
        ctx, _ = sales
        with pytest.raises(ValueError, match="sales"):
            ctx.compile(sales_query(ctx), parallel=3, cache=PlanCache())

    def test_ladder_shape(self):
        chosen = {"groupby": "direct", "fuse": "fused",
                  "grouped-recombine": "exchange"}
        rungs = list(fallback_ladder(chosen))
        assert [r for r, _ in rungs] == [
            "groupby=sorted", "fuse=unfused", "grouped-recombine=gather",
            "interp"]
        # already-safe choices are skipped, never retried
        assert list(fallback_ladder({"groupby": "sorted"},
                                    choice_names={"groupby"})) \
            == [("interp", None)]


# ---------------------------------------------------------------------------
# poison plans: a crashed plan is never reloaded and re-crashed
# ---------------------------------------------------------------------------


class TestPoisonPlans:
    def test_poison_prevents_second_crash_from_cache(self, sales, tmp_path):
        ctx, oracle = sales
        store = PlanStore(tmp_path)
        q = sales_query(ctx)

        with inject("backend.execute", times=1, seed=CHAOS_SEED):
            first = ctx.compile(q, target="local", cache=PlanCache(),
                                store=store)
            got = run_compiled(ctx, first)  # crashes once, guard recovers
        assert_matches_oracle(got, oracle)
        assert first.degraded

        # the crashed strategy is on the store's poison list
        records = [p for p in tmp_path.glob("*.json")
                   if p.name != "calibration.json"]
        assert records, "plan record must persist"
        poisons = [json.loads(p.read_text()).get("poison") or []
                   for p in records]
        assert any(poisons), "crashed strategy must be poisoned"

        # a fresh process (fresh memory cache, same store) must not walk
        # into the same crash: the poisoned strategy is skipped up front
        with tracing() as tr:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                second = ctx.compile(q, target="local", cache=PlanCache(),
                                     store=store)
                got = run_compiled(ctx, second)
        assert_matches_oracle(got, oracle)
        assert second.degraded
        assert tr.counters.get("robust.fallback.poison_skip", 0) >= 1
        assert [w for w in caught
                if issubclass(w.category, DegradedWarning)]

    def test_poisoned_strategies_roundtrip(self, tmp_path):
        store = PlanStore(tmp_path)
        store.mark_poison("k1", (("fuse", "fused"), ("groupby", "sorted")),
                          reason="execute: boom")
        record = store._read_raw(store._plan_path("k1"))
        got = PlanStore.poisoned_strategies(record)
        assert (("fuse", "fused"), ("groupby", "sorted")) in got
        # idempotent: marking again does not duplicate
        store.mark_poison("k1", (("groupby", "sorted"), ("fuse", "fused")),
                          reason="again")
        record = store._read_raw(store._plan_path("k1"))
        assert len(record["poison"]) == 1


# ---------------------------------------------------------------------------
# plan-store chaos: retries, quarantine, non-fatal writes
# ---------------------------------------------------------------------------


class TestStoreChaos:
    def _record_paths(self, root: Path):
        return [p for p in root.glob("*.json") if p.name != "calibration.json"]

    def test_load_fault_degrades_to_miss(self, sales, tmp_path):
        ctx, oracle = sales
        store = PlanStore(tmp_path)
        q = sales_query(ctx)
        ctx.compile(q, target="local", cache=PlanCache(), store=store)
        (record,) = self._record_paths(tmp_path)

        with tracing() as tr:
            with inject("store.load", mode="raise", times=1,
                        seed=CHAOS_SEED):
                result = ctx.compile(q, target="local", cache=PlanCache(),
                                     store=store)
        got = run_compiled(ctx, result)
        assert_matches_oracle(got, oracle)
        assert tr.counters.get("plan_store.corrupt", 0) >= 1
        # a transient read failure must NOT quarantine the good bytes
        assert record.exists()
        assert json.loads(record.read_text())

    def test_injected_corruption_quarantines(self, sales, tmp_path):
        ctx, _ = sales
        store = PlanStore(tmp_path)
        q = sales_query(ctx)
        ctx.compile(q, target="local", cache=PlanCache(), store=store)
        (record,) = self._record_paths(tmp_path)

        with tracing() as tr:
            with inject("store.load", mode="corrupt", times=1,
                        seed=CHAOS_SEED):
                ctx.compile(q, target="local", cache=PlanCache(), store=store)
        assert tr.counters.get("plan_store.quarantined", 0) == 1
        assert record.with_suffix(".corrupt").exists()
        # the compile that hit the corruption re-planned and re-saved a
        # fresh, parseable record in its place
        assert json.loads(record.read_text())

    def test_on_disk_corruption_quarantined_once(self, sales, tmp_path):
        """Real torn-write corruption: first load renames the bytes aside,
        every later load is a clean miss — no repeated crash, no repeated
        warning on the same corruption."""
        ctx, oracle = sales
        store = PlanStore(tmp_path)
        q = sales_query(ctx)
        ctx.compile(q, target="local", cache=PlanCache(), store=store)
        (record,) = self._record_paths(tmp_path)
        record.write_text("{\"target\": \"local\", \"strate")  # torn write

        with tracing() as tr:
            r2 = ctx.compile(q, target="local", cache=PlanCache(),
                             store=store)
            got = run_compiled(ctx, r2)
        assert_matches_oracle(got, oracle)
        assert tr.counters.get("plan_store.quarantined", 0) == 1
        assert record.with_suffix(".corrupt").exists()

        with tracing() as tr2:
            ctx.compile(q, target="local", cache=PlanCache(), store=store)
        assert tr2.counters.get("plan_store.quarantined", 0) == 0

    def test_save_fault_is_nonfatal(self, sales, tmp_path):
        ctx, oracle = sales
        store = PlanStore(tmp_path)
        with tracing() as tr:
            with inject("store.save", mode="raise", times=1,
                        seed=CHAOS_SEED):
                result = ctx.compile(sales_query(ctx), target="local",
                                     cache=PlanCache(), store=store)
        got = run_compiled(ctx, result)
        assert_matches_oracle(got, oracle)
        assert tr.counters.get("plan_store.save_failed", 0) >= 1
        assert not result.degraded  # persistence loss is not degradation


# ---------------------------------------------------------------------------
# resource admission
# ---------------------------------------------------------------------------


def make_big_domain_ctx() -> Context:
    """A grouping key with a ~200k-wide domain: the dense-bucket direct
    strategy allocates megabytes of scratch; the sorted tier does not."""
    rng = np.random.default_rng(CHAOS_SEED + 11)
    n = 4096
    ctx = Context(pad_to=512)
    ctx.register("events", {
        "user": rng.integers(0, 200_000, n).astype(np.int32),
        "val": rng.gamma(2.0, 10.0, n).astype(np.float32),
    })
    return ctx


def events_query(ctx: Context):
    return (ctx.table("events")
            .group_by("user", max_groups=4096)
            .agg(sum_("val").as_("total")))


class TestAdmission:
    BUDGET = 1_000_000

    def test_direct_estimate_dwarfs_sorted(self):
        ctx = make_big_domain_ctx()
        q = events_query(ctx)
        direct = ctx.compile(q, target="local", cache=False,
                             strategy={"groupby": "direct"}, guard=False)
        sorted_ = ctx.compile(q, target="local", cache=False,
                              strategy={"groupby": "sorted"}, guard=False)
        est_direct = estimate_peak_bytes(direct.program)
        est_sorted = estimate_peak_bytes(sorted_.program)
        assert est_direct.peak_site == "vec.GroupAggDirect"
        assert est_direct.peak_bytes > self.BUDGET
        assert est_sorted.peak_bytes < self.BUDGET
        assert "peak ≈" in est_direct.render()

    def test_over_budget_rejected_without_guard(self):
        ctx = make_big_domain_ctx()
        with pytest.raises(AdmissionError, match="resource admission"):
            ctx.compile(events_query(ctx), target="local", cache=False,
                        strategy={"groupby": "direct"},
                        memory_budget=self.BUDGET, guard=False)

    def test_over_budget_degrades_to_sorted_with_guard(self):
        ctx = make_big_domain_ctx()
        oracle = ctx.execute(events_query(ctx), target="interp")
        with tracing() as tr:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                result = ctx.compile(events_query(ctx), target="local",
                                     cache=PlanCache(),
                                     strategy={"groupby": "direct"},
                                     memory_budget=self.BUDGET)
        assert ("groupby", "sorted") in result.strategy
        assert result.degraded
        assert result.resources is not None
        assert result.resources.peak_bytes <= self.BUDGET
        assert tr.counters.get("robust.admission.reject", 0) >= 1
        assert [w for w in caught
                if issubclass(w.category, DegradedWarning)]
        got = run_compiled(ctx, result)
        order_g = np.argsort(np.asarray(got["user"]).ravel())
        order_w = np.argsort(np.asarray(oracle["user"]).ravel())
        for k in oracle:
            np.testing.assert_allclose(
                np.asarray(got[k]).ravel()[order_g],
                np.asarray(oracle[k]).ravel()[order_w], rtol=1e-4)

    def test_oversized_domain_downgrade_is_loud(self):
        """A forced ``groupby=direct`` whose key domain exceeds the bucket
        cap silently lowered to sorted before; now it warns with the
        offending domain size (``lower_vec.direct_unavailable``)."""
        rng = np.random.default_rng(CHAOS_SEED + 13)
        n = 1024
        ctx = Context(pad_to=256)
        ctx.register("wide", {
            # domain width ≫ MAX_DIRECT_BUCKETS (1<<20)
            "k": rng.integers(0, 50_000_000, n).astype(np.int64),
            "v": rng.gamma(2.0, 10.0, n).astype(np.float32),
        })
        q = (ctx.table("wide").group_by("k", max_groups=1024)
             .agg(sum_("v").as_("total")))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            result = ctx.compile(q, target="local", cache=False,
                                 strategy={"groupby": "direct"})
        msgs = [str(w.message) for w in caught
                if "lower_vec.direct_unavailable" in str(w.message)]
        assert msgs, [str(w.message) for w in caught]
        assert "k" in msgs[0] and "too large" in msgs[0]
        assert "vec.GroupAggSorted" in result.program.opcodes()
        assert "vec.GroupAggDirect" not in result.program.opcodes()

    def test_within_budget_admitted_with_provenance(self, sales):
        ctx, _ = sales
        result = ctx.compile(sales_query(ctx), target="local",
                             cache=PlanCache(),
                             memory_budget=1 << 30)
        assert not result.degraded
        assert result.resources is not None
        assert result.metrics()["resources"]["peak_bytes"] \
            == result.resources.peak_bytes


# ---------------------------------------------------------------------------
# retry / straggler / deadline primitives
# ---------------------------------------------------------------------------


class TestRetryPrimitives:
    def test_retry_recovers_and_bounds(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise OSError("transient")
            return "ok"

        policy = RetryPolicy(max_retries=3, backoff_s=0.0)
        assert call_with_retry(flaky, policy, name="t",
                               sleep=lambda s: None) == "ok"
        assert calls["n"] == 3

        def always():
            raise OSError("permanent")

        with pytest.raises(OSError, match="permanent"):
            call_with_retry(always, RetryPolicy(max_retries=1, backoff_s=0.0),
                            name="t", sleep=lambda s: None)

    def test_retry_ignores_unlisted_exceptions(self):
        policy = RetryPolicy(max_retries=5, retry_on=(OSError,))
        calls = {"n": 0}

        def typed():
            calls["n"] += 1
            raise ValueError("not retryable")

        with pytest.raises(ValueError):
            call_with_retry(typed, policy, name="t", sleep=lambda s: None)
        assert calls["n"] == 1

    def test_backoff_is_exponential_and_capped(self):
        p = RetryPolicy(backoff_s=0.1, backoff_factor=2.0, max_backoff_s=0.3)
        assert p.backoff(0) == pytest.approx(0.1)
        assert p.backoff(1) == pytest.approx(0.2)
        assert p.backoff(5) == pytest.approx(0.3)

    def test_straggler_detector(self):
        det = StragglerDetector(factor=3.0, alpha=0.5)
        assert det.observe(1.0) is False  # first observation seeds
        assert det.observe(1.0) is False
        assert det.observe(10.0) is True
        assert det.stragglers == 1
        # the slow step raised the bar: 2.0 is no longer 3× the EWMA
        assert det.observe(2.0) is False

    def test_deadline(self):
        d = Deadline.after(100.0, clock=lambda: 0.0)
        assert d.remaining(clock=lambda: 40.0) == pytest.approx(60.0)
        assert not d.expired(clock=lambda: 99.0)
        assert d.expired(clock=lambda: 100.0)


# ---------------------------------------------------------------------------
# serve: bounded queue + deadline-aware load shedding
# ---------------------------------------------------------------------------


def _echo_wave(wave):
    return {r.rid: r.prompt for r in wave}


class TestServeShedding:
    def test_no_faults_serves_everything(self):
        reqs = [Request(rid=i, prompt=i) for i in range(10)]
        out = serve_loop(reqs, _echo_wave, batch=4)
        assert out == {i: i for i in range(10)}

    def test_queue_cap_sheds_overflow(self):
        with tracing() as tr:
            reqs = [Request(rid=i, prompt=i) for i in range(10)]
            out = serve_loop(reqs, _echo_wave, batch=4, queue_cap=6)
        assert len(out) == 6
        assert tr.counters.get("serve.shed.queue_full", 0) == 4
        assert tr.counters.get("serve.shed", 0) == 4

    def test_slow_step_sheds_deadlines_without_deadlock(self):
        reqs = [Request(rid=i, prompt=i) for i in range(12)]

        def slow_wave(wave):
            time.sleep(0.01)
            return _echo_wave(wave)

        t0 = time.monotonic()
        with tracing() as tr:
            with inject("serve.step", mode="delay", delay_s=0.05,
                        times=None, seed=CHAOS_SEED):
                out = serve_loop(reqs, slow_wave, batch=4, deadline_s=0.08)
        wall = time.monotonic() - t0
        assert wall < 5.0, "shedding must terminate promptly"
        shed = 12 - len(out)
        assert shed > 0, "a saturated server must shed"
        assert tr.counters.get("serve.shed.deadline", 0) == shed
        # every request is accounted for: served or shed, never lost
        assert len(out) + shed == 12

    def test_failing_wave_sheds_after_bounded_retries(self):
        reqs = [Request(rid=i, prompt=i) for i in range(8)]
        with tracing() as tr:
            with inject("serve.step", mode="raise", times=None,
                        seed=CHAOS_SEED):
                out = serve_loop(reqs, _echo_wave, batch=4)
        assert out == {}
        assert tr.counters.get("serve.shed.error", 0) == 8
        assert tr.counters.get("robust.retry.serve.step", 0) >= 2

    def test_transient_wave_failure_is_retried_not_shed(self):
        reqs = [Request(rid=i, prompt=i) for i in range(4)]
        with inject("serve.step", mode="raise", times=1, seed=CHAOS_SEED):
            out = serve_loop(reqs, _echo_wave, batch=4)
        assert len(out) == 4

    def test_take_skips_expired(self):
        q = AdmissionQueue()
        q.offer(Request(rid=0, prompt=0, deadline=Deadline(at=-1.0)))
        q.offer(Request(rid=1, prompt=1))
        wave = q.take(4)
        assert [r.rid for r in wave] == [1]
        assert q.shed.deadline == 1


# ---------------------------------------------------------------------------
# spmd chaos (own device fleet: subprocess, like test_spmd_backend)
# ---------------------------------------------------------------------------


SPMD_CHAOS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import warnings
    import numpy as np

    from repro.compiler import PlanCache
    from repro.core.expr import col
    from repro.frontends.dataflow import Context, count_, sum_, _to_numpy
    from repro.obs import DegradedWarning
    from repro.robust.inject import inject

    seed = int(os.environ.get("REPRO_CHAOS_SEED", "0"))
    rng = np.random.default_rng(7)
    n = 2048
    ctx = Context(pad_to=256)
    ctx.register("sales", {
        "region": rng.integers(0, 6, n).astype(np.int32),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    q = (ctx.table("sales").filter(col("year") >= 2020)
         .group_by("region", max_groups=8)
         .agg(sum_("amount").as_("rev"), count_().as_("n")))

    oracle = ctx.execute(q, target="interp")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        with inject("spmd.shard", mode="raise", times=1, seed=seed):
            result = ctx.compile(q, target="spmd", parallel=2,
                                 cache=PlanCache())
            (out,) = result(ctx.sources())
    got = _to_numpy(out)
    o_g = np.argsort(np.asarray(got["region"]).ravel())
    o_w = np.argsort(np.asarray(oracle["region"]).ravel())
    ok = all(np.allclose(np.asarray(got[k]).ravel()[o_g],
                         np.asarray(oracle[k]).ravel()[o_w], rtol=1e-4)
             for k in oracle)
    print("RESULTS" + json.dumps({
        "ok": bool(ok),
        "degraded": list(result.degraded),
        "warned": sum(1 for w in caught
                      if issubclass(w.category, DegradedWarning)),
    }))
""")


def test_spmd_shard_fault_recovers_to_oracle():
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_CHAOS_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS")][0]
    got = json.loads(line[len("RESULTS"):])
    assert got["ok"], got
    assert got["degraded"], got
    assert got["warned"] >= 1, got


# ---------------------------------------------------------------------------
# the encode=raw rung: a crashing dict-encoded plan keeps its direct tier
# ---------------------------------------------------------------------------


class TestEncodeRawRung:
    def _sparse_ctx(self):
        rng = np.random.default_rng(23)
        n, ndv = 2048, 200
        domain = rng.integers(0, 1_400_000_000, ndv).astype(np.int32)
        ctx = Context(pad_to=256)
        ctx.register("t", {
            "k": domain[rng.integers(0, ndv, n)],
            "v": rng.normal(size=n).astype(np.float32),
        })
        return ctx

    def _query(self, ctx):
        return (ctx.table("t").group_by("k", max_groups=256)
                .agg(sum_("v").as_("s"), count_().as_("n")))

    def test_ladder_tries_encode_raw_first(self):
        chosen = {"groupby": "direct", "encode": "dict"}
        rungs = [r for r, _ in fallback_ladder(chosen)]
        assert rungs == ["encode=raw", "groupby=sorted", "interp"]
        # the first rung drops only the dictionary, not the direct tier
        first = dict(fallback_ladder(chosen).__next__()[1])
        assert first == {"groupby": "direct", "encode": "raw"}

    def test_crashed_dict_plan_degrades_through_encode_raw(self):
        ctx = self._sparse_ctx()
        q = self._query(ctx)
        oracle = ctx.execute(q, target="interp")
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            with inject("backend.execute", times=1, seed=CHAOS_SEED):
                result = ctx.compile(
                    q, target="local", cache=PlanCache(),
                    strategy={"groupby": "direct", "encode": "dict"})
                got = run_compiled(ctx, result)
        assert result.degraded and result.degraded[0] == "encode=raw"
        assert [w for w in caught if issubclass(w.category, DegradedWarning)]
        order_g = np.argsort(np.asarray(got["k"]).ravel())
        order_w = np.argsort(np.asarray(oracle["k"]).ravel())
        for col_name in oracle:
            np.testing.assert_allclose(
                np.asarray(got[col_name]).ravel()[order_g],
                np.asarray(oracle[col_name]).ravel()[order_w], rtol=1e-4)

    def test_poisoned_dict_strategy_not_replayed(self, tmp_path):
        """A crashed dict-encoded plan is poisoned in the store: a fresh
        process (fresh cache, same store) skips it up front instead of
        re-crashing through the same strategy."""
        ctx = self._sparse_ctx()
        q = self._query(ctx)
        store = PlanStore(tmp_path)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            with inject("backend.execute", times=1, seed=CHAOS_SEED):
                first = ctx.compile(
                    q, target="local", cache=PlanCache(), store=store,
                    strategy={"groupby": "direct", "encode": "dict"})
                run_compiled(ctx, first)
        assert first.degraded
        records = [p for p in tmp_path.glob("*.json")
                   if p.name != "calibration.json"]
        poisons = [json.loads(p.read_text()).get("poison") or []
                   for p in records]
        assert any(poisons), "crashed dict strategy must be poisoned"
        with tracing() as tr:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                second = ctx.compile(
                    q, target="local", cache=PlanCache(), store=store,
                    strategy={"groupby": "direct", "encode": "dict"})
                run_compiled(ctx, second)
        assert tr.counters.get("robust.fallback.poison_skip", 0) >= 1
