"""Rewriting passes: DCE, CSE, fusion, and the parallelization rewrite.

The key property (paper §3.2): any transformation must preserve behaviour
*as if executed on the abstract machine* — checked by interpreting original
and rewritten programs on the same inputs.
"""

import numpy as np
import pytest

from repro.backends.interp import Interpreter
from repro.core import Builder, Program, verify
from repro.core.expr import AggSpec, col
from repro.core.passes import (
    CommonSubexpressionElimination, DeadCodeElimination, FuseKMeansStep,
    Parallelize,
)
from repro.core.passes.rewriter import PassManager
from repro.core.types import Atom, Bag, F32, Tensor, TupleType

LINEITEM = TupleType.of(
    l_quantity=F32, l_eprice=F32, l_disc=F32, l_shipdate=Atom("date"),
)

Q6_PRED = (
    col("l_shipdate").between(8766, 9131)
    & col("l_disc").between(0.05, 0.07)
    & (col("l_quantity") < 24.0)
)


def q6_program() -> Program:
    b = Builder("Tpch6Seq")
    li = b.input("lineitem", Bag(LINEITEM))
    filtered = b.emit1("rel.Select", [li], {"pred": Q6_PRED})
    projected = b.emit1(
        "rel.ExProj", [filtered], {"exprs": (("x", col("l_eprice") * col("l_disc")),)}
    )
    result = b.emit1("rel.Aggr", [projected], {"aggs": (AggSpec("sum", col("x"), "revenue"),)})
    return b.finish(result)


def lineitem_data(n=1000, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "l_quantity": rng.uniform(1, 50, n).astype(np.float32),
        "l_eprice": rng.uniform(100, 10000, n).astype(np.float32),
        "l_disc": np.round(rng.uniform(0.0, 0.1, n), 2).astype(np.float32),
        "l_shipdate": rng.integers(8500, 9500, n).astype(np.int32),
    }


class TestInterpreter:
    def test_q6_against_manual_numpy(self):
        t = lineitem_data()
        (out,) = Interpreter().run(q6_program(), t)
        mask = (
            (t["l_shipdate"] >= 8766) & (t["l_shipdate"] <= 9131)
            & (t["l_disc"] >= 0.05) & (t["l_disc"] <= 0.07)
            & (t["l_quantity"] < 24.0)
        )
        expected = np.sum((t["l_eprice"] * t["l_disc"])[mask].astype(np.float64))
        assert out["revenue"] == pytest.approx(expected, rel=1e-6)


class TestParallelize:
    def test_q6_structure_matches_paper_alg2(self):
        """After the rewrite, Q6 must look like paper Algorithm 2:
        Split → ConcurrentExecute(Select;ExProj;pre-Aggr) → combine."""
        p = Parallelize(n=4).apply(q6_program())
        verify(p)
        ops = [i.opcode for i in p.body]
        assert "cf.Split" in ops and "cf.ConcurrentExecute" in ops
        assert "rel.CombinePartials" in ops
        # everything movable moved inside: no Select/ExProj/Aggr at top level
        assert not any(o.startswith("rel.") for o in ops if o != "rel.CombinePartials")
        ce = next(i for i in p.body if i.opcode == "cf.ConcurrentExecute")
        inner_ops = [i.opcode for i in ce.param("P").body]
        assert inner_ops == ["rel.Select", "rel.ExProj", "rel.Aggr"]

    @pytest.mark.parametrize("n", [1, 2, 3, 8])
    def test_q6_semantics_preserved(self, n):
        t = lineitem_data(1013)  # deliberately not divisible by n
        (orig,) = Interpreter().run(q6_program(), t)
        par = Parallelize(n=n).apply(q6_program())
        verify(par)
        (out,) = Interpreter().run(par, t)
        assert out["revenue"] == pytest.approx(orig["revenue"], rel=1e-9)

    def test_groupby_parallelizes_with_merge_recombine(self):
        b = Builder("grp")
        li = b.input("lineitem", Bag(LINEITEM))
        g = b.emit1("rel.GroupByAggr", [li], {
            "keys": ("l_shipdate",),
            "aggs": (AggSpec("sum", col("l_eprice"), "total"),
                     AggSpec("count", col("l_eprice"), "n")),
        })
        p0 = b.finish(g)
        t = lineitem_data(500)
        (orig,) = Interpreter().run(p0, t)
        par = Parallelize(n=4).apply(p0)
        verify(par)
        ops = [i.opcode for i in par.body]
        # pre-aggregation inside, merge + combine-GroupByAggr outside
        assert "cf.ConcurrentExecute" in ops
        assert ops.count("rel.GroupByAggr") == 1
        (out,) = Interpreter().run(par, t)
        o_order = np.argsort(orig["l_shipdate"])
        n_order = np.argsort(out["l_shipdate"])
        np.testing.assert_allclose(
            np.asarray(orig["total"])[o_order], np.asarray(out["total"])[n_order], rtol=1e-9
        )
        np.testing.assert_array_equal(
            np.asarray(orig["n"])[o_order], np.asarray(out["n"])[n_order]
        )

    def test_unknown_instruction_left_outside(self):
        """Paper: 'If an unknown instruction had been encountered, then the
        rule would leave it as is.'"""
        from repro.core.program import Instruction, Register

        b = Builder("withunknown")
        li = b.input("lineitem", Bag(LINEITEM))
        filtered = b.emit1("rel.Select", [li], {"pred": Q6_PRED})
        p0 = b.finish(filtered)
        exotic_out = Register("exo0", filtered.type)
        body = list(p0.body) + [Instruction("exotic.Op", (filtered,), (exotic_out,))]
        p0 = p0.with_body(body).with_results((exotic_out,))

        par = Parallelize(n=2).apply(p0)
        verify(par)
        ops = [i.opcode for i in par.body]
        assert "exotic.Op" in ops  # still at top level
        ce = next(i for i in par.body if i.opcode == "cf.ConcurrentExecute")
        assert [i.opcode for i in ce.param("P").body] == ["rel.Select"]

    def test_kmeans_broadcast_and_combine(self):
        """LA flavor: X is split, centroids broadcast, partials summed."""
        n, d, k = 240, 8, 5
        b = Builder("kmeans_step")
        X = b.input("X", Tensor(F32, (n, d)))
        C = b.input("C", Tensor(F32, (k, d)))
        sums, counts = b.emit("la.KMeansStep", [X, C])
        p0 = b.finish(sums, counts)

        rng = np.random.default_rng(1)
        xv = rng.normal(size=(n, d)).astype(np.float32)
        cv = rng.normal(size=(k, d)).astype(np.float32)
        s0, c0 = Interpreter().run(p0, xv, cv)

        par = Parallelize(n=4, targets={X.name}).apply(p0)
        verify(par)
        ops = [i.opcode for i in par.body]
        assert "cf.Broadcast" in ops and ops.count("cf.CombineChunks") == 2
        s1, c1 = Interpreter().run(par, xv, cv)
        np.testing.assert_allclose(s0, s1, rtol=1e-6)
        np.testing.assert_allclose(c0, c1, rtol=0)


class TestFusion:
    def test_kmeans_pipeline_fuses_to_step(self):
        n, d, k = 96, 4, 3
        b = Builder("kmeans_unfused")
        X = b.input("X", Tensor(F32, (n, d)))
        C = b.input("C", Tensor(F32, (k, d)))
        dist = b.emit1("la.CDist2", [X, C])
        lab = b.emit1("la.ArgMinRow", [dist])
        sums = b.emit1("la.SegSum", [X, lab], {"k": k})
        counts = b.emit1("la.SegCount", [lab], {"k": k})
        p0 = b.finish(sums, counts)

        fused = FuseKMeansStep().apply(p0)
        verify(fused)
        assert [i.opcode for i in fused.body] == ["la.KMeansStep"]

        rng = np.random.default_rng(2)
        xv = rng.normal(size=(n, d)).astype(np.float32)
        cv = rng.normal(size=(k, d)).astype(np.float32)
        s0, c0 = Interpreter().run(p0, xv, cv)
        s1, c1 = Interpreter().run(fused, xv, cv)
        np.testing.assert_allclose(s0, s1, rtol=1e-9)
        np.testing.assert_allclose(c0, c1, rtol=0)


class TestDceCse:
    def test_dce_removes_dead_pure_chain(self):
        b = Builder("dead")
        li = b.input("lineitem", Bag(LINEITEM))
        live = b.emit1("rel.Select", [li], {"pred": Q6_PRED})
        dead = b.emit1("rel.ExProj", [li], {"exprs": (("y", col("l_disc") + 1.0),)})
        _dead2 = b.emit1("rel.Select", [dead], {"pred": col("y") > 0.0})
        p = b.finish(live)
        out = DeadCodeElimination().apply(p)
        verify(out)
        assert [i.opcode for i in out.body] == ["rel.Select"]

    def test_dce_keeps_unknown_ops(self):
        from repro.core.program import Instruction, Register

        b = Builder("u")
        li = b.input("lineitem", Bag(LINEITEM))
        filtered = b.emit1("rel.Select", [li], {"pred": Q6_PRED})
        p = b.finish(filtered)
        eff = Instruction("exotic.SideEffect", (li,), (Register("e0", Bag(LINEITEM)),))
        p = p.with_body(list(p.body) + [eff])
        out = DeadCodeElimination().apply(p)
        assert any(i.opcode == "exotic.SideEffect" for i in out.body)

    def test_cse_merges_identical_selects(self):
        b = Builder("dup")
        li = b.input("lineitem", Bag(LINEITEM))
        s1 = b.emit1("rel.Select", [li], {"pred": Q6_PRED})
        s2 = b.emit1("rel.Select", [li], {"pred": Q6_PRED})
        a1 = b.emit1("rel.Aggr", [s1], {"aggs": (AggSpec("count", col("l_disc"), "n"),)})
        a2 = b.emit1("rel.Aggr", [s2], {"aggs": (AggSpec("count", col("l_disc"), "n"),)})
        p = b.finish(a1, a2)
        out = PassManager([CommonSubexpressionElimination(), DeadCodeElimination()]).run(p)
        ops = [i.opcode for i in out.body]
        assert ops.count("rel.Select") == 1 and ops.count("rel.Aggr") == 1
        assert out.results[0].name == out.results[1].name

    def test_pipeline_equivalence_after_all_passes(self):
        t = lineitem_data(750, seed=3)
        p = q6_program()
        pm = PassManager([
            CommonSubexpressionElimination(), DeadCodeElimination(), Parallelize(n=3),
        ])
        out = pm.run(p)
        (a,) = Interpreter().run(p, t)
        (b_,) = Interpreter().run(out, t)
        assert a["revenue"] == pytest.approx(b_["revenue"], rel=1e-9)
