"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle.

Every kernel sweeps shapes/dtypes and asserts against ref.py.  Property
tests (hypothesis) cover the data-dependent kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.expr import AggSpec, col
from repro.kernels import ops, ref
from repro.relational.runtime import VecTable


def rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# fused_select_agg
# ---------------------------------------------------------------------------

PRED = (col("a") > 0.3) & (col("b") < 0.8)
AGGS = (
    AggSpec("sum", col("a") * col("b"), "s"),
    AggSpec("count", col("a"), "n"),
    AggSpec("min", col("a"), "lo"),
    AggSpec("max", col("b") - col("a"), "hi"),
)


class TestFusedSelectAgg:
    @pytest.mark.parametrize("cap,valid_frac", [(256, 1.0), (1000, 0.7), (4096, 0.5), (128, 0.0)])
    def test_sweep_capacity(self, cap, valid_frac):
        r = rng(cap)
        cols = {
            "a": r.uniform(0, 1, cap).astype(np.float32),
            "b": r.uniform(0, 1, cap).astype(np.float32),
        }
        valid = r.uniform(0, 1, cap) < valid_frac
        t = VecTable({k: jnp.asarray(v) for k, v in cols.items()}, jnp.asarray(valid))
        got = ops.fused_select_agg(t, PRED, AGGS, interpret=True)
        want = ref.fused_select_agg(t.cols, t.valid, PRED, AGGS)
        for i, a in enumerate(AGGS):
            np.testing.assert_allclose(np.asarray(got[a.name]), np.asarray(want[i]),
                                       rtol=1e-5, err_msg=a.name)

    @pytest.mark.parametrize("block_rows", [8, 64, 512])
    def test_sweep_block_shape(self, block_rows):
        r = rng(1)
        cap = 2048
        cols = {"a": r.uniform(0, 1, cap).astype(np.float32),
                "b": r.uniform(0, 1, cap).astype(np.float32)}
        t = VecTable({k: jnp.asarray(v) for k, v in cols.items()},
                     jnp.asarray(np.ones(cap, bool)))
        got = ops.fused_select_agg(t, PRED, AGGS, block_rows=block_rows, interpret=True)
        want = ref.fused_select_agg(t.cols, t.valid, PRED, AGGS)
        for i, a in enumerate(AGGS):
            np.testing.assert_allclose(np.asarray(got[a.name]), np.asarray(want[i]), rtol=1e-5)

    def test_integer_date_columns(self):
        r = rng(2)
        cap = 512
        cols = {"d": r.integers(8000, 10000, cap).astype(np.int32),
                "x": r.uniform(0, 1, cap).astype(np.float32)}
        t = VecTable({k: jnp.asarray(v) for k, v in cols.items()},
                     jnp.asarray(np.ones(cap, bool)))
        pred = (col("d") >= 8500) & (col("d") < 9500)
        aggs = (AggSpec("sum", col("x"), "s"), AggSpec("count", col("x"), "n"))
        got = ops.fused_select_agg(t, pred, aggs, interpret=True)
        want = ref.fused_select_agg(t.cols, t.valid, pred, aggs)
        np.testing.assert_allclose(np.asarray(got["s"]), np.asarray(want[0]), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(got["n"]), np.asarray(want[1]), rtol=0)


# ---------------------------------------------------------------------------
# segsum
# ---------------------------------------------------------------------------


class TestSegSum:
    @pytest.mark.parametrize("n,d,k", [(256, 8, 4), (1000, 16, 17), (2048, 128, 64), (64, 1, 2)])
    def test_sweep_shapes(self, n, d, k):
        r = rng(n + d + k)
        data = r.normal(size=(n, d)).astype(np.float32)
        seg = r.integers(0, k, n).astype(np.int32)
        got = ops.segsum(jnp.asarray(data), jnp.asarray(seg), k, interpret=True)
        want = ref.segsum(jnp.asarray(data), jnp.asarray(seg), k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(16, 600), d=st.integers(1, 32), k=st.integers(1, 40),
           seed=st.integers(0, 2**16))
    def test_property_matches_oracle(self, n, d, k, seed):
        r = rng(seed)
        data = r.normal(size=(n, d)).astype(np.float32)
        seg = r.integers(0, k, n).astype(np.int32)
        got = ops.segsum(jnp.asarray(data), jnp.asarray(seg), k, block_rows=128, interpret=True)
        want = ref.segsum(jnp.asarray(data), jnp.asarray(seg), k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# kmeans_step
# ---------------------------------------------------------------------------


class TestKMeansStep:
    @pytest.mark.parametrize("n,d,k", [(1024, 8, 5), (1000, 32, 16), (4096, 128, 8)])
    def test_sweep_shapes(self, n, d, k):
        r = rng(n * 7 + k)
        x = r.normal(size=(n, d)).astype(np.float32)
        c = r.normal(size=(k, d)).astype(np.float32)
        gs, gc = ops.kmeans_step(jnp.asarray(x), jnp.asarray(c), interpret=True)
        ws, wc = ref.kmeans_step(jnp.asarray(x), jnp.asarray(c))
        np.testing.assert_allclose(np.asarray(gc), np.asarray(wc), rtol=0)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), rtol=1e-4, atol=1e-4)

    def test_counts_conserved(self):
        r = rng(9)
        x = r.normal(size=(1536, 4)).astype(np.float32)
        c = r.normal(size=(7, 4)).astype(np.float32)
        _, counts = ops.kmeans_step(jnp.asarray(x), jnp.asarray(c), block_rows=512,
                                    interpret=True)
        assert float(jnp.sum(counts)) == 1536.0


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,s,d", [
        (1, 2, 1, 128, 64), (2, 4, 2, 256, 32), (1, 8, 2, 128, 128), (1, 1, 1, 512, 64),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_sweep_shapes(self, b, hq, hkv, s, d, causal):
        r = rng(b * s + hq)
        q = r.normal(size=(b, hq, s, d)).astype(np.float32)
        k = r.normal(size=(b, hkv, s, d)).astype(np.float32)
        v = r.normal(size=(b, hkv, s, d)).astype(np.float32)
        got = ops.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal, mode="pallas", interpret=True)
        want = ref.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                   causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("window", [64, 128])
    def test_sliding_window(self, window):
        r = rng(3)
        b, hq, hkv, s, d = 1, 2, 1, 256, 32
        q = r.normal(size=(b, hq, s, d)).astype(np.float32)
        k = r.normal(size=(b, hkv, s, d)).astype(np.float32)
        v = r.normal(size=(b, hkv, s, d)).astype(np.float32)
        got = ops.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True, window=window, mode="pallas", interpret=True)
        want = ref.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                   causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)

    def test_bf16(self):
        r = rng(4)
        b, hq, hkv, s, d = 1, 4, 4, 128, 64
        q = jnp.asarray(r.normal(size=(b, hq, s, d)), jnp.bfloat16)
        k = jnp.asarray(r.normal(size=(b, hkv, s, d)), jnp.bfloat16)
        v = jnp.asarray(r.normal(size=(b, hkv, s, d)), jnp.bfloat16)
        got = ops.attention(q, k, v, mode="pallas", interpret=True)
        want = ref.flash_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                                   np.asarray(want, dtype=np.float32), rtol=5e-2, atol=5e-2)

    def test_chunked_matches_ref(self):
        r = rng(5)
        b, hq, hkv, s, d = 2, 4, 2, 256, 64
        q = jnp.asarray(r.normal(size=(b, hq, s, d)), jnp.float32)
        k = jnp.asarray(r.normal(size=(b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(r.normal(size=(b, hkv, s, d)), jnp.float32)
        got = ops.chunked_attention(q, k, v, causal=True, block_k=64)
        want = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_chunked_gradients_match_ref(self):
        r = rng(6)
        b, hq, hkv, s, d = 1, 2, 1, 128, 32
        q = jnp.asarray(r.normal(size=(b, hq, s, d)), jnp.float32)
        k = jnp.asarray(r.normal(size=(b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(r.normal(size=(b, hkv, s, d)), jnp.float32)

        def loss_chunked(q, k, v):
            return jnp.sum(ops.chunked_attention(q, k, v, causal=True, block_k=32) ** 2)

        def loss_ref(q, k, v):
            return jnp.sum(ref.flash_attention(q, k, v, causal=True) ** 2)

        g1 = jax.grad(loss_chunked, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-3, atol=2e-3)

    def test_decode_matches_full_forward_last_token(self):
        r = rng(7)
        b, hq, hkv, s, d = 2, 4, 2, 64, 32
        q_full = jnp.asarray(r.normal(size=(b, hq, s, d)), jnp.float32)
        k = jnp.asarray(r.normal(size=(b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(r.normal(size=(b, hkv, s, d)), jnp.float32)
        full = ref.flash_attention(q_full, k, v, causal=True)
        dec = ops.decode_attention(q_full[:, :, -1:, :], k, v, cache_len=s)
        np.testing.assert_allclose(np.asarray(dec[:, :, 0]), np.asarray(full[:, :, -1]),
                                   rtol=1e-4, atol=1e-4)
