"""SQL frontend + property-based rewrite-invariance tests.

The hypothesis tests check the system's core invariant on *randomly
generated* relational programs: every rewriting pipeline (CSE, DCE,
parallelization with any worker count) preserves abstract-machine
semantics.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.backends.interp import Interpreter
from repro.core import verify
from repro.core.expr import AggSpec, col, const
from repro.core.passes import (
    CommonSubexpressionElimination, DeadCodeElimination, Parallelize,
)
from repro.core.passes.rewriter import PassManager
from repro.frontends import sql
from repro.frontends.dataflow import Context


@pytest.fixture(scope="module")
def ctx():
    rng = np.random.default_rng(5)
    n = 3000
    c = Context(pad_to=256)
    c.register("t", {
        "a": rng.integers(0, 20, n).astype(np.int32),
        "b": rng.uniform(0, 100, n).astype(np.float32),
        "c": rng.uniform(0, 1, n).astype(np.float32),
        "g": rng.integers(0, 4, n).astype(np.int32),
    })
    c.register("dim", {
        "g": np.arange(4, dtype=np.int32),
        "label": np.asarray([10, 20, 30, 40], dtype=np.int32),
    })
    return c


class TestSQL:
    def test_scalar_agg(self, ctx):
        out = sql.query(ctx, "SELECT sum(b * c) AS s, count(*) AS n FROM t WHERE a < 10")
        t = ctx.tables["t"]
        m = t["a"] < 10
        assert out["s"] == pytest.approx(float((t["b"] * t["c"])[m].sum()), rel=1e-4)
        assert int(out["n"]) == int(m.sum())

    def test_group_by_order_by(self, ctx):
        out = sql.query(ctx, "SELECT sum(b) AS s FROM t GROUP BY g ORDER BY g")
        t = ctx.tables["t"]
        want = [float(t["b"][t["g"] == g].sum()) for g in range(4)]
        np.testing.assert_allclose(np.asarray(out["s"], dtype=np.float64), want, rtol=1e-4)

    def test_join(self, ctx):
        out = sql.query(ctx, "SELECT sum(label) AS s FROM t JOIN dim ON g = g WHERE b < 50")
        t, d = ctx.tables["t"], ctx.tables["dim"]
        m = t["b"] < 50
        want = d["label"][t["g"][m]].sum()
        assert int(out["s"]) == int(want)

    def test_between_and_arithmetic(self, ctx):
        out = sql.query(ctx, "SELECT sum(b - 2 * c) AS s FROM t WHERE c BETWEEN 0.2 AND 0.4")
        t = ctx.tables["t"]
        m = (t["c"] >= 0.2) & (t["c"] <= 0.4)
        assert out["s"] == pytest.approx(float((t["b"] - 2 * t["c"])[m].sum()), rel=1e-4)

    def test_avg_desugars(self, ctx):
        out = sql.query(ctx, "SELECT avg(b) AS m FROM t")
        assert out["m"] == pytest.approx(float(ctx.tables["t"]["b"].mean()), rel=1e-4)

    def test_syntax_error(self, ctx):
        with pytest.raises(SyntaxError):
            sql.parse("SELECT FROM t", ctx)

    def test_same_ir_as_python_frontend(self, ctx):
        """SQL and the Python dataflow frontend compile to the same plan."""
        q_sql = sql.parse("SELECT sum(b) AS s FROM t WHERE a < 5", ctx)
        from repro.frontends.dataflow import sum_
        q_py = ctx.table("t").filter(col("a") < 5).agg(sum_("b").as_("s"))
        assert [i.opcode for i in q_sql.program().body] == \
               [i.opcode for i in q_py.program().body]


# ---------------------------------------------------------------------------
# property-based rewrite invariance
# ---------------------------------------------------------------------------

SCHEMA_COLS = ["a", "b", "c", "g"]


def _tables(seed, n):
    rng = np.random.default_rng(seed)
    return {"t": {
        "a": rng.integers(0, 20, n).astype(np.int32),
        "b": rng.uniform(0, 100, n).astype(np.float32),
        "c": rng.uniform(0, 1, n).astype(np.float32),
        "g": rng.integers(0, 4, n).astype(np.int32),
    }}


@st.composite
def random_query(draw):
    """A random Select/ExProj/Aggr-or-GroupBy pipeline over table t."""
    c = Context(pad_to=64)
    c.register("t", _tables(0, 8)["t"])  # schema donor
    f = c.table("t")
    n_filters = draw(st.integers(0, 2))
    for _ in range(n_filters):
        column = draw(st.sampled_from(["a", "b", "c"]))
        thresh = draw(st.floats(0.1, 50.0, allow_nan=False))
        f = f.filter(col(column) < float(thresh))
    if draw(st.booleans()):
        f = f.with_columns(x=col("b") * col("c") + draw(st.integers(0, 5)))
        val = "x"
    else:
        val = "b"
    fn = draw(st.sampled_from(["sum", "count", "min", "max"]))
    grouped = draw(st.booleans())
    if grouped:
        node_params = {"keys": ("g",), "aggs": (AggSpec(fn, col(val), "r"),),
                       "max_groups": 8}
        from repro.frontends.dataflow import _Node, Frame
        from repro.core.types import TupleType
        node = _Node("rel.GroupByAggr", tuple(node_params.items()), (f._node,))
        fields = (("g", f.schema.field("g")),
                  ("r", AggSpec(fn, col(val), "r").result_atom(f.schema)))
        f = Frame(c, node, TupleType(fields))
    else:
        from repro.frontends.dataflow import AggExpr
        f = f.agg(AggExpr(fn, col(val), "r"))
    return f.program("rand")


@settings(max_examples=25, deadline=None)
@given(program=random_query(), n_workers=st.integers(1, 6),
       seed=st.integers(0, 1000), n_rows=st.integers(1, 500))
def test_parallelize_preserves_semantics_on_random_programs(
        program, n_workers, seed, n_rows):
    tables = _tables(seed, n_rows)
    interp = Interpreter(sources=tables)
    (want,) = interp.run(program)

    pm = PassManager([CommonSubexpressionElimination(), DeadCodeElimination(),
                      Parallelize(n=n_workers)])
    rewritten = pm.run(program)
    verify(rewritten)
    (got,) = Interpreter(sources=tables).run(rewritten)

    if isinstance(want, dict) and "r" in want and np.ndim(want.get("r")) == 0:
        np.testing.assert_allclose(float(got["r"]), float(want["r"]), rtol=1e-6)
    else:
        ow = np.argsort(np.asarray(want["g"]))
        og = np.argsort(np.asarray(got["g"]))
        np.testing.assert_array_equal(np.asarray(want["g"])[ow],
                                      np.asarray(got["g"])[og])
        np.testing.assert_allclose(np.asarray(want["r"], dtype=np.float64)[ow],
                                   np.asarray(got["r"], dtype=np.float64)[og],
                                   rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(program=random_query())
def test_rewrites_keep_programs_verifiable(program):
    for n in (2, 4):
        out = PassManager([Parallelize(n=n), CommonSubexpressionElimination(),
                           DeadCodeElimination()]).run(program)
        verify(out)
        # parallelization must not lose the Return value
        assert len(out.results) == len(program.results)
