"""The sort-free physical tier for the vec flavor (ISSUE 5).

Contracts:
  * ``vec.GroupAggDirect`` (dense-bucket segment reduction) is row-for-row
    equivalent to ``SortByKey + GroupAggSorted`` and to the interp oracle —
    across int/bool/float keys, empty selections, all-invalid tables, and
    max_groups boundaries;
  * the ``groupby: sorted | direct`` strategy Choice is forceable through
    ``compile(...)`` and chosen by ``optimize="cost"`` from the key-domain
    statistics (low NDV → direct, huge domain → sorted);
  * ``compact`` is the O(n) prefix-sum scatter, same semantics as before;
  * ``topk`` takes the ``lax.top_k`` fast path on single numeric keys;
  * composite keys no longer silently collide: grouped aggregation is
    collision-free by construction, multi-key joins pack with real bounds
    and raise when a static domain cannot fit the 32-bit accumulator;
  * the ``grouped_select_agg`` Pallas kernel (use_kernels) agrees with all
    of the above;
  * on spmd, the costed search picks direct for the TPC-H Q1 shape and both
    tiers match the oracle (subprocess: owns an 8-device host platform).
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.compiler import PlanCache, compile as cvm_compile
from repro.core.expr import AggSpec, col
from repro.frontends.dataflow import Context, avg_, count_, max_, min_, sum_
from repro.launch.hermetic import subprocess_env
from repro.relational import runtime as rt
from repro.relational.runtime import VecTable

ROOT = Path(__file__).resolve().parents[1]


def _sorted_rows(table, keys):
    arrs = [np.asarray(table[k]) for k in keys]
    order = np.lexsort(tuple(reversed(arrs)))
    return {k: np.asarray(v)[order] for k, v in table.items()}


def _assert_tables_equal(got, want, keys, rtol=1e-4):
    got, want = _sorted_rows(got, keys), _sorted_rows(want, keys)
    assert set(got) == set(want)
    for k in got:
        g, w = np.asarray(got[k]), np.asarray(want[k])
        assert g.shape == w.shape, (k, g.shape, w.shape)
        if np.issubdtype(g.dtype, np.floating) or np.issubdtype(w.dtype, np.floating):
            np.testing.assert_allclose(g, w.astype(g.dtype), rtol=rtol, err_msg=k)
        else:
            np.testing.assert_array_equal(g, w, err_msg=k)


@pytest.fixture()
def sales_ctx():
    rng = np.random.default_rng(7)
    n = 4096
    ctx = Context(pad_to=512)
    ctx.register("sales", {
        "region": rng.integers(0, 12, n).astype(np.int32),
        "flag": rng.integers(0, 2, n).astype(bool),
        "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        "year": rng.integers(2018, 2026, n).astype(np.int32),
    })
    return ctx


def grouped_query(ctx, *keys, max_groups=64):
    return (ctx.table("sales")
            .group_by(*(keys or ("region",)), max_groups=max_groups)
            .agg(sum_("amount").as_("rev"), count_().as_("n"),
                 min_("amount").as_("lo"), max_("amount").as_("hi")))


AGGS = (AggSpec("sum", col("x"), "s"), AggSpec("count", col("x"), "c"),
        AggSpec("min", col("x"), "lo"), AggSpec("max", col("x"), "hi"))


# ---------------------------------------------------------------------------
# runtime tier: group_agg_direct ≡ sort_by_key + group_agg_sorted
# ---------------------------------------------------------------------------


class TestRuntimeDirect:
    def _table(self, keys_cols, n=500, cap=512, seed=0, valid=None):
        rng = np.random.default_rng(seed)
        data = dict(keys_cols)
        data["x"] = rng.normal(10.0, 5.0, n).astype(np.float32)
        t = VecTable.from_numpy(data, cap)
        if valid is not None:
            import jax.numpy as jnp
            t = VecTable(t.cols, jnp.asarray(valid))
        return t

    def _check(self, t, keys, domains, max_groups=64):
        nb = 1
        for lo, hi in domains:
            nb *= hi - lo + 1
        direct = rt.group_agg_direct(t, keys, AGGS, max_groups, domains, nb)
        ref = rt.group_agg_sorted(rt.sort_by_key(t, keys), keys, AGGS, max_groups)
        for k in list(keys) + [a.name for a in AGGS]:
            np.testing.assert_allclose(
                np.asarray(direct.cols[k])[np.asarray(direct.valid)],
                np.asarray(ref.cols[k])[np.asarray(ref.valid)],
                rtol=1e-5, err_msg=k)
        np.testing.assert_array_equal(np.asarray(direct.valid),
                                      np.asarray(ref.valid))

    def test_int_keys(self):
        rng = np.random.default_rng(1)
        k1 = rng.integers(3, 11, 500).astype(np.int32)
        self._check(self._table({"k1": k1}), ("k1",), ((3, 10),))

    def test_multi_key_int_bool(self):
        rng = np.random.default_rng(2)
        k1 = rng.integers(0, 5, 500).astype(np.int32)
        k2 = rng.integers(0, 2, 500).astype(bool)
        self._check(self._table({"k1": k1, "k2": k2}), ("k1", "k2"),
                    ((0, 4), (0, 1)))

    def test_large_key_values(self):
        """Key values ≥ 65536 — the old 16-bit composite packing collided."""
        rng = np.random.default_rng(3)
        k1 = (rng.integers(0, 4, 500) * 70_000 + 100_000).astype(np.int32)
        self._check(self._table({"k1": k1}), ("k1",), ((100_000, 310_000),))

    def test_all_invalid(self):
        t = self._table({"k1": np.zeros(500, np.int32)}, valid=np.zeros(512, bool))
        direct = rt.group_agg_direct(t, ("k1",), AGGS, 8, ((0, 0),), 1)
        assert not np.asarray(direct.valid).any()

    def test_max_groups_boundary(self):
        """Exactly max_groups groups, and more groups than max_groups: both
        tiers keep the first max_groups groups in key order."""
        k1 = np.arange(500, dtype=np.int32) % 16
        t = self._table({"k1": k1})
        self._check(t, ("k1",), ((0, 15),), max_groups=16)
        self._check(t, ("k1",), ((0, 15),), max_groups=8)


# ---------------------------------------------------------------------------
# O(n) compact / limit
# ---------------------------------------------------------------------------


class TestCompact:
    def _rand_table(self, cap=257, seed=5):
        rng = np.random.default_rng(seed)
        t = VecTable.from_numpy({
            "a": rng.integers(0, 100, cap).astype(np.int32),
            "b": rng.normal(size=cap).astype(np.float32),
        }, cap)
        import jax.numpy as jnp
        return VecTable(t.cols, jnp.asarray(rng.random(cap) < 0.35))

    def test_compact_matches_reference(self):
        t = self._rand_table()
        c = rt.compact(t)
        mask = np.asarray(t.valid)
        n = int(mask.sum())
        got_valid = np.asarray(c.valid)
        assert got_valid[:n].all() and not got_valid[n:].any()
        for k in t.cols:
            np.testing.assert_array_equal(np.asarray(c.cols[k])[:n],
                                          np.asarray(t.cols[k])[mask])

    def test_compact_truncates_to_max_count(self):
        t = self._rand_table()
        c = rt.compact(t, max_count=16)
        assert c.capacity == 16
        mask = np.asarray(t.valid)
        keep = min(16, int(mask.sum()))
        assert np.asarray(c.valid)[:keep].all()
        for k in t.cols:
            np.testing.assert_array_equal(np.asarray(c.cols[k])[:keep],
                                          np.asarray(t.cols[k])[mask][:keep])

    def test_limit(self):
        t = self._rand_table(seed=6)
        out = rt.limit(t, 10)
        mask = np.asarray(t.valid)
        np.testing.assert_array_equal(
            np.asarray(out.cols["a"])[np.asarray(out.valid)],
            np.asarray(t.cols["a"])[mask][:10])

    def test_compact_empty(self):
        import jax.numpy as jnp
        t = self._rand_table()
        t = VecTable(t.cols, jnp.zeros(t.capacity, bool))
        c = rt.compact(t)
        assert not np.asarray(c.valid).any()


# ---------------------------------------------------------------------------
# topk fast path
# ---------------------------------------------------------------------------


class TestTopK:
    def _table(self, seed=9, cap=512, n=400):
        rng = np.random.default_rng(seed)
        return VecTable.from_numpy({
            "k": rng.permutation(n * 4)[:n].astype(np.int32),  # distinct keys
            "f": rng.normal(size=n).astype(np.float32),
        }, cap)

    @pytest.mark.parametrize("ascending", [True, False])
    @pytest.mark.parametrize("key", ["k", "f"])
    def test_single_key_matches_sort(self, key, ascending):
        t = self._table()
        fast = rt.topk(t, (key,), (ascending,), 25)
        slow = rt.sort_by_key(t, (key,), (ascending,))
        for c in t.cols:
            np.testing.assert_array_equal(
                np.asarray(fast.cols[c])[np.asarray(fast.valid)],
                np.asarray(slow.cols[c])[:25])
        assert np.asarray(fast.valid).all()

    def test_k_exceeds_valid_rows(self):
        t = self._table(n=20)
        out = rt.topk(t, ("k",), (True,), 50)
        assert int(np.asarray(out.valid).sum()) == 20

    def test_ascending_includes_int32_min(self):
        """Ascending int scores flip via bitwise NOT, not negation — the
        global minimum INT32_MIN must not overflow into the sentinel."""
        t = VecTable.from_numpy({
            "k": np.array([5, np.iinfo(np.int32).min, 3], np.int32)}, 4)
        out = rt.topk(t, ("k",), (True,), 2)
        np.testing.assert_array_equal(
            np.asarray(out.cols["k"])[np.asarray(out.valid)],
            [np.iinfo(np.int32).min, 3])

    def test_multi_key_still_sorts(self):
        t = self._table()
        out = rt.topk(t, ("k", "f"), (True, True), 10)
        slow = rt.sort_by_key(t, ("k", "f"), (True, True))
        np.testing.assert_array_equal(
            np.asarray(out.cols["k"])[np.asarray(out.valid)],
            np.asarray(slow.cols["k"])[:10])


# ---------------------------------------------------------------------------
# composite keys: no silent collisions
# ---------------------------------------------------------------------------


class TestCompositeKeys:
    def test_grouped_agg_large_two_keys_match_oracle(self):
        """Two int keys with values ≥ 65536: the old packed accumulator
        collided; per-column change detection is collision-free."""
        rng = np.random.default_rng(11)
        n = 1000
        ctx = Context(pad_to=256)
        ctx.register("t", {
            "a": (rng.integers(0, 3, n) * 100_000).astype(np.int32),
            "b": (rng.integers(0, 3, n) * 90_001).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32),
        })
        q = (ctx.table("t").group_by("a", "b", max_groups=16)
             .agg(sum_("x").as_("s"), count_().as_("c")))
        want = ctx.execute(q, target="interp")
        for strat in ({"groupby": "sorted"}, {"groupby": "direct"}):
            got = ctx.execute(q, strategy=strat)
            _assert_tables_equal(got, want, ("a", "b"))

    def test_multikey_join_large_values_match_oracle(self):
        """First join key ≥ 65536 — the old 16-bit packing shifted it out of
        the accumulator entirely; joint-bound packing keeps it exact."""
        rng = np.random.default_rng(12)
        n = 600
        ka = rng.integers(0, 20, n) * 70_000
        kb = rng.integers(0, 10, n)
        ctx = Context(pad_to=256)
        right = np.stack(np.meshgrid(np.arange(20) * 70_000, np.arange(10)),
                         -1).reshape(-1, 2)
        ctx.register("probe", {
            "a": ka.astype(np.int32), "b": kb.astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32),
        })
        ctx.register("build", {
            "a2": right[:, 0].astype(np.int32), "b2": right[:, 1].astype(np.int32),
            "y": np.arange(len(right)).astype(np.float32),
        })
        q = ctx.table("probe").join(ctx.table("build"),
                                    left_on=("a", "b"), right_on=("a2", "b2"))
        want = ctx.execute(q, target="interp")
        got = ctx.execute(q)
        _assert_tables_equal(got, want, ("a", "b", "x"))

    def test_static_domain_overflow_raises(self):
        t = VecTable.from_numpy({
            "a": np.zeros(8, np.int32), "b": np.zeros(8, np.int32)}, 8)
        with pytest.raises(ValueError, match="cannot be packed"):
            rt.merge_join_sorted(t, t, ("a", "b"), ("a", "b"), 8,
                                 key_domains=((0, 1 << 20), (0, 1 << 20)))

    def test_unpackable_without_bounds_raises(self):
        t = VecTable.from_numpy({"a": np.zeros(8, np.int32)}, 8)
        with pytest.raises(ValueError, match="domain bounds"):
            rt._composite_key(t, ("a", "a"))


# ---------------------------------------------------------------------------
# forced strategies + the costed choice, through compile(...)
# ---------------------------------------------------------------------------


class TestStrategyChoice:
    def test_forced_direct_and_sorted_match_oracle(self, sales_ctx):
        q = grouped_query(sales_ctx, "region", "flag")
        want = sales_ctx.execute(q, target="interp")
        progs = {}
        for label in ("sorted", "direct"):
            res = sales_ctx.compile(q, strategy={"groupby": label},
                                    cache=PlanCache())
            progs[label] = res.program.opcodes()
            (out,) = res(sales_ctx.sources())
            _assert_tables_equal(out.to_numpy(), want, ("region", "flag"))
        assert "vec.GroupAggSorted" in progs["sorted"]
        assert "vec.GroupAggDirect" not in progs["sorted"]
        assert "vec.GroupAggDirect" in progs["direct"]
        assert "vec.SortByKey" not in progs["direct"]

    def test_forced_direct_float_key_falls_back_to_sorted(self, sales_ctx):
        """Float keys have no catalog domain — the direct tier falls back to
        the always-valid sorted lowering per instruction, still ≡ oracle."""
        q = (sales_ctx.table("sales").group_by("amount", max_groups=4096)
             .agg(count_().as_("n")))
        res = sales_ctx.compile(q, strategy={"groupby": "direct"},
                                cache=PlanCache())
        assert "vec.GroupAggSorted" in res.program.opcodes()
        assert "vec.GroupAggDirect" not in res.program.opcodes()
        want = sales_ctx.execute(q, target="interp")
        (out,) = res(sales_ctx.sources())
        _assert_tables_equal(out.to_numpy(), want, ("amount",))

    def test_cost_low_ndv_selects_direct(self, sales_ctx):
        res = sales_ctx.compile(grouped_query(sales_ctx, "region", "flag"),
                                optimize="cost", cache=PlanCache())
        assert dict(res.strategy)["groupby"] == "direct"
        assert "vec.GroupAggDirect" in res.program.opcodes()
        labels = [c.label() for c in res.decision.candidates]
        assert any("groupby=sorted" in l for l in labels)

    def test_cost_huge_domain_selects_sorted(self):
        """A key spread over a 2^17 domain: the dense bucket table would
        dwarf one pass over the rows, so the sorted tier must win."""
        rng = np.random.default_rng(13)
        n = 4096
        ctx = Context(pad_to=512)
        ctx.register("sales", {
            "k": rng.integers(0, 1 << 17, n).astype(np.int32),
            "amount": rng.gamma(2.0, 50.0, n).astype(np.float32),
        })
        q = (ctx.table("sales").group_by("k", max_groups=4096)
             .agg(sum_("amount").as_("rev")))
        res = ctx.compile(q, optimize="cost", cache=PlanCache())
        assert dict(res.strategy)["groupby"] == "sorted"
        assert "vec.GroupAggSorted" in res.program.opcodes()

    def test_direct_strategy_is_cache_keyed(self, sales_ctx):
        cache = PlanCache()
        q = grouped_query(sales_ctx)
        r1 = sales_ctx.compile(q, strategy={"groupby": "direct"}, cache=cache)
        r2 = sales_ctx.compile(q, strategy={"groupby": "sorted"}, cache=cache)
        r3 = sales_ctx.compile(q, strategy={"groupby": "direct"}, cache=cache)
        assert not r1.cache_hit and not r2.cache_hit and r3.cache_hit

    def test_empty_selection_matches_oracle(self, sales_ctx):
        q = (sales_ctx.table("sales").filter(col("year") >= 3000)
             .group_by("region", max_groups=64).agg(count_().as_("n")))
        want = sales_ctx.execute(q, target="interp")
        assert len(np.asarray(want["n"]).ravel()) == 0
        for label in ("sorted", "direct"):
            got = sales_ctx.execute(q, strategy={"groupby": label})
            assert len(got["n"]) == 0

    def test_redefined_key_column_invalidates_domain(self, sales_ctx):
        """A computed column reusing a key's name must drop its domain —
        a stale bound would let the direct tier silently merge groups."""
        q = (sales_ctx.table("sales")
             .with_columns(region=col("region") * 10)
             .group_by("region", max_groups=256)
             .agg(count_().as_("n")))
        want = sales_ctx.execute(q, target="interp")
        res = sales_ctx.compile(q, strategy={"groupby": "direct"},
                                cache=PlanCache())
        # no trustworthy domain → the direct lowering falls back to sorted
        assert "vec.GroupAggDirect" not in res.program.opcodes()
        (out,) = res(sales_ctx.sources())
        _assert_tables_equal(out.to_numpy(), want, ("region",))

    def test_fused_predicate_in_direct_plan(self, sales_ctx):
        """MaskSelect folds into GroupAggDirect (single-pass Q1 shape)."""
        q = (sales_ctx.table("sales").filter(col("year") >= 2020)
             .group_by("region", max_groups=64)
             .agg(sum_("amount").as_("rev"), count_().as_("n")))
        res = sales_ctx.compile(q, strategy={"groupby": "direct"},
                                cache=PlanCache())
        ops = res.program.opcodes()
        assert "vec.GroupAggDirect" in ops and "vec.MaskSelect" not in ops
        want = sales_ctx.execute(q, target="interp")
        (out,) = res(sales_ctx.sources())
        _assert_tables_equal(out.to_numpy(), want, ("region",))


# ---------------------------------------------------------------------------
# the Pallas kernel tier
# ---------------------------------------------------------------------------


class TestGroupedSelectAggKernel:
    def test_kernel_matches_oracle(self, sales_ctx):
        q = (sales_ctx.table("sales").filter(col("year") >= 2021)
             .group_by("region", "flag", max_groups=64)
             .agg(sum_("amount").as_("rev"), count_().as_("n"),
                  min_("amount").as_("lo"), max_("amount").as_("hi")))
        want = sales_ctx.execute(q, target="interp")
        res = sales_ctx.compile(q, strategy={"groupby": "direct"},
                                use_kernels=True, cache=PlanCache())
        assert "vec.GroupAggDirect" in res.program.opcodes()
        (out,) = res(sales_ctx.sources())
        _assert_tables_equal(out.to_numpy(), want, ("region", "flag"))

    def test_kernel_empty_selection(self, sales_ctx):
        q = (sales_ctx.table("sales").filter(col("year") >= 3000)
             .group_by("region", max_groups=64).agg(count_().as_("n")))
        res = sales_ctx.compile(q, strategy={"groupby": "direct"},
                                use_kernels=True, cache=PlanCache())
        (out,) = res(sales_ctx.sources())
        assert len(out.to_numpy()["n"]) == 0


# ---------------------------------------------------------------------------
# spmd acceptance: cost picks direct for the Q1 shape (own device fleet)
# ---------------------------------------------------------------------------

SPMD_DIRECT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np

    from repro.compiler import PlanCache, compile as cvm_compile
    from repro.core.expr import col
    from repro.frontends.dataflow import Context, count_, sum_

    rng = np.random.default_rng(21)
    n = 8192
    ctx = Context(pad_to=1024)
    ctx.register("lineitem", {
        "rf": rng.integers(0, 3, n).astype(np.int32),
        "ls": rng.integers(0, 2, n).astype(np.int32),
        "qty": rng.integers(1, 50, n).astype(np.int32),
        "price": rng.gamma(2.0, 100.0, n).astype(np.float32),
        "ship": rng.integers(0, 2500, n).astype(np.int32),
    })
    q1 = (ctx.table("lineitem")
          .filter(col("ship") <= 2000)
          .group_by("rf", "ls", max_groups=8)
          .agg(sum_("qty").as_("sum_qty"), sum_("price").as_("rev"),
               count_().as_("cnt")))
    program = q1.program()
    catalog = ctx.catalog()
    out = {}

    res = cvm_compile(program, target="spmd", parallel=8, catalog=catalog,
                      optimize="cost", cache=False)
    out["strategy"] = dict(res.strategy)
    out["ops"] = sorted(set(res.program.opcodes()))

    want = ctx.execute(q1, target="interp")
    o_w = np.lexsort((np.asarray(want["ls"]), np.asarray(want["rf"])))
    for label in ("sorted", "direct"):
        r = cvm_compile(program, target="spmd", parallel=8, catalog=catalog,
                        strategy={"groupby": label}, cache=False)
        (got_t,) = r(ctx.sources())
        got = got_t.to_numpy()
        o_g = np.lexsort((got["ls"], got["rf"]))
        np.testing.assert_allclose(got["rev"][o_g],
                                   np.asarray(want["rev"]).ravel()[o_w],
                                   rtol=1e-4)
        np.testing.assert_array_equal(got["cnt"][o_g],
                                      np.asarray(want["cnt"]).ravel()[o_w])
        out[label + "_ok"] = True
        out[label + "_ops"] = sorted(set(r.program.opcodes()))

    # the direct tier composes with the exchange recombine (extended
    # PushGroupedCombineIntoMesh): force both and check the oracle again
    r = cvm_compile(program, target="spmd", parallel=8, catalog=catalog,
                    strategy={"groupby": "direct",
                              "grouped-recombine": "exchange"}, cache=False)
    (got_t,) = r(ctx.sources())
    got = got_t.to_numpy()
    o_g = np.lexsort((got["ls"], got["rf"]))
    np.testing.assert_allclose(got["rev"][o_g],
                               np.asarray(want["rev"]).ravel()[o_w], rtol=1e-4)
    out["exchange_direct_ops"] = sorted(set(
        op for p in r.program.walk() for op in p.opcodes()))
    print("RESULTS" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmd_direct_results():
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_DIRECT_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(ROOT),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


class TestSpmdDirectChoice:
    def test_cost_selects_direct_on_spmd(self, spmd_direct_results):
        r = spmd_direct_results
        assert r["strategy"]["groupby"] == "direct"
        assert "vec.GroupAggDirect" in r["ops"]

    def test_both_tiers_match_interp(self, spmd_direct_results):
        assert spmd_direct_results["sorted_ok"]
        assert spmd_direct_results["direct_ok"]
        assert "vec.GroupAggDirect" in spmd_direct_results["direct_ops"]
        assert "vec.GroupAggSorted" in spmd_direct_results["sorted_ops"]

    def test_direct_composes_with_exchange(self, spmd_direct_results):
        ops = spmd_direct_results["exchange_direct_ops"]
        assert "mesh.ExchangeByKey" in ops
        assert "vec.GroupAggDirect" in ops
        assert "vec.GroupAggSorted" not in ops
